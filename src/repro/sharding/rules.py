"""Sharding rules: logical axis names → PartitionSpecs.

Every parameter leaf gets a spec derived from its *path* (what it is) and the
:class:`~repro.configs.base.ParallelConfig` plan:

* FSDP — the largest weight dimension shards over the data axes
  (``('pod','data')`` multi-pod), gathered per layer by GSPMD (or the
  overlap futures when ``overlap_fsdp``);
* TP — heads / d_ff / vocab over the ``model`` axis where divisible;
* EP — the expert dimension over ``model`` when ``shard_experts``;
* caches — batch over data axes; heads or sequence over ``model`` per
  ``seq_shard_cache``;
* anything indivisible stays replicated on that axis (checked numerically,
  never silently wrong — GSPMD refuses non-divisible shardings).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return int(mesh.shape[axes])
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0


def logical_to_spec(
    logical: tuple[str | None, ...], shape: tuple[int, ...], mesh: Mesh, pcfg
) -> P:
    """Map logical dim names to mesh axes, dropping non-divisible mappings."""

    table: dict[str, Any] = {
        "batch": pcfg.data_axes,
        "fsdp": pcfg.data_axes if pcfg.fsdp else None,
        "model": pcfg.model_axis,
        "experts": pcfg.model_axis if pcfg.shard_experts else None,
        "seq_model": pcfg.model_axis,
    }
    out = []
    for name, dim in zip(logical, shape):
        axes = table.get(name) if name else None
        if axes is not None and not _fits(dim, mesh, axes):
            axes = None
        out.append(axes)
    return P(*out)


def param_specs(params: Any, mesh: Mesh, pcfg) -> Any:
    """Specs for a parameter pytree by leaf path conventions."""

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = names[-1] if names else ""
        shape = np.shape(leaf)
        nd = len(shape)
        # stacked layers add a leading scan dim: never shard it
        lead: tuple[str | None, ...] = ()
        core = shape
        if any(n in ("layers", "ssm_layers", "encoder", "decoder", "ssm_tail") for n in names):
            k_lead = 2 if "ssm_layers" in names else 1  # (groups, per) for hybrid
            lead = (None,) * min(k_lead, nd)
            core = shape[len(lead):]

        logical = _logical_for(name, names, core, pcfg)
        return logical_to_spec(lead + logical, shape, mesh, pcfg)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def _logical_for(name: str, names: list[str], core: tuple[int, ...], pcfg):
    nd = len(core)
    tp_heads = pcfg.attn_plan == "tp_heads"
    if name == "embed":
        return ("model", "fsdp")
    if name == "lm_head":
        return ("fsdp", "model")
    if name == "mm_proj":
        return ("fsdp", "model") if nd == 2 else (None,) * nd
    if name in ("wq", "wk", "wv"):
        # (d, heads, head_dim)
        return ("fsdp", "model" if tp_heads else None, None)
    if name == "wo":
        return ("model" if tp_heads else None, None, "fsdp")
    if name in ("bq", "bk", "bv"):
        return ("model" if tp_heads else None, None)
    # MLA
    if name == "wq_a":
        return ("fsdp", "model")
    if name == "wq_b":
        return ("fsdp", "model" if tp_heads else None, None)
    if name == "wkv_a":
        return ("fsdp", None)
    if name in ("wk_b", "wv_b"):
        return ("fsdp", "model" if tp_heads else None, None)
    # MLPs (dense): (d, f) / (f, d); MoE adds leading expert dim
    if name in ("w_gate", "w_up"):
        if nd == 3:
            return ("experts", "fsdp", None if pcfg.shard_experts else "model")
        return ("fsdp", "model")
    if name == "w_down":
        if nd == 3:
            return ("experts", None if pcfg.shard_experts else "model", "fsdp")
        return ("model", "fsdp")
    if name == "router":
        return ("fsdp", None)
    # mamba2
    if name == "w_in":
        return ("fsdp", "model")
    if name == "w_out":
        return ("model", "fsdp")
    if name in ("conv_w", "conv_b"):
        return (None,) * (nd - 1) + ("model",)
    return (None,) * nd


def batch_spec(batch: Any, mesh: Mesh, pcfg) -> Any:
    """Input batch: leading batch dim over the data axes (replicate when it
    does not divide, e.g. long_500k's batch of 1)."""

    def spec_for(leaf):
        shape = np.shape(leaf)
        if not shape:
            return P()
        return logical_to_spec(("batch",) + (None,) * (len(shape) - 1), shape, mesh, pcfg)

    return jax.tree_util.tree_map(spec_for, batch)


def cache_specs(cache: Any, mesh: Mesh, pcfg, cfg) -> Any:
    """KV / SSM / latent caches.

    Layout (L, B, S, H, D) for KV; batch over data axes; then either heads
    over model (tp) or sequence over model (``seq_shard_cache``); SSM states
    (L, B, H, P, N) shard heads over model.
    """

    def spec_for(path: tuple, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = names[-1] if names else ""
        shape = np.shape(leaf)
        nd = len(shape)
        if nd == 0:
            return P()
        if name in ("conv",):
            return logical_to_spec(
                (None, "batch", None, "model"), shape, mesh, pcfg
            )
        if name in ("state",):
            return logical_to_spec(
                (None, "batch", "model", None, None), shape, mesh, pcfg
            )
        if name in ("ckv", "k_rope"):
            seq = "seq_model" if pcfg.seq_shard_cache else None
            return logical_to_spec((None, "batch", seq, None), shape, mesh, pcfg)
        if name in ("k", "v", "k_scale", "v_scale", "cross_k", "cross_v"):
            if pcfg.seq_shard_cache:
                return logical_to_spec(
                    (None, "batch", "seq_model", None, None)[:nd], shape, mesh, pcfg
                )
            return logical_to_spec(
                (None, "batch", None, "model", None)[:nd], shape, mesh, pcfg
            )
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def shardings(tree_specs: Any, mesh: Mesh) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        tree_specs,
        is_leaf=lambda x: isinstance(x, P),
    )
