from repro.sharding.rules import (  # noqa: F401
    batch_spec,
    cache_specs,
    logical_to_spec,
    param_specs,
)
