"""Communicators (paper §II, C1/C4 — and MPI 4.0 §11 Sessions).

``mpi::communicator`` wraps an ``MPI_Comm`` with managed/unmanaged lifetime.
The TPU analogue of a communicator is *a mesh plus a subset of its named
axes*: collectives address devices through axis names, sub-communicators are
axis subsets (``MPI_Comm_split`` along topology dimensions), and "world" is a
1-axis mesh over all devices.

Construction follows the Sessions model (:mod:`repro.core.session`): a
:class:`~repro.core.session.Session` names process sets, a pset yields an
immutable :class:`~repro.core.session.Group`, and
:meth:`Communicator.from_group` — the ``MPI_Comm_create_from_group``
analogue — is the **single canonical constructor**.  Every other path routes
through it: :func:`world` is a shim over the default session's
``repro://world`` pset, :meth:`Communicator.create` wraps its devices in a
group first, and :meth:`split`/:meth:`dup` derive their results from the
parent's group via the group algebra.

Lifetime semantics mirror the paper:

* **managed** — the communicator built the mesh itself (``world()``,
  ``Communicator.create``, ``Communicator.from_group``) and owns it;
* **unmanaged** — it wraps a mesh owned by someone else (a training runtime's
  mesh) and must not outlive it.
* copy construction is deleted (Python: no implicit copies are taken); ``dup``
  exists because MPI provides ``MPI_Comm_dup``; "move" is Python reference
  semantics.

Rank/size are *trace-level* notions inside :meth:`spmd` regions (SPMD code),
exactly as MPI ranks are only meaningful inside the parallel program.

Virtual topologies (MPI 4.0 ch. 8) live in :mod:`repro.core.topology`:
``comm.cart_create(dims, periods)`` / ``comm.dist_graph_create_adjacent``
derive structured communicators from this one — both routed through
:meth:`from_group` (cart grids additionally register ``repro://cart/<dims>``
process sets), so topology construction stays inside the Sessions model.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import _compat, errors
from repro.core.session import Group, GroupComparison, default_session


def _flat_axis_index(axis_names: tuple[str, ...], mesh: Mesh):
    """Linearised rank over possibly-multiple mesh axes (row-major)."""

    idx = None
    for name in axis_names:
        component = jax.lax.axis_index(name)
        size = mesh.shape[name]
        idx = component if idx is None else idx * size + component
    return idx


def _axis_name_from_tag(tag: str) -> str:
    """Default mesh axis name for a pset tag: its last path component,
    sanitised to an identifier (``repro://world`` → ``world``)."""

    leaf = tag.rsplit("/", 1)[-1] if tag else ""
    name = "".join(c if c.isalnum() or c == "_" else "_" for c in leaf)
    return name or "ranks"


class Communicator:
    """A named-axis communicator over a :class:`jax.sharding.Mesh`."""

    def __init__(
        self,
        mesh: Mesh,
        axis_names: Sequence[str] | str | None = None,
        *,
        managed: bool = False,
        tag: str = "",
    ):
        if isinstance(axis_names, str):
            axis_names = (axis_names,)
        if axis_names is None:
            axis_names = tuple(mesh.axis_names)
        axis_names = tuple(axis_names)
        for a in axis_names:
            errors.check(
                a in mesh.axis_names,
                errors.ErrorClass.ERR_TOPOLOGY,
                f"axis {a!r} not in mesh axes {mesh.axis_names}",
            )
        self.mesh = mesh
        self.axis_names = axis_names
        self.managed = managed
        self.tag = tag

    # -- lifetime ----------------------------------------------------------

    @classmethod
    def from_group(
        cls,
        group: Group,
        *,
        tag: str = "",
        shape: Sequence[int] | None = None,
        axis_names: Sequence[str] | None = None,
    ) -> "Communicator":
        """``MPI_Comm_create_from_group``: the canonical constructor.

        Builds (and owns) a fresh mesh over exactly the group's devices.  By
        default the mesh is one axis named after ``tag``
        (``repro://world`` → axis ``world``); pass ``shape``/``axis_names``
        to fold the group onto a multi-axis sub-grid (group rank order is
        row-major over the axes).
        """

        errors.check(
            isinstance(group, Group),
            errors.ErrorClass.ERR_GROUP,
            f"from_group needs a Group, got {type(group).__name__}",
        )
        errors.check(
            group.size() > 0,
            errors.ErrorClass.ERR_GROUP,
            "cannot build a communicator from the empty group",
        )
        if shape is None:
            shape = (group.size(),)
        shape = tuple(int(s) for s in shape)
        errors.check(
            math.prod(shape) == group.size(),
            errors.ErrorClass.ERR_DIMS,
            f"shape {shape} does not fold a group of {group.size()} devices",
        )
        if axis_names is None:
            errors.check(
                len(shape) == 1,
                errors.ErrorClass.ERR_DIMS,
                "multi-axis from_group needs explicit axis_names",
            )
            axis_names = (_axis_name_from_tag(tag),)
        axis_names = tuple(axis_names)
        errors.check(
            len(axis_names) == len(shape),
            errors.ErrorClass.ERR_DIMS,
            f"{len(axis_names)} axis names for a {len(shape)}-dim shape {shape}",
        )
        # Mesh is built directly from the group's own device order (never via
        # make_mesh, which may permute for physical topology): rank r in the
        # group IS the device holding trace-level rank r, row-major.
        mesh = _compat.mesh_from_devices(
            np.array(group.devices, dtype=object).reshape(shape), axis_names
        )
        return cls(mesh, axis_names, managed=True, tag=tag)

    @classmethod
    def create(cls, shape: Sequence[int], axis_names: Sequence[str], devices=None):
        """Managed constructor: wraps ``devices[:prod(shape)]`` in a group
        and routes through :meth:`from_group`."""

        devices = devices if devices is not None else jax.devices()
        n = math.prod(shape)
        errors.check(
            n <= len(devices),
            errors.ErrorClass.ERR_DIMS,
            f"mesh of {n} devices requested, {len(devices)} available",
        )
        return cls.from_group(
            Group(devices[:n]), shape=shape, axis_names=tuple(axis_names)
        )

    def dup(self) -> "Communicator":
        """``MPI_Comm_dup`` analogue (the only sanctioned copy): a new
        handle over the same group and topology (``MPI_IDENT``)."""

        return Communicator(self.mesh, self.axis_names, managed=False, tag=self.tag)

    def __copy__(self):  # copy ctor is "deleted"
        errors.fail(
            errors.ErrorClass.ERR_COMM,
            "communicators are not copyable; use .dup() (MPI_Comm_dup)",
        )

    __deepcopy__ = __copy__

    # -- topology ----------------------------------------------------------

    def size(self) -> int:
        return int(math.prod(self.mesh.shape[a] for a in self.axis_names))

    def axis_size(self, name: str) -> int:
        return int(self.mesh.shape[name])

    def rank(self):
        """Trace-level rank (only meaningful inside :meth:`spmd` bodies)."""

        try:
            return _flat_axis_index(self.axis_names, self.mesh)
        except NameError as e:  # pragma: no cover - jax error type may vary
            errors.fail(
                errors.ErrorClass.ERR_COMM,
                f"rank() is only available inside spmd regions: {e}",
            )

    def split(self, *axis_names: str) -> "Communicator":
        """``MPI_Comm_split`` along topology axes: the returned communicator
        spans ``axis_names``; ranks differing in the *other* axes land in
        different sub-communicators (the color).

        The split is group-routed: each color's process set is derived from
        this communicator's group, and (under error checking) the colors are
        asserted to partition it — pairwise disjoint, union identical — a
        consistency check on the group/mesh indexing, not on user input.
        """

        for a in axis_names:
            errors.check(
                a in self.axis_names,
                errors.ErrorClass.ERR_TOPOLOGY,
                f"split axis {a!r} not spanned by this communicator "
                f"(axes: {self.axis_names})",
            )
        child = Communicator(self.mesh, axis_names, managed=False, tag=self.tag)
        if errors.error_checking_enabled():
            # colors of THIS communicator's group: vary the parent axes the
            # child dropped (other mesh axes stay at self.group()'s color 0)
            dropped = tuple(a for a in self.axis_names if a not in axis_names)
            colors = [
                child.group(**dict(zip(dropped, idx)))
                for idx in np.ndindex(*(self.mesh.shape[a] for a in dropped))
            ]
            merged = Group()
            for i, g in enumerate(colors):
                errors.check(
                    not (merged & g),
                    errors.ErrorClass.ERR_GROUP,
                    f"split color {i} overlaps a previous color",
                )
                merged = merged | g
            errors.check(
                merged.compare(self.group()) is not GroupComparison.UNEQUAL,
                errors.ErrorClass.ERR_GROUP,
                "split colors must partition the communicator group",
            )
        return child

    def _color_axes(self) -> tuple[str, ...]:
        """Mesh axes *not* spanned by this communicator (the color axes)."""

        return tuple(a for a in self.mesh.axis_names if a not in self.axis_names)

    def group(self, **coords: int) -> Group:
        """``MPI_Comm_group``: the process set of this communicator.

        For a split communicator the group depends on the color; fix the
        complement axes with keyword coordinates (``comm.split("model")
        .group(data=1)``), defaulting to color 0.  Rank *r* in the group is
        the device that holds trace-level :meth:`rank` ``r``.
        """

        for a in coords:
            errors.check(
                a in self._color_axes(),
                errors.ErrorClass.ERR_TOPOLOGY,
                f"{a!r} is not a color axis of this communicator "
                f"(color axes: {self._color_axes()})",
            )
        index = []
        for a in self.mesh.axis_names:
            if a in self.axis_names:
                index.append(slice(None))
            else:
                c = int(coords.get(a, 0))
                errors.check(
                    0 <= c < self.mesh.shape[a],
                    errors.ErrorClass.ERR_DIMS,
                    f"color {c} out of range for axis {a!r} of size {self.mesh.shape[a]}",
                )
                index.append(c)
        sub = self.mesh.devices[tuple(index)]
        remaining = [a for a in self.mesh.axis_names if a in self.axis_names]
        sub = np.transpose(sub, [remaining.index(a) for a in self.axis_names])
        return Group(sub.reshape(-1))

    # -- SPMD region launcher ----------------------------------------------

    def spmd(
        self,
        fn: Callable | None = None,
        *,
        in_specs: Any = P(),
        out_specs: Any = P(),
        jit: bool = True,
        donate_argnums: tuple[int, ...] = (),
        static_argnums: tuple[int, ...] = (),
    ):
        """Enter SPMD: run ``fn`` per-device under ``shard_map``.

        This is the region inside which ``rank()`` and all trace-level
        collectives are live — the analogue of the MPI program itself.
        Usable as a decorator.
        """

        if fn is None:
            return lambda f: self.spmd(
                f,
                in_specs=in_specs,
                out_specs=out_specs,
                jit=jit,
                donate_argnums=donate_argnums,
                static_argnums=static_argnums,
            )
        mapped = _compat.shard_map(
            fn,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
        )
        if jit:
            mapped = jax.jit(
                mapped, donate_argnums=donate_argnums, static_argnums=static_argnums
            )
        return mapped

    def run(self, fn: Callable, *args, in_specs: Any = P(), out_specs: Any = P()):
        """One-shot :meth:`spmd` invocation."""

        return self.spmd(fn, in_specs=in_specs, out_specs=out_specs)(*args)

    # -- sharding helpers ---------------------------------------------------

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def device_put(self, value, spec: P):
        return jax.device_put(value, self.sharding(spec))

    def __repr__(self):
        kind = "managed" if self.managed else "unmanaged"
        tag = f", tag={self.tag!r}" if self.tag else ""
        return f"Communicator(axes={self.axis_names}, size={self.size()}, {kind}{tag})"


_WORLD: Communicator | None = None


def world(refresh: bool = False) -> Communicator:
    """The ``mpi::world_communicator`` analogue: one axis over all devices.

    A thin shim over the Sessions model — the default session's
    ``repro://world`` pset, turned into a group, handed to
    :meth:`Communicator.from_group`.  Managed singleton; ``refresh=True``
    rebuilds it (e.g. after an elastic resize changed the device set).
    """

    global _WORLD
    if _WORLD is None or refresh:
        sess = default_session(refresh=refresh)
        _WORLD = Communicator.from_group(
            sess.group("repro://world"), tag="repro://world"
        )
    return _WORLD


def local_ranks(comm: Communicator) -> np.ndarray:
    """Host-side rank layout (for tests and IO): the rank each device holds."""

    sizes = [comm.mesh.shape[a] for a in comm.axis_names]
    return np.arange(math.prod(sizes)).reshape(sizes)
