"""Communicators (paper §II, C1/C4).

``mpi::communicator`` wraps an ``MPI_Comm`` with managed/unmanaged lifetime.
The TPU analogue of a communicator is *a mesh plus a subset of its named
axes*: collectives address devices through axis names, sub-communicators are
axis subsets (``MPI_Comm_split`` along topology dimensions), and "world" is a
1-axis mesh over all devices.

Lifetime semantics mirror the paper:

* **managed** — the communicator built the mesh itself (``world()``,
  ``Communicator.create``) and owns it;
* **unmanaged** — it wraps a mesh owned by someone else (a training runtime's
  mesh) and must not outlive it.
* copy construction is deleted (Python: no implicit copies are taken); ``dup``
  exists because MPI provides ``MPI_Comm_dup``; "move" is Python reference
  semantics.

Rank/size are *trace-level* notions inside :meth:`spmd` regions (SPMD code),
exactly as MPI ranks are only meaningful inside the parallel program.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import errors


def _flat_axis_index(axis_names: tuple[str, ...], mesh: Mesh):
    """Linearised rank over possibly-multiple mesh axes (row-major)."""

    idx = None
    for name in axis_names:
        component = jax.lax.axis_index(name)
        size = mesh.shape[name]
        idx = component if idx is None else idx * size + component
    return idx


class Communicator:
    """A named-axis communicator over a :class:`jax.sharding.Mesh`."""

    def __init__(
        self,
        mesh: Mesh,
        axis_names: Sequence[str] | str | None = None,
        *,
        managed: bool = False,
    ):
        if isinstance(axis_names, str):
            axis_names = (axis_names,)
        if axis_names is None:
            axis_names = tuple(mesh.axis_names)
        axis_names = tuple(axis_names)
        for a in axis_names:
            errors.check(
                a in mesh.axis_names,
                errors.ErrorClass.ERR_TOPOLOGY,
                f"axis {a!r} not in mesh axes {mesh.axis_names}",
            )
        self.mesh = mesh
        self.axis_names = axis_names
        self.managed = managed

    # -- lifetime ----------------------------------------------------------

    @classmethod
    def create(cls, shape: Sequence[int], axis_names: Sequence[str], devices=None):
        """Managed constructor: builds (and owns) a fresh mesh."""

        devices = devices if devices is not None else jax.devices()
        n = math.prod(shape)
        errors.check(
            n <= len(devices),
            errors.ErrorClass.ERR_DIMS,
            f"mesh of {n} devices requested, {len(devices)} available",
        )
        mesh = jax.make_mesh(
            tuple(shape),
            tuple(axis_names),
            devices=devices[:n],
            axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(shape)),
        )
        return cls(mesh, axis_names, managed=True)

    def dup(self) -> "Communicator":
        """``MPI_Comm_dup`` analogue (the only sanctioned copy)."""

        return Communicator(self.mesh, self.axis_names, managed=False)

    def __copy__(self):  # copy ctor is "deleted"
        errors.fail(
            errors.ErrorClass.ERR_COMM,
            "communicators are not copyable; use .dup() (MPI_Comm_dup)",
        )

    __deepcopy__ = __copy__

    # -- topology ----------------------------------------------------------

    def size(self) -> int:
        return int(math.prod(self.mesh.shape[a] for a in self.axis_names))

    def axis_size(self, name: str) -> int:
        return int(self.mesh.shape[name])

    def rank(self):
        """Trace-level rank (only meaningful inside :meth:`spmd` bodies)."""

        try:
            return _flat_axis_index(self.axis_names, self.mesh)
        except NameError as e:  # pragma: no cover - jax error type may vary
            errors.fail(
                errors.ErrorClass.ERR_COMM,
                f"rank() is only available inside spmd regions: {e}",
            )

    def split(self, *axis_names: str) -> "Communicator":
        """``MPI_Comm_split`` along topology axes: the returned communicator
        spans ``axis_names``; ranks differing in the *other* axes land in
        different sub-communicators (the color)."""

        return Communicator(self.mesh, axis_names, managed=False)

    def group(self) -> tuple[str, ...]:
        """The axis-name group (``MPI_Comm_group`` analogue)."""

        return self.axis_names

    # -- SPMD region launcher ----------------------------------------------

    def spmd(
        self,
        fn: Callable | None = None,
        *,
        in_specs: Any = P(),
        out_specs: Any = P(),
        jit: bool = True,
        donate_argnums: tuple[int, ...] = (),
        static_argnums: tuple[int, ...] = (),
    ):
        """Enter SPMD: run ``fn`` per-device under ``shard_map``.

        This is the region inside which ``rank()`` and all trace-level
        collectives are live — the analogue of the MPI program itself.
        Usable as a decorator.
        """

        if fn is None:
            return lambda f: self.spmd(
                f,
                in_specs=in_specs,
                out_specs=out_specs,
                jit=jit,
                donate_argnums=donate_argnums,
                static_argnums=static_argnums,
            )
        mapped = jax.shard_map(
            fn,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
        if jit:
            mapped = jax.jit(
                mapped, donate_argnums=donate_argnums, static_argnums=static_argnums
            )
        return mapped

    def run(self, fn: Callable, *args, in_specs: Any = P(), out_specs: Any = P()):
        """One-shot :meth:`spmd` invocation."""

        return self.spmd(fn, in_specs=in_specs, out_specs=out_specs)(*args)

    # -- sharding helpers ---------------------------------------------------

    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def device_put(self, value, spec: P):
        return jax.device_put(value, self.sharding(spec))

    def __repr__(self):
        kind = "managed" if self.managed else "unmanaged"
        return f"Communicator(axes={self.axis_names}, size={self.size()}, {kind})"


_WORLD: Communicator | None = None


def world(refresh: bool = False) -> Communicator:
    """The ``mpi::world_communicator`` analogue: one axis over all devices.

    Managed singleton; ``refresh=True`` rebuilds it (e.g. after an elastic
    resize changed the device set).
    """

    global _WORLD
    if _WORLD is None or refresh:
        n = len(jax.devices())
        _WORLD = Communicator.create((n,), ("world",))
    return _WORLD


def local_ranks(comm: Communicator) -> np.ndarray:
    """Host-side rank layout (for tests and IO): the rank each device holds."""

    sizes = [comm.mesh.shape[a] for a in comm.axis_names]
    return np.arange(math.prod(sizes)).reshape(sizes)
