"""Parallel IO (paper §II — MPI 4.0 chapter 14, ``MPI_File_*``).

Collective IO's purpose is bandwidth-parallel, offset-disjoint file access.
The JAX-cluster adaptation: a :class:`File` is a *directory dataset* where
each process writes the shards it owns (`.npy` fragments named by their
global offset) plus an atomically renamed JSON manifest — the idiom every
production checkpointing system on TPU uses (and what
:mod:`repro.checkpoint` builds on).

``write_at_all`` / ``read_at_all`` mirror the collective ``MPI_File_*_at_all``
calls: every process participates, offsets are disjoint by construction
(derived from the array sharding), and completion of the manifest write is
the ``MPI_File_sync`` point.
"""

from __future__ import annotations

import builtins
import hashlib
import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

from repro.core import errors
from repro.core.descriptors import FileSpec, Mode

MANIFEST = "manifest.json"


def _atomic_write(path: str, data: bytes) -> None:
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _resolve_dtype(name: str) -> np.dtype:
    """np.dtype from a manifest string, including extended ml_dtypes names."""

    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _checksum(buf: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(buf).tobytes()).hexdigest()[:16]


class File:
    """A parallel dataset directory (``MPI_File`` analogue)."""

    def __init__(self, path: str, spec: FileSpec | None = None):
        self.path = path
        self.spec = spec or FileSpec()
        if Mode.CREATE in self.spec.mode:
            os.makedirs(path, exist_ok=True)
        elif Mode.EXCL in self.spec.mode and os.path.exists(os.path.join(path, MANIFEST)):
            errors.fail(errors.ErrorClass.ERR_FILE, f"{path} already exists (EXCL)")

    # -- collective writes ---------------------------------------------------

    def write_at_all(self, name: str, array: jax.Array | np.ndarray) -> dict:
        """Collective write: each process writes the addressable shards it
        owns at their global offsets; one manifest describes the whole."""

        errors.check(
            Mode.WRONLY in self.spec.mode or Mode.RDWR in self.spec.mode,
            errors.ErrorClass.ERR_FILE,
            f"{self.path} not opened for writing",
        )
        entries = []
        if isinstance(array, jax.Array) and hasattr(array, "addressable_shards"):
            shards = array.addressable_shards
            global_shape = tuple(array.shape)
            dtype = str(np.dtype(array.dtype))
            seen = set()
            for shard in shards:
                start = tuple(s.start or 0 for s in shard.index)
                if start in seen:  # replicated shard: first owner writes
                    continue
                seen.add(start)
                buf = np.asarray(shard.data)
                frag = f"{name}.{'_'.join(map(str, start))}.npy"
                self._write_fragment(frag, buf)
                entries.append(
                    {
                        "fragment": frag,
                        "offset": list(start),
                        "shape": list(buf.shape),
                        "checksum": _checksum(buf) if self.spec.checksum else None,
                    }
                )
        else:
            buf = np.asarray(array)
            global_shape = tuple(buf.shape)
            dtype = str(buf.dtype)
            frag = f"{name}.0.npy"
            self._write_fragment(frag, buf)
            entries.append(
                {
                    "fragment": frag,
                    "offset": [0] * buf.ndim,
                    "shape": list(buf.shape),
                    "checksum": _checksum(buf) if self.spec.checksum else None,
                }
            )
        record = {"name": name, "shape": list(global_shape), "dtype": dtype, "fragments": entries}
        self._update_manifest(name, record)
        return record

    def _write_fragment(self, frag: str, buf: np.ndarray) -> None:
        import io as _io

        # np.save cannot serialise extended ml_dtypes (bfloat16, fp8):
        # store them as unsigned views; the manifest dtype restores them.
        if buf.dtype.kind not in "biufc":
            buf = buf.view(np.dtype(f"uint{buf.dtype.itemsize * 8}"))
        bio = _io.BytesIO()
        np.save(bio, buf, allow_pickle=False)
        _atomic_write(os.path.join(self.path, frag), bio.getvalue())

    def _update_manifest(self, name: str, record: dict) -> None:
        manifest = self.manifest()
        manifest["arrays"][name] = record
        _atomic_write(
            os.path.join(self.path, MANIFEST),
            json.dumps(manifest, indent=1).encode(),
        )

    # -- collective reads ------------------------------------------------------

    def manifest(self) -> dict:
        p = os.path.join(self.path, MANIFEST)
        if os.path.exists(p):
            with builtins.open(p) as f:
                return json.load(f)
        return {"version": 1, "arrays": {}}

    def read_at_all(self, name: str, sharding: Any | None = None) -> jax.Array:
        """Collective read: reassemble (and optionally reshard) an array.

        With a target ``sharding`` whose mesh differs from the writer's, this
        is the *elastic restore* path: fragments are assembled to the global
        array and placed under the new sharding.
        """

        rec = self.manifest()["arrays"].get(name)
        if rec is None:
            errors.fail(errors.ErrorClass.ERR_IO, f"array {name!r} not in {self.path}")
        dtype = _resolve_dtype(rec["dtype"])
        out = np.zeros(rec["shape"], dtype=dtype)
        for e in rec["fragments"]:
            buf = np.load(os.path.join(self.path, e["fragment"]), allow_pickle=False)
            if self.spec.checksum and e.get("checksum"):
                errors.check(
                    _checksum(buf) == e["checksum"],
                    errors.ErrorClass.ERR_IO,
                    f"checksum mismatch in {e['fragment']}",
                )
            if buf.dtype != dtype:  # stored as an unsigned view (bf16/fp8)
                buf = buf.view(dtype)
            idx = tuple(slice(o, o + s) for o, s in zip(e["offset"], e["shape"]))
            out[idx] = buf
        if sharding is not None:
            return jax.device_put(out, sharding)
        return jax.numpy.asarray(out)

    def names(self) -> list[str]:
        return sorted(self.manifest()["arrays"].keys())


def open(path: str, mode: Mode = Mode.RDONLY, **kw) -> File:  # noqa: A001
    """``MPI_File_open`` analogue with meaningful defaults."""

    return File(path, FileSpec(mode=mode, **kw))
