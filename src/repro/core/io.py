"""Parallel IO (paper §II — MPI 4.0 chapter 14, ``MPI_File_*``).

Collective IO's purpose is bandwidth-parallel, offset-disjoint file access.
The JAX-cluster adaptation: a :class:`File` is a *directory dataset* where
each process writes the shards it owns (`.npy` fragments named by their
global offset) plus an atomically renamed JSON manifest — the idiom every
production checkpointing system on TPU uses (and what
:mod:`repro.checkpoint` builds on).

The chapter-14 surface and its mapping:

===============================  ==========================================
MPI 4.0                          here
===============================  ==========================================
``MPI_File_open``                :func:`open` / :class:`File` (``EXCL``
                                 raises ``ERR_FILE`` on an existing
                                 dataset, with or without ``CREATE``)
``MPI_File_write_at_all``        :meth:`File.write_at_all` (blocking)
``MPI_File_iwrite_at_all``       :meth:`File.iwrite_at_all` → a host
                                 :class:`IORequest` future in the C3 engine
``MPI_File_iread_at_all``        :meth:`File.iread_at_all`
``MPI_File_*_at_all_begin/end``  :meth:`File.write_at_all_begin` /
                                 ``..._end`` split collectives (one active
                                 split collective per handle, MPI's rule)
``MPI_File_set_view``            :meth:`File.set_view` — etype (storage
                                 representation) + filetype (a C2
                                 :class:`~repro.core.datatypes.DataType`
                                 packed layout, paged like an RMA window)
``MPI_File_sync``                :meth:`File.commit_manifest` — one atomic
                                 manifest write covering many records
===============================  ==========================================

Completion of the manifest write is the sync point; nonblocking operations
complete at ``get()``/``wait()`` on their request, where any background
failure is re-raised as ``ERR_IO`` — a failed write can never read as
success (the error-forwarding gap thin wrappers are criticised for).
"""

from __future__ import annotations

import atexit
import builtins
import dataclasses
import hashlib
import json
import os
import sys
import tempfile
import threading
import weakref
from typing import Any, Callable

import jax
import numpy as np

from repro.analysis import events as analysis_events
from repro.core import datatypes, errors
from repro.core.descriptors import FileSpec, Mode
from repro.core.futures import DeferredFuture

MANIFEST = "manifest.json"


def _atomic_write(path: str, data: bytes) -> None:
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".tmp-")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _resolve_dtype(name: str) -> np.dtype:
    """np.dtype from a manifest string, including extended ml_dtypes names."""

    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes

        return np.dtype(getattr(ml_dtypes, name))


def _checksum(buf: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(buf).tobytes()).hexdigest()[:16]


def storage_alias(dtype: Any) -> np.dtype | None:
    """The on-disk alias for dtypes ``np.save`` cannot serialise (bfloat16,
    fp8, ...): the same-itemsize unsigned integer, so the bytes round-trip
    exactly.  ``None`` for natively serialisable dtypes."""

    dtype = np.dtype(dtype)
    if dtype.kind in "biufc":
        return None
    return np.dtype(f"uint{dtype.itemsize * 8}")


# ---------------------------------------------------------------------------
# request-based nonblocking IO (MPI_File_i*)
# ---------------------------------------------------------------------------

_OUTSTANDING: "weakref.WeakSet[IORequest]" = weakref.WeakSet()


class IORequest(DeferredFuture):
    """A nonblocking file operation's request (``MPI_File_i*``).

    The operation body runs on a background thread; the request itself is a
    host :class:`~repro.core.futures.DeferredFuture`, so it chains with
    ``then()`` and joins with ``when_all`` exactly like every other request
    in the C3 engine.  ``get()``/``wait()`` join the thread and re-raise any
    failure — typed :class:`~repro.core.errors.Error`\\ s pass through
    unchanged, anything else is wrapped as ``ERR_IO`` — so a background
    failure always surfaces at the completion call, never as a silent
    success.  Threads are daemonic, but every live request is joined by an
    ``atexit`` hook: interpreter shutdown cannot kill an operation mid-write.
    """

    def __init__(self, op: str, fn: Callable[[], Any], *, start: bool = True):
        self.op = op
        self._exc: BaseException | None = None
        self._result: Any = None
        self._event = threading.Event()
        self._start_lock = threading.Lock()
        self._launched = False
        self._delivered = False

        def run():
            try:
                self._result = fn()
            except errors.Error as e:
                self._exc = e
            except BaseException as e:  # lint: allow-broad-except — forwarded to the joiner, never dropped
                exc = errors.exception(errors.ErrorClass.ERR_IO, f"{op}: {e!r}")
                exc.__cause__ = e
                self._exc = exc
            finally:
                self._event.set()

        super().__init__(self._join, probe=self._event.is_set)
        self._thread = threading.Thread(target=run, name=f"repro-io:{op}", daemon=True)
        _OUTSTANDING.add(self)
        if start:
            self.start()

    def start(self) -> "IORequest":
        """Activate the request (idempotent).  ``start=False`` construction
        is the persistent-style two-phase form: a batch issuer creates all
        its requests cheaply and a single driver fans them out, paying one
        thread launch on the issue path instead of N (the checkpoint
        manager's bucket requests)."""

        with self._start_lock:
            if not self._launched:
                self._launched = True
                self._thread.start()
        return self

    @property
    def delivered(self) -> bool:
        """Has the captured failure (if any) been raised to a caller?  The
        atexit reporter uses this instead of request validity: a request
        consumed by ``then()`` whose chain is never waited must still have
        its failure surfaced somewhere."""

        return self._delivered

    def _join(self) -> Any:
        self.start()  # waiting an inactive request activates it first
        self._thread.join()
        if self._exc is not None:
            self._delivered = True
            raise self._exc
        return self._result

    def drain(self) -> BaseException | None:
        """Join without raising; return the captured failure, if any (the
        atexit path — exceptions cannot propagate out of interpreter
        shutdown, but they must not vanish either)."""

        self.start()
        self._thread.join()
        return self._exc


@atexit.register
def _join_outstanding_at_exit() -> None:
    for req in list(_OUTSTANDING):
        exc = req.drain()
        if exc is not None and not req.delivered:
            print(
                f"repro.core.io: background {req.op} failed at interpreter "
                f"exit: {exc}",
                file=sys.stderr,
            )


# ---------------------------------------------------------------------------
# file views (MPI_File_set_view)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FileView:
    """An installed file view: how collective accesses interpret the data.

    ``etype`` is the elementary storage representation — fragments are
    stored as this (same-itemsize) dtype and reinterpreted back to the
    manifest dtype on read.  ``filetype`` is a C2
    :class:`~repro.core.datatypes.DataType`: writes pack the aggregate into
    its per-dtype group buffers and store them page-by-page
    (:meth:`~repro.core.datatypes.DataType.page_bounds` — the same paging an
    RMA window uses), reads reassemble and unpack.
    """

    etype: np.dtype | None = None
    filetype: "datatypes.DataType | None" = None
    num_pages: int = 1


class File:
    """A parallel dataset directory (``MPI_File`` analogue)."""

    def __init__(self, path: str, spec: FileSpec | None = None):
        self.path = path
        self.spec = spec or FileSpec()
        # MPI_ERR_FILE_EXISTS semantics: EXCL rejects an existing dataset
        # whether or not CREATE is also set (the old elif skipped the check
        # whenever CREATE was present, so CREATE | EXCL could never raise)
        if Mode.EXCL in self.spec.mode and os.path.exists(os.path.join(path, MANIFEST)):
            errors.fail(errors.ErrorClass.ERR_FILE, f"{path} already exists (EXCL)")
        if Mode.CREATE in self.spec.mode:
            os.makedirs(path, exist_ok=True)
        self._view = FileView()
        self._split: tuple[str, str, IORequest] | None = None
        self._manifest_cache: dict | None = None
        self._manifest_lock = threading.Lock()
        #: fault-injection / test hook, called with each fragment name just
        #: before its write (see ``runtime.faults.FaultInjector.check_io``)
        self.write_hook: Callable[[str], None] | None = None

    # -- views ---------------------------------------------------------------

    def set_view(
        self,
        etype: Any | None = None,
        filetype: Any | None = None,
        *,
        num_pages: int | None = None,
    ) -> "File":
        """``MPI_File_set_view``: install (or, with no arguments, reset) the
        view through which subsequent collective accesses run.

        ``filetype`` may be a :class:`~repro.core.datatypes.DataType` or any
        compliant example aggregate (its datatype is derived, the C2
        reflection step).  The written layout records the view's group
        signature; a reader must install a matching view (``ERR_IO``
        otherwise) — MPI's etype/filetype equivalence rule for collective
        accesses.  ``num_pages`` splits each group buffer into near-equal
        page fragments, the granularity at which RMA window pages round-trip
        through files.
        """

        from repro.core import tool

        if filetype is not None and not isinstance(filetype, datatypes.DataType):
            filetype = datatypes.datatype_of(filetype)
        et = None if etype is None else np.dtype(etype)
        if et is not None:
            errors.check(
                et.kind in "biufc",
                errors.ErrorClass.ERR_TYPE,
                f"etype {et} is not a serialisable storage dtype",
            )
        n = 1 if num_pages is None else int(num_pages)
        errors.check(
            n >= 1, errors.ErrorClass.ERR_ARG, f"set_view needs >= 1 page, got {n}"
        )
        if et is not None and filetype is not None:
            for d in filetype.group_dtypes:
                errors.check(
                    np.dtype(d).itemsize == et.itemsize,
                    errors.ErrorClass.ERR_TYPE,
                    f"etype {et} (itemsize {et.itemsize}) cannot represent "
                    f"group dtype {np.dtype(d)}",
                )
        self._view = FileView(et, filetype, n)
        tool.pvar_count("io_set_view")
        return self

    @property
    def view(self) -> FileView:
        return self._view

    # -- collective writes ---------------------------------------------------

    def _check_writable(self) -> None:
        errors.check(
            Mode.WRONLY in self.spec.mode or Mode.RDWR in self.spec.mode,
            errors.ErrorClass.ERR_FILE,
            f"{self.path} not opened for writing",
        )

    def _storage_dtype(self, dtype: Any) -> np.dtype | None:
        """The dtype a fragment is stored as, or ``None`` for as-is."""

        dtype = np.dtype(dtype)
        et = self._view.etype
        if et is not None and et != dtype:
            errors.check(
                et.itemsize == dtype.itemsize,
                errors.ErrorClass.ERR_TYPE,
                f"etype {et} (itemsize {et.itemsize}) cannot store dtype {dtype}",
            )
            return et
        return storage_alias(dtype)

    def _gather(self, name: str, array: Any) -> tuple[dict, list[tuple[str, np.ndarray]]]:
        """Synchronous device→host gather: the fragment buffers plus the
        manifest record describing them.  Shared by the blocking,
        nonblocking and split collective forms — the buffers are stable
        before control returns, so a pending request never races the
        caller's arrays.  The checkpoint manager keeps its own variant of
        this gather (sanitised names, checksums deferred to the bucket
        threads): fragment/record shape changes here must be mirrored
        there."""

        if self._view.filetype is not None:
            return self._gather_view(name, array)
        entries: list[dict] = []
        frags: list[tuple[str, np.ndarray]] = []
        if isinstance(array, jax.Array) and hasattr(array, "addressable_shards"):
            global_shape = tuple(array.shape)
            dtype = str(np.dtype(array.dtype))
            seen = set()
            for shard in array.addressable_shards:
                start = tuple(s.start or 0 for s in shard.index)
                if start in seen:  # replicated shard: first owner writes
                    continue
                seen.add(start)
                buf = np.asarray(shard.data)
                frag = f"{name}.{'_'.join(map(str, start))}.npy"
                frags.append((frag, buf))
                entries.append(
                    {
                        "fragment": frag,
                        "offset": list(start),
                        "shape": list(buf.shape),
                        "checksum": _checksum(buf) if self.spec.checksum else None,
                    }
                )
        else:
            buf = np.asarray(array)
            global_shape = tuple(buf.shape)
            dtype = str(buf.dtype)
            frag = f"{name}.0.npy"
            frags.append((frag, buf))
            entries.append(
                {
                    "fragment": frag,
                    "offset": [0] * buf.ndim,
                    "shape": list(buf.shape),
                    "checksum": _checksum(buf) if self.spec.checksum else None,
                }
            )
        record = {
            "name": name,
            "shape": list(global_shape),
            "dtype": dtype,
            "fragments": entries,
        }
        if self._view.etype is not None:
            record["etype"] = str(self._view.etype)
        return record, frags

    def _gather_view(self, name: str, aggregate: Any) -> tuple[dict, list]:
        """Filetype-view gather: pack the aggregate into the datatype's
        per-dtype group buffers and page them (one fragment per page)."""

        dt = self._view.filetype
        bufs = dt.pack(aggregate)
        bounds = dt.page_bounds(self._view.num_pages)
        entries, frags = [], []
        for g, (buf, pages) in enumerate(zip(bufs, bounds)):
            host = np.asarray(buf)
            for p, (off, length) in enumerate(pages):
                page = host[off : off + length]
                frag = f"{name}.g{g}.p{p}.npy"
                frags.append((frag, page))
                entries.append(
                    {
                        "fragment": frag,
                        "group": g,
                        "offset": [int(off)],
                        "shape": [int(length)],
                        "checksum": _checksum(page) if self.spec.checksum else None,
                    }
                )
        record = {
            "name": name,
            "view": {**dt.layout_signature(), "num_pages": self._view.num_pages},
            "fragments": entries,
        }
        if self._view.etype is not None:
            record["etype"] = str(self._view.etype)
        return record, frags

    def write_at_all(self, name: str, array: Any) -> dict:
        """Collective write: each process writes the addressable shards it
        owns at their global offsets (or, under a filetype view, the packed
        group-buffer pages); one manifest record describes the whole.  The
        manifest write is the sync point."""

        from repro.core import tool

        self._check_writable()
        tool.pvar_count("io_write")
        record, frags = self._gather(name, array)
        for frag, buf in frags:
            self._write_fragment(frag, buf)
        self._update_manifest(name, record)
        return record

    def iwrite_at_all(self, name: str, array: Any, *, commit: bool = True) -> IORequest:
        """``MPI_File_iwrite_at_all``: nonblocking collective write.

        The device→host gather happens synchronously (the buffers are
        stable before control returns); fragment and manifest writes run on
        a background thread.  The returned request chains with ``then()``
        and joins with ``when_all``; completing it is the manifest sync
        point, and a failed write raises ``ERR_IO`` from ``get()``/``wait()``
        — never a silent success.

        ``commit=False`` defers the manifest update: the request completes
        once the fragments are durable and resolves to the record, which the
        caller later passes to :meth:`commit_manifest` — one sync point over
        many writes, the checkpoint manager's single-commit save.
        """

        from repro.core import tool

        self._check_writable()
        tool.pvar_count("io_iwrite")
        record, frags = self._gather(name, array)

        def work():
            for frag, buf in frags:
                self._write_fragment(frag, buf)
            if commit:
                self._update_manifest(name, record)
            return record

        return IORequest(f"iwrite_at_all({name!r})", work)

    def awrite_fragments(
        self, op: str, frags: list[tuple[str, np.ndarray]], *, start: bool = True
    ) -> IORequest:
        """One request over pre-gathered ``(fragment, buffer)`` pairs — the
        checkpoint manager's per-dtype-bucket write.  No manifest update:
        pair with :meth:`commit_manifest` for the single sync point.

        Resolves to ``{fragment: checksum}`` — digests are computed on the
        background thread (off the issue path) and flow through the request
        join into the commit continuation (Listing-2 dataflow)."""

        self._check_writable()

        def work():
            sums = {}
            for frag, buf in frags:
                self._write_fragment(frag, buf)
                sums[frag] = _checksum(buf) if self.spec.checksum else None
            return sums

        return IORequest(op, work, start=start)

    def _write_fragment(self, frag: str, buf: np.ndarray) -> int:
        import io as _io

        from repro.core import tool

        if self.write_hook is not None:
            self.write_hook(frag)
        store = self._storage_dtype(buf.dtype)
        if store is not None:
            buf = np.ascontiguousarray(buf).view(store)
        bio = _io.BytesIO()
        np.save(bio, buf, allow_pickle=False)
        data = bio.getvalue()
        _atomic_write(os.path.join(self.path, frag), data)
        if self.spec.verify:
            # data integrity, not interface validation: raises even with the
            # error_checking cvar off (a torn write must never read as ok)
            back = np.load(os.path.join(self.path, frag), allow_pickle=False)
            if _checksum(back) != _checksum(buf):
                errors.fail(
                    errors.ErrorClass.ERR_IO, f"read-back verify failed for {frag}"
                )
        tool.pvar_add("io_bytes_written", len(data))
        return len(data)

    # -- the manifest sync point ----------------------------------------------

    def commit_manifest(
        self, records: dict[str, dict], meta: dict | None = None
    ) -> None:
        """Merge ``records`` and write the manifest **once**, atomically —
        the explicit ``MPI_File_sync``.  N arrays cost a single
        read-modify-write, not N rewrites of an ever-growing JSON (the old
        per-array update was O(n²) over a whole checkpoint).

        ``meta`` — writer-context tags merged into ``manifest["meta"]``
        (the elastic runtime records the communicator epoch and world size
        the fragments were sharded under, so a restore onto a different
        survivor set knows it is resharding)."""

        from repro.core import tool

        with self._manifest_lock:
            manifest = self.manifest()
            for name, record in records.items():
                manifest["arrays"][name] = record
            if meta:
                manifest.setdefault("meta", {}).update(meta)
            _atomic_write(
                os.path.join(self.path, MANIFEST),
                json.dumps(manifest, indent=1).encode(),
            )
            self._manifest_cache = manifest
        tool.pvar_count("io_manifest_commit")

    def _update_manifest(self, name: str, record: dict) -> None:
        self.commit_manifest({name: record})

    # -- split collectives (MPI_File_*_at_all_begin / _end) --------------------

    def write_at_all_begin(self, name: str, array: Any) -> None:
        """``MPI_File_write_at_all_begin``: start the split collective.  At
        most one split collective may be active per file handle (MPI's
        rule) — ``ERR_REQUEST`` otherwise."""

        from repro.core import tool

        self._check_split_free()
        tool.pvar_count("io_split_begin")
        if analysis_events.RECORDING:
            analysis_events.record_io_split("io_split_begin", str(self.path), name)
        self._split = ("write", name, self.iwrite_at_all(name, array))

    def write_at_all_end(self, name: str) -> dict:
        """Complete the split collective write; returns the manifest record.
        Failures surface here as ``ERR_IO``."""

        return self._split_end("write", name)

    def read_at_all_begin(self, name: str, sharding: Any | None = None) -> None:
        """``MPI_File_read_at_all_begin``: start the split collective read."""

        from repro.core import tool

        self._check_split_free()
        tool.pvar_count("io_split_begin")
        if analysis_events.RECORDING:
            analysis_events.record_io_split("io_split_begin", str(self.path), name)
        self._split = ("read", name, self.iread_at_all(name, sharding))

    def read_at_all_end(self, name: str) -> Any:
        return self._split_end("read", name)

    def _check_split_free(self) -> None:
        active = self._split
        errors.check(
            active is None,
            errors.ErrorClass.ERR_REQUEST,
            f"split collective already active on {self.path}"
            + (f" ({active[0]}_at_all({active[1]!r}))" if active else ""),
        )

    def _split_end(self, kind: str, name: str) -> Any:
        errors.check(
            self._split is not None,
            errors.ErrorClass.ERR_REQUEST,
            f"{kind}_at_all_end({name!r}) without a matching begin",
        )
        k, n, req = self._split
        errors.check(
            (k, n) == (kind, name),
            errors.ErrorClass.ERR_REQUEST,
            f"{kind}_at_all_end({name!r}) does not match the active split "
            f"collective {k}_at_all({n!r})",
        )
        if analysis_events.RECORDING:
            analysis_events.record_io_split("io_split_end", str(self.path), name)
        self._split = None
        return req.get()

    # -- collective reads ------------------------------------------------------

    def manifest(self, *, refresh: bool = False) -> dict:
        p = os.path.join(self.path, MANIFEST)
        if self._manifest_cache is None or refresh:
            if not os.path.exists(p):
                return {"version": 1, "arrays": {}}  # absence is not cached
            with builtins.open(p) as f:
                self._manifest_cache = json.load(f)
        return self._manifest_cache

    def read_at_all(self, name: str, sharding: Any | None = None) -> Any:
        """Collective read: reassemble (and optionally reshard) an array.

        With a target ``sharding`` whose mesh differs from the writer's, this
        is the *elastic restore* path: fragments are assembled to the global
        array and placed under the new sharding.  Under a filetype view the
        result is the unpacked aggregate.
        """

        from repro.core import tool

        tool.pvar_count("io_read")
        return self._read(name, sharding)

    def iread_at_all(self, name: str, sharding: Any | None = None) -> IORequest:
        """``MPI_File_iread_at_all``: nonblocking collective read; the
        request resolves to the assembled (optionally resharded) array, or
        the unpacked aggregate under a filetype view."""

        from repro.core import tool

        tool.pvar_count("io_iread")
        return IORequest(f"iread_at_all({name!r})", lambda: self._read(name, sharding))

    def _read(self, name: str, sharding: Any | None = None) -> Any:
        rec = self.manifest()["arrays"].get(name)
        if rec is None:
            errors.fail(errors.ErrorClass.ERR_IO, f"array {name!r} not in {self.path}")
        if "view" in rec:
            return self._read_view(name, rec)
        dtype = _resolve_dtype(rec["dtype"])
        out = np.zeros(rec["shape"], dtype=dtype)
        for e in rec["fragments"]:
            buf = self._load_fragment(e, dtype, rec)
            idx = tuple(slice(o, o + s) for o, s in zip(e["offset"], e["shape"]))
            out[idx] = buf
        if sharding is not None:
            return jax.device_put(out, sharding)
        return jax.numpy.asarray(out)

    def _read_view(self, name: str, rec: dict) -> Any:
        # unconditional (data integrity): a wrong view would unpack wrong
        # bytes into right-looking arrays
        dt = self._view.filetype
        if dt is None:
            errors.fail(
                errors.ErrorClass.ERR_IO,
                f"{name!r} was written through a file view; "
                "set_view(filetype=...) before reading it",
            )
        if rec["view"]["groups"] != dt.layout_signature()["groups"]:
            errors.fail(
                errors.ErrorClass.ERR_IO,
                f"file view mismatch for {name!r}: dataset layout "
                f"{rec['view']['groups']}, installed view "
                f"{dt.layout_signature()['groups']}",
            )
        bufs = []
        for g, grp in enumerate(rec["view"]["groups"]):
            gd = _resolve_dtype(grp["dtype"])
            out = np.zeros(grp["size"], dtype=gd)
            for e in rec["fragments"]:
                if e.get("group") != g:
                    continue
                buf = self._load_fragment(e, gd, rec)
                off = e["offset"][0]
                out[off : off + e["shape"][0]] = buf
            bufs.append(jax.numpy.asarray(out))
        return dt.unpack(bufs)

    def _load_fragment(self, e: dict, dtype: np.dtype, rec: dict) -> np.ndarray:
        from repro.core import tool

        buf = np.load(os.path.join(self.path, e["fragment"]), allow_pickle=False)
        tool.pvar_add("io_bytes_read", buf.nbytes)
        # integrity checks below are unconditional: they guard the data, not
        # the interface, so the error_checking cvar must not disable them
        if self.spec.checksum and e.get("checksum"):
            if _checksum(buf) != e["checksum"]:
                errors.fail(
                    errors.ErrorClass.ERR_IO,
                    f"checksum mismatch in {e['fragment']}",
                )
        if buf.dtype != dtype:
            # reinterpret ONLY a declared storage representation — the
            # record's etype, the installed view etype, or the unsigned
            # serialisation alias (all same-itemsize, so the bytes
            # round-trip exactly).  Anything else is a corrupt or foreign
            # fragment: a typed ERR_IO, never a blind view() (a float64
            # fragment against a float32 manifest used to corrupt silently
            # or crash with a bare numpy error).
            declared: set[np.dtype] = set()
            if rec.get("etype") is not None:
                declared.add(np.dtype(rec["etype"]))
            if self._view.etype is not None:
                declared.add(self._view.etype)
            alias = storage_alias(dtype)
            if alias is not None:
                declared.add(alias)
            if not (buf.dtype in declared and buf.dtype.itemsize == dtype.itemsize):
                errors.fail(
                    errors.ErrorClass.ERR_IO,
                    f"fragment {e['fragment']} has dtype {buf.dtype}; the "
                    f"manifest says {dtype} (declared storage: "
                    f"{sorted(str(d) for d in declared)}) — refusing to "
                    "reinterpret",
                )
            buf = buf.view(dtype)
        return buf

    def names(self) -> list[str]:
        return sorted(self.manifest()["arrays"].keys())


def open(path: str, mode: Mode = Mode.RDONLY, **kw) -> File:  # noqa: A001
    """``MPI_File_open`` analogue with meaningful defaults."""

    return File(path, FileSpec(mode=mode, **kw))
