"""Automatic datatype generation via aggregate reflection (paper §II, C2).

The paper uses Boost.PFR to introspect aggregate classes at compile time and
derive ``MPI_Datatype``\\ s automatically, so user-defined types can be
communicated without manual ``MPI_Type_create_struct`` calls.  The JAX
analogue introspects Python aggregates (dataclasses, named tuples, dicts,
sequences) with :mod:`dataclasses` reflection, registers them as pytrees on
first use, and derives a :class:`DataType`: the treedef plus a *packed
layout* — leaves grouped by dtype and raveled into one contiguous buffer per
dtype group, so a single collective moves the whole object (the actual point
of derived datatypes: one message, not N).

The ``mpi::compliant`` concept maps onto :func:`is_compliant`:

* arithmetic types (Python ``bool/int/float/complex``, NumPy scalars, any
  real/complex/integer ``jnp`` dtype) are compliant and map to their XLA
  equivalents explicitly;
* enumerations are compliant (communicated as their underlying integers);
* ``std::complex`` ↔ ``complex64/128``;
* C-style arrays / ``std::array`` ↔ fixed-shape ``jax.Array`` / ``np.ndarray``
  of compliant dtype;
* ``std::pair`` / ``std::tuple`` ↔ tuples, and contiguous sequential
  containers ↔ lists;
* aggregates of compliant members (dataclasses, ``NamedTuple``, ``dict`` with
  static keys) are compliant themselves, recursively.
"""

from __future__ import annotations

import dataclasses
import enum
from functools import partial
from typing import Any, Hashable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import errors

# ---------------------------------------------------------------------------
# Compliance (the `mpi::compliant` concept)
# ---------------------------------------------------------------------------

#: Explicit arithmetic-type → dtype mapping (paper: "arithmetic types,
#: enumerations and specializations of std::complex ... are mapped to their
#: MPI equivalents explicitly").
_SCALAR_DTYPES: dict[type, Any] = {
    bool: jnp.bool_,
    int: jnp.int32,
    float: jnp.float32,
    complex: jnp.complex64,
}

_COMPLIANT_KINDS = frozenset("biufc")  # bool, int, uint, float, complex


def _dtype_ok(dtype) -> bool:
    if np.dtype(dtype).kind in _COMPLIANT_KINDS:
        return True
    try:  # extended ml_dtypes floats (bfloat16, fp8, ...) report kind 'V'
        return bool(jnp.issubdtype(dtype, jnp.floating))
    except TypeError:
        return False    # not coercible to a dtype at all


def _leaf_dtype(value: Any) -> Any | None:
    """dtype if ``value`` is a compliant *leaf*, else ``None``."""

    if isinstance(value, enum.Enum):
        return jnp.int32
    t = builtin_type(value)
    if t in _SCALAR_DTYPES:
        return _SCALAR_DTYPES[t]
    if isinstance(value, (np.ndarray, np.generic, jax.Array, jax.ShapeDtypeStruct)):
        return value.dtype if _dtype_ok(value.dtype) else None
    return None


def builtin_type(value: Any) -> type:
    # bool is a subclass of int: test in declaration order.
    for t in (bool, int, float, complex):
        if builtins_isinstance(value, t):
            return t
    return type(value)


def builtins_isinstance(value: Any, t: type) -> bool:
    return isinstance(value, t) and type(value) in (bool, int, float, complex)


def is_compliant(value: Any) -> bool:
    """The ``mpi::compliant`` concept, evaluated on an instance.

    ``None`` is compliant only as an aggregate *member* (a pytree-empty
    subtree, e.g. an absent optional field such as an unquantised cache's
    scale); a bare ``None`` operand is not — accepting it would turn a
    forgotten value into a silent zero-extent no-op.
    """

    if _leaf_dtype(value) is not None:
        return True
    if isinstance(value, (tuple, list)):
        return all(_member_compliant(v) for v in value)
    if isinstance(value, dict):
        return all(isinstance(k, Hashable) for k in value) and all(
            _member_compliant(v) for v in value.values()
        )
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        register_aggregate(type(value))
        return all(
            _member_compliant(getattr(value, f.name))
            for f in dataclasses.fields(value)
        )
    return False


def _member_compliant(value: Any) -> bool:
    return value is None or is_compliant(value)


# ---------------------------------------------------------------------------
# Aggregate reflection → pytree registration (the Boost.PFR analogue)
# ---------------------------------------------------------------------------

_REGISTERED: set[type] = set()


def register_aggregate(cls: type) -> type:
    """Reflect a dataclass and register it as a pytree node (idempotent).

    This is the PFR step: field names/order come from reflection, not from
    user-written (un)flatten boilerplate.  Usable as a decorator::

        @mpx.register_aggregate
        @dataclasses.dataclass
        class Particle: ...
    """

    if cls in _REGISTERED:
        return cls
    errors.check(
        dataclasses.is_dataclass(cls),
        errors.ErrorClass.ERR_TYPE,
        f"{cls!r} is not an aggregate (dataclass) and cannot be reflected",
    )
    names = [f.name for f in dataclasses.fields(cls)]

    def flatten(obj):
        return tuple(getattr(obj, n) for n in names), None

    def flatten_with_keys(obj):
        return (
            tuple((jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in names),
            None,
        )

    def unflatten(_, children):
        obj = object.__new__(cls)
        for n, c in zip(names, children):
            object.__setattr__(obj, n, c)
        return obj

    try:
        jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys, unflatten, flatten)
    except ValueError:
        pass  # registered elsewhere (e.g. by the user) — fine
    _REGISTERED.add(cls)
    return cls


def _ensure_registered(obj: Any) -> None:
    """Walk an aggregate, registering every unregistered dataclass type."""

    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        register_aggregate(type(obj))
        for f in dataclasses.fields(obj):
            _ensure_registered(getattr(obj, f.name))
    elif isinstance(obj, (tuple, list)):
        for v in obj:
            _ensure_registered(v)
    elif isinstance(obj, dict):
        for v in obj.values():
            _ensure_registered(v)


# ---------------------------------------------------------------------------
# DataType: treedef + packed layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _LeafLayout:
    shape: tuple[int, ...]
    dtype: Any
    group: int       # index of the dtype group this leaf packs into
    offset: int      # element offset within the group buffer
    size: int        # number of elements


@dataclasses.dataclass(frozen=True)
class DataType:
    """Derived datatype: how an aggregate maps onto contiguous buffers.

    ``pack`` produces one 1-D buffer per distinct leaf dtype (a *dtype
    group*); ``unpack`` restores the original aggregate, with Python scalars
    and enums coming back as 0-d arrays / ints (documented deviation: XLA
    buffers cannot hold Python objects).
    """

    treedef: Any
    leaves: tuple[_LeafLayout, ...]
    group_dtypes: tuple[Any, ...]
    group_sizes: tuple[int, ...]

    @property
    def extent(self) -> int:
        """Total packed size in bytes (``MPI_Type_get_extent`` analogue)."""

        return int(
            sum(s * np.dtype(d).itemsize for s, d in zip(self.group_sizes, self.group_dtypes))
        )

    def pack(self, obj: Any) -> list[jax.Array]:
        """Aggregate → list of contiguous per-dtype buffers (jit-safe)."""

        leaves = jax.tree_util.tree_leaves(obj)
        errors.check(
            len(leaves) == len(self.leaves),
            errors.ErrorClass.ERR_COUNT,
            f"object has {len(leaves)} leaves, datatype describes {len(self.leaves)}",
        )
        parts: list[list[jax.Array]] = [[] for _ in self.group_dtypes]
        for value, layout in zip(leaves, self.leaves):
            arr = _as_array(value, layout.dtype)
            errors.check(
                tuple(arr.shape) == layout.shape,
                errors.ErrorClass.ERR_TRUNCATE,
                f"leaf shape {arr.shape} does not match datatype {layout.shape}",
            )
            parts[layout.group].append(arr.reshape(-1))
        return [
            jnp.concatenate(p) if len(p) > 1 else p[0]
            for p in parts
        ]

    def unpack(self, buffers: list[jax.Array]) -> Any:
        """Per-dtype buffers → aggregate (jit-safe)."""

        errors.check(
            len(buffers) == len(self.group_dtypes),
            errors.ErrorClass.ERR_COUNT,
            f"expected {len(self.group_dtypes)} buffers, got {len(buffers)}",
        )
        leaves = []
        for layout in self.leaves:
            buf = buffers[layout.group]
            piece = jax.lax.dynamic_slice_in_dim(buf, layout.offset, layout.size)
            leaves.append(piece.reshape(layout.shape).astype(layout.dtype))
        return jax.tree_util.tree_unflatten(self.treedef, leaves)

    def page_bounds(self, num_pages: int) -> list[list[tuple[int, int]]]:
        """Even page split of each packed group buffer: per group, a list of
        ``(offset, length)`` pairs (lengths differ by at most one element).

        This is the paged-transfer layout for RMA windows over aggregates
        (:mod:`repro.core.onesided`): one ``rput`` moves page ``i`` of every
        dtype group, so a large KV cache streams in ``num_pages`` epochs'
        worth of traffic instead of one monolithic message.
        """

        errors.check(
            num_pages >= 1,
            errors.ErrorClass.ERR_COUNT,
            f"page_bounds needs >= 1 page, got {num_pages}",
        )
        return [even_page_bounds(size, num_pages) for size in self.group_sizes]

    def layout_signature(self) -> dict:
        """JSON-able description of the packed layout (group dtypes and
        element counts) — what a :class:`repro.core.io.File` view records in
        the manifest so a reader's ``set_view`` is validated against the
        writer's (the MPI etype/filetype-equivalence rule for collective
        file views)."""

        return {
            "groups": [
                {"dtype": str(np.dtype(d)), "size": int(s)}
                for d, s in zip(self.group_dtypes, self.group_sizes)
            ]
        }

    def shape_dtype_structs(self) -> list[jax.ShapeDtypeStruct]:
        """Stand-ins for the packed buffers (for AOT lowering)."""

        return [
            jax.ShapeDtypeStruct((s,), d)
            for s, d in zip(self.group_sizes, self.group_dtypes)
        ]


def even_page_bounds(size: int, num_pages: int) -> list[tuple[int, int]]:
    """``num_pages`` contiguous ``(offset, length)`` spans covering ``size``
    elements, lengths differing by at most one (later pages may be empty when
    ``size < num_pages``)."""

    base, rem = divmod(int(size), int(num_pages))
    bounds, offset = [], 0
    for p in range(num_pages):
        length = base + (1 if p < rem else 0)
        bounds.append((offset, length))
        offset += length
    return bounds


def _as_array(value: Any, dtype: Any) -> jax.Array:
    if isinstance(value, enum.Enum):
        value = value.value
    return jnp.asarray(value, dtype=dtype)


_DATATYPE_CACHE: dict[Any, DataType] = {}


def datatype_of(obj: Any) -> DataType:
    """Derive (and cache) the :class:`DataType` of an aggregate instance.

    The cache key is the structural signature (treedef + leaf shapes/dtypes),
    so derivation cost is paid once per *type*, mirroring the paper's
    compile-time generation.
    """

    _ensure_registered(obj)
    leaves, treedef = jax.tree_util.tree_flatten(obj)
    sig_parts = []
    layouts_raw = []
    for leaf in leaves:
        dt = _leaf_dtype(leaf)
        if dt is None:
            errors.fail(
                errors.ErrorClass.ERR_TYPE,
                f"leaf of type {type(leaf).__name__} is not mpi-compliant",
            )
        shape = tuple(np.shape(leaf)) if not isinstance(leaf, enum.Enum) else ()
        layouts_raw.append((shape, np.dtype(dt)))
        sig_parts.append((shape, np.dtype(dt).str))
    key = (treedef, tuple(sig_parts))
    cached = _DATATYPE_CACHE.get(key)
    if cached is not None:
        return cached

    group_index: dict[Any, int] = {}
    group_sizes: list[int] = []
    layouts: list[_LeafLayout] = []
    for shape, dtype in layouts_raw:
        g = group_index.setdefault(dtype, len(group_index))
        if g == len(group_sizes):
            group_sizes.append(0)
        size = int(np.prod(shape)) if shape else 1
        layouts.append(_LeafLayout(shape, dtype, g, group_sizes[g], size))
        group_sizes[g] += size

    dt = DataType(
        treedef=treedef,
        leaves=tuple(layouts),
        group_dtypes=tuple(group_index.keys()),
        group_sizes=tuple(group_sizes),
    )
    _DATATYPE_CACHE[key] = dt
    return dt


def pack(obj: Any) -> tuple[list[jax.Array], DataType]:
    """Convenience: derive the datatype and pack in one call."""

    dt = datatype_of(obj)
    return dt.pack(obj), dt


def unpack(buffers: list[jax.Array], dt: DataType) -> Any:
    return dt.unpack(buffers)


# ---------------------------------------------------------------------------
# Communication adapter: apply a buffer-level collective to any aggregate
# ---------------------------------------------------------------------------


def apply_packed(fn, obj: Any):
    """Run ``fn`` (a collective over a single 1-D buffer) on every packed
    buffer of ``obj`` and restore the aggregate.  This is what lets every
    collective in :mod:`repro.core.collectives` accept user-defined types
    (paper Listing 1)."""

    dt = datatype_of(obj)
    buffers = dt.pack(obj)
    out = [fn(b) for b in buffers]
    return dt.unpack(out)


def apply_leafwise(fn, obj: Any):
    """Leaf-wise variant (no packing) — used when the collective must see the
    leaf shapes (e.g. scatter along a leaf axis)."""

    _ensure_registered(obj)
    return jax.tree_util.tree_map(partial(_call_on_leaf, fn), obj)


def _call_on_leaf(fn, leaf):
    dt = _leaf_dtype(leaf)
    if dt is None:
        errors.fail(
            errors.ErrorClass.ERR_TYPE,
            f"leaf of type {type(leaf).__name__} is not mpi-compliant",
        )
    return fn(_as_array(leaf, dt))
