"""Scoped enumerations, description objects and defaults (paper §II, C4).

The paper replaces MPI's loose ``int`` constants with scoped enumerations and
replaces long argument lists with *description objects*.  We mirror both:

* every operation selector is a :class:`enum.Enum` (``ReduceOp``,
  ``Algorithm``, ``ThreadLevel``, ``Mode``, ...) so erroneous values cannot be
  passed and editors can complete them;
* operations with many knobs accept a frozen dataclass descriptor
  (:class:`CollectiveSpec`, :class:`WindowSpec`, :class:`FileSpec`) carrying
  meaningful defaults, instead of positional argument soup.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Any


class ReduceOp(enum.Enum):
    """Scoped analogue of ``MPI_Op`` (MPI 4.0 §6.9.2)."""

    SUM = "sum"
    PROD = "prod"
    MAX = "max"
    MIN = "min"
    LAND = "land"   # logical and
    LOR = "lor"     # logical or
    LXOR = "lxor"
    BAND = "band"   # bitwise and
    BOR = "bor"
    BXOR = "bxor"
    MAXLOC = "maxloc"
    MINLOC = "minloc"
    # RMA-only operators (MPI 4.0 §12.3): REPLACE is valid for accumulate
    # (put-with-ordering semantics), NO_OP for get_accumulate/fetch_and_op
    # (pure fetch).  Collectives reject both with ERR_OP.
    REPLACE = "replace"
    NO_OP = "no_op"


class Algorithm(enum.Enum):
    """Collective algorithm selector.

    ``XLA`` emits the native XLA collective (the compiler schedules it);
    ``RING``/``BIDIRECTIONAL`` emit an explicitly decomposed ``ppermute``
    schedule whose per-step continuations can be fused with compute — the
    trace-level realisation of the paper's future continuations (C3).
    ``HIERARCHICAL`` splits a multi-axis reduction into intra/inter stages
    (reduce-scatter inside, all-reduce across, all-gather inside).
    """

    AUTO = "auto"
    XLA = "xla"
    RING = "ring"
    BIDIRECTIONAL = "bidirectional"
    HIERARCHICAL = "hierarchical"


class ThreadLevel(enum.Enum):
    """Analogue of ``MPI_THREAD_*`` — JAX dispatch is inherently
    ``MULTIPLE``-safe; kept for interface completeness."""

    SINGLE = "single"
    FUNNELED = "funneled"
    SERIALIZED = "serialized"
    MULTIPLE = "multiple"


class Mode(enum.Flag):
    """File access mode flags (``MPI_MODE_*``, MPI 4.0 §14.2.1)."""

    RDONLY = enum.auto()
    WRONLY = enum.auto()
    RDWR = enum.auto()
    CREATE = enum.auto()
    EXCL = enum.auto()
    APPEND = enum.auto()
    DELETE_ON_CLOSE = enum.auto()


class Compression(enum.Enum):
    """Payload compression for wide (cross-pod / DCN) reductions."""

    NONE = "none"
    INT8 = "int8"           # per-block-scaled int8 with error feedback


@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """Description object for collectives (paper: "functions with a large
    number of arguments accept description objects").

    Attributes
    ----------
    op: reduction operator where applicable.
    algorithm: which lowering to use; ``AUTO`` picks ``XLA`` unless a fused
        continuation is attached to the returned future.
    num_chunks: decomposition granularity for ``RING``/``BIDIRECTIONAL``.
    compression: wire compression for reduction payloads (hierarchical DCN
        stage only, applied with error feedback by the caller).
    tiled: ``tiled=True`` concatenates along an existing axis rather than
        stacking a new one (mirrors ``jax.lax`` semantics).
    axis: operand axis the collective concatenates / scatters over.
    """

    op: ReduceOp = ReduceOp.SUM
    algorithm: Algorithm = Algorithm.AUTO
    num_chunks: int | None = None
    compression: Compression = Compression.NONE
    tiled: bool = True
    axis: int = 0


@dataclasses.dataclass(frozen=True)
class WindowSpec:
    """Description object for one-sided windows (``MPI_Win_create``).

    Attributes
    ----------
    accumulate_op: the default operator for ``accumulate`` / ``raccumulate``
        / ``get_accumulate`` when no explicit op is passed (the
        ``accumulate_ops`` info-key analogue).
    no_locks: the ``no_locks`` info key.  Passive-target lock/unlock has no
        SPMD analogue (see the honesty note in :mod:`repro.core.onesided`),
        so only ``no_locks=True`` windows can be created; asking for lock
        support raises ``ERR_UNSUPPORTED_OPERATION`` instead of silently
        pretending.
    fence_barrier: emit an ``optimization_barrier`` at every ``fence`` so
        XLA cannot move operations across the epoch boundary.  Disable only
        when program order already pins the schedule (cheaper epochs).
    num_pages: default page count for paged transfers (``put``/``rput`` with
        ``page=(i, n)``); the paged-KV-block granularity.
    dynamic: ``MPI_Win_create_dynamic`` analogue.  The window starts with
        *no* pages attached; memory must be registered page-by-page with
        :meth:`~repro.core.onesided.Window.attach` before a ``put`` may
        target it (``ERR_RMA_RANGE`` otherwise, the dynamic-window
        out-of-range class).  ``attach``/``detach`` double as the
        sub-allocation free-list a paged KV block pool rides
        (:mod:`repro.runtime.kvpool`).  Dynamic windows are addressed at
        page granularity: full-window puts require every page attached.
    """

    accumulate_op: ReduceOp = ReduceOp.SUM
    no_locks: bool = True
    fence_barrier: bool = True
    num_pages: int = 1
    dynamic: bool = False


@dataclasses.dataclass(frozen=True)
class FileSpec:
    """Description object for parallel IO (``MPI_File_open``).

    Attributes
    ----------
    mode: access mode flags.  ``EXCL`` raises ``ERR_FILE`` when the dataset
        already exists — with or without ``CREATE``, matching
        ``MPI_ERR_FILE_EXISTS`` semantics.
    atomic: manifests are written atomically (tmp + rename).
    checksum: record per-fragment checksums and verify them on read.
    verify: read each fragment back after writing it and compare checksums
        before the write is reported complete (read-back verify — the
        durability check an async checkpoint save runs before committing its
        manifest).
    """

    mode: Mode = Mode.RDONLY
    atomic: bool = True          # manifests are written atomically
    checksum: bool = True
    verify: bool = False


DEFAULT_COLLECTIVE = CollectiveSpec()


def resolve(spec: CollectiveSpec | None, **overrides: Any) -> CollectiveSpec:
    """Meaningful defaults: merge a possibly-``None`` descriptor with keyword
    overrides (the paper's defaulted trailing arguments)."""

    base = spec if spec is not None else DEFAULT_COLLECTIVE
    if overrides:
        base = dataclasses.replace(base, **overrides)
    return base
