"""Wire compression for wide reductions (int8 with per-block scales).

Used by :func:`repro.core.overlap.hierarchical_allreduce` for the cross-pod
(DCN) stage of gradient reductions, with error feedback maintained by the
optimizer (:mod:`repro.optim`).  A Pallas TPU kernel with identical semantics
lives in :mod:`repro.kernels.quant`; this module is the pure-jnp reference
and the CPU execution path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256  # elements per scale block


def _pad_to_block(x: jax.Array, block: int) -> tuple[jax.Array, int]:
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
    return x, pad


def quantize_int8(x: jax.Array, block: int = BLOCK) -> tuple[jax.Array, jax.Array, int]:
    """Flat tensor → (int8 payload, fp32 per-block scales, pad).

    Symmetric per-block quantisation: ``scale = max|x| / 127``.
    """

    flat = x.reshape(-1).astype(jnp.float32)
    flat, pad = _pad_to_block(flat, block)
    blocks = flat.reshape(-1, block)
    absmax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale[:, 0], pad


def dequantize_int8(
    q: jax.Array, scale: jax.Array, pad: int, shape, dtype, block: int = BLOCK
) -> jax.Array:
    blocks = q.reshape(-1, block).astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape).astype(dtype)


def compression_error(x: jax.Array, block: int = BLOCK) -> jax.Array:
    """Residual ``x - dequant(quant(x))`` for error feedback."""

    q, s, pad = quantize_int8(x, block)
    return x - dequantize_int8(q, s, pad, x.shape, x.dtype, block)
