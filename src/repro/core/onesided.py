"""One-sided communication (paper §II, C1 — MPI 4.0 chapter 12, RMA).

A window (``MPI_Win``) exposes each rank's local buffer for remote ``put`` /
``get`` / ``accumulate``.  The SPMD adaptation: a :class:`Window` is the
per-rank value inside an SPMD region; RMA operations with *trace-time static*
target patterns lower to ``collective-permute`` (put/get) and masked
reductions (accumulate).  Epochs (``fence``) map to program-order barriers.

Three MPI 4.0 capabilities beyond the plain put/get subset:

* **Request-based RMA** (``MPI_Rput``/``MPI_Rget``/``MPI_Raccumulate``):
  :meth:`Window.rput` / :meth:`Window.rget` / :meth:`Window.raccumulate`
  return lazy :class:`~repro.core.futures.TraceFuture`\\ s that chain with
  ``then()`` and join with ``when_all`` exactly like nonblocking collectives
  — one-sided traffic rides the same request engine.  :meth:`Window.fence`
  completes any requests not explicitly waited on (in issue order), the
  epoch-close semantics of ``MPI_Win_fence``.

* **Derived-datatype windows**: a window may be created over any
  :func:`repro.core.datatypes.is_compliant` aggregate.  The C2 reflection
  system derives the packed per-dtype layout, the window holds one packed
  buffer per dtype group, and every RMA operation moves the whole aggregate
  (or a *page* of its packed extent via ``page=(i, n)``) in one epoch — a KV
  cache or train-state struct crosses as one logical object.

* **Atomic read-modify-write**: :meth:`Window.get_accumulate`,
  :meth:`Window.fetch_and_op` and :meth:`Window.compare_and_swap`, with the
  full :class:`ReduceOp` set (reusing the collectives lowering) plus the
  RMA-only ``REPLACE`` / ``NO_OP`` operators.

Honesty note (recorded in DESIGN.md): true *passive-target* progress —
one rank mutating another's memory while the target computes — has no
analogue in a statically scheduled SPMD program, which is why
``WindowSpec(no_locks=False)`` is refused rather than faked.  What transfers
is the *active-target* (fence-epoch) subset, which is also the portable
subset MPI codes rely on for correctness.  The disaggregated serving
transport (:mod:`repro.runtime.server`) lives entirely inside that subset:
prefill→decode KV movement is epoch-delimited, not asynchronous intrusion.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.analysis import events as analysis_events
from repro.core import collectives, datatypes, errors, tool
from repro.core.communicator import Communicator
from repro.core.descriptors import ReduceOp, WindowSpec
from repro.core.futures import TraceFuture

#: Operators with no two-operand combine / cross-rank reduction — rejected
#: for accumulate with ERR_OP before any lowering is attempted.
_LOC_OPS = (ReduceOp.MAXLOC, ReduceOp.MINLOC)


class Window:
    """An RMA window over this rank's local array or aggregate (inside
    ``spmd``)."""

    def __init__(self, comm: Communicator, local: Any, spec: WindowSpec | None = None):
        self.comm = comm
        self.spec = spec or WindowSpec()
        errors.check(
            self.spec.no_locks,
            errors.ErrorClass.ERR_UNSUPPORTED_OPERATION,
            "passive-target lock/unlock has no SPMD analogue; windows are "
            "active-target only (no_locks=True)",
        )
        if collectives._is_leaf_operand(local):
            self._datatype = None
            self._buffers = [jnp.asarray(local)]
        else:
            errors.check(
                datatypes.is_compliant(local),
                errors.ErrorClass.ERR_TYPE,
                f"window over a non-compliant aggregate of type "
                f"{type(local).__name__}",
            )
            self._datatype = datatypes.datatype_of(local)
            self._buffers = self._datatype.pack(local)
        self._epoch_open = False
        self._pending: list[TraceFuture] = []
        # monotonically increasing fence-epoch id; with the window token it
        # lets the analyzer prove a put was applied in its issue epoch
        self._epoch_id = 0
        self._win_token = analysis_events.next_token()
        # per-epoch write ledger: target rank -> page specs written (None =
        # the whole window); overlapping writes in one epoch are a data race
        self._writes: dict[int, list[tuple[int, int] | None]] = {}
        # dynamic windows (MPI_Win_create_dynamic): pages start detached and
        # must be registered with attach() before a put may target them; the
        # attached set doubles as the sub-allocation free-list
        self._attached: set[int] | None = set() if self.spec.dynamic else None
        if self.spec.dynamic:
            errors.check(
                self.spec.num_pages >= 1,
                errors.ErrorClass.ERR_COUNT,
                f"a dynamic window needs num_pages >= 1, got {self.spec.num_pages}",
            )

    # -- introspection ------------------------------------------------------

    @property
    def buffer(self) -> Any:
        """The window's local value (the aggregate view for datatype
        windows)."""

        if self._datatype is None:
            return self._buffers[0]
        return self._datatype.unpack(self._buffers)

    @property
    def datatype(self) -> "datatypes.DataType | None":
        """The derived datatype (``None`` for plain-array windows)."""

        return self._datatype

    def extent(self) -> int:
        """Window size in bytes (``MPI_Win_get_attr(MPI_WIN_SIZE)``)."""

        if self._datatype is not None:
            return self._datatype.extent
        b = self._buffers[0]
        return int(b.size) * jnp.dtype(b.dtype).itemsize

    # -- dynamic-window sub-allocation (MPI_Win_attach / MPI_Win_detach) ----

    def _check_dynamic(self, what: str) -> None:
        errors.check(
            self._attached is not None,
            errors.ErrorClass.ERR_RMA_ATTACH,
            f"{what} requires a dynamic window (WindowSpec(dynamic=True))",
        )

    def _check_page_ids(self, pages: Sequence[int]) -> list[int]:
        ids = [int(p) for p in pages]
        for p in ids:
            errors.check(
                0 <= p < self.spec.num_pages,
                errors.ErrorClass.ERR_RMA_RANGE,
                f"page {p} out of range for a window of {self.spec.num_pages} pages",
            )
        return ids

    def attach(self, pages: Sequence[int]) -> "Window":
        """``MPI_Win_attach``: register pages of the packed extent with the
        dynamic window, making them legal ``put`` targets.  Re-attaching an
        attached page is erroneous (``ERR_RMA_ATTACH``, as in the
        standard)."""

        self._check_dynamic("attach")
        ids = self._check_page_ids(pages)
        for p in ids:
            errors.check(
                p not in self._attached,
                errors.ErrorClass.ERR_RMA_ATTACH,
                f"page {p} is already attached",
            )
        self._attached.update(ids)
        tool.pvar_add("rma_attach", len(ids))
        if analysis_events.RECORDING:
            analysis_events.record_rma_pages("rma_attach", self._win_token, len(ids))
        return self

    def detach(self, pages: Sequence[int]) -> "Window":
        """``MPI_Win_detach``: deregister pages; subsequent puts to them
        raise ``ERR_RMA_RANGE``."""

        self._check_dynamic("detach")
        ids = self._check_page_ids(pages)
        for p in ids:
            errors.check(
                p in self._attached,
                errors.ErrorClass.ERR_RMA_ATTACH,
                f"page {p} is not attached",
            )
        self._attached.difference_update(ids)
        tool.pvar_add("rma_detach", len(ids))
        if analysis_events.RECORDING:
            analysis_events.record_rma_pages("rma_detach", self._win_token, len(ids))
        return self

    @property
    def attached_pages(self) -> frozenset[int]:
        """The currently attached page set (empty for static windows)."""

        return frozenset(self._attached or ())

    def free_pages(self) -> int:
        """Number of detached (allocatable) pages of a dynamic window."""

        self._check_dynamic("free_pages")
        return self.spec.num_pages - len(self._attached)

    def page_alloc(self, count: int) -> list[int]:
        """Sub-allocation hook: attach the ``count`` lowest detached pages
        and return their ids — the free-list pop a paged KV block pool rides
        (:mod:`repro.runtime.kvpool`).  ``ERR_NO_MEM`` when the window has
        fewer detached pages than requested."""

        self._check_dynamic("page_alloc")
        free = sorted(set(range(self.spec.num_pages)) - self._attached)
        errors.check(
            count <= len(free),
            errors.ErrorClass.ERR_NO_MEM,
            f"window has {len(free)} free pages, {count} requested",
        )
        ids = free[:count]
        self.attach(ids)
        return ids

    def page_free(self, pages: Sequence[int]) -> "Window":
        """Sub-allocation hook: return pages to the free-list (detach)."""

        return self.detach(pages)

    def _check_attached(self, page: tuple[int, int] | None) -> None:
        """Dynamic windows only accept writes to attached memory, at the
        attach granularity (``spec.num_pages``)."""

        if self._attached is None:
            return
        if page is None:
            errors.check(
                len(self._attached) == self.spec.num_pages,
                errors.ErrorClass.ERR_RMA_RANGE,
                f"full-window put on a dynamic window with only "
                f"{len(self._attached)}/{self.spec.num_pages} pages attached",
            )
            return
        index, num_pages = page
        errors.check(
            num_pages == self.spec.num_pages,
            errors.ErrorClass.ERR_RMA_RANGE,
            f"dynamic windows are addressed at attach granularity: page "
            f"counts must equal spec.num_pages ({self.spec.num_pages}), "
            f"got {num_pages}",
        )
        errors.check(
            index in self._attached,
            errors.ErrorClass.ERR_RMA_RANGE,
            f"page {index} is not attached (attached: "
            f"{sorted(self._attached)})",
        )

    # -- epochs -------------------------------------------------------------

    def fence(self) -> "Window":
        """Open/close an access epoch (``MPI_Win_fence``).

        Closing completes outstanding request-based operations in issue
        order (requests chained through ``then()`` drain recursively: a
        continuation that issues another RMA op extends the queue).
        """

        tool.pvar_count("rma_fence")
        while self._pending:
            self._pending.pop(0).get()
        if self.spec.fence_barrier:
            self._buffers = list(lax.optimization_barrier(tuple(self._buffers)))
        self._epoch_open = not self._epoch_open
        self._writes = {}
        self._epoch_id += 1
        if analysis_events.RECORDING:
            analysis_events.record_fence(self._win_token, self._epoch_id)
        return self

    def _check_epoch(self):
        errors.check(
            self._epoch_open,
            errors.ErrorClass.ERR_WIN,
            "RMA access outside a fence epoch; call win.fence() first",
        )

    # -- validation ---------------------------------------------------------

    def _validate_perm(self, perm: Sequence[tuple[int, int]], *, writes: bool) -> None:
        n = self.comm.size()
        for s, d in perm:
            errors.check(
                0 <= s < n and 0 <= d < n,
                errors.ErrorClass.ERR_RANK,
                f"RMA pair ({s}, {d}) out of range for window over {n} ranks",
            )
        if writes:
            # mirrors send_recv's duplicate-source check: two origins writing
            # one target in the same epoch is a data race, never
            # last-writer-wins
            targets = [d for _, d in perm]
            errors.check(
                len(set(targets)) == len(targets),
                errors.ErrorClass.ERR_RANK,
                f"duplicate put targets in {list(perm)}: a window location "
                "may be written by at most one origin per epoch",
            )

    def _pages_overlap(
        self,
        a: tuple[int, int] | None,
        b: tuple[int, int] | None,
    ) -> bool:
        """Do two page specs cover a common span of the packed extent?"""

        if a is None or b is None:
            return True            # a full-window put covers every page
        (ia, na), (ib, nb) = a, b
        if na == nb:
            return ia == ib
        for ga, gb in zip(self._page_bounds(na), self._page_bounds(nb)):
            sa, la = ga[ia]
            sb, lb = gb[ib]
            if la and lb and sa < sb + lb and sb < sa + la:
                return True
        return False

    def _note_writes(
        self, perm: Sequence[tuple[int, int]], page: tuple[int, int] | None
    ) -> None:
        """Record this epoch's put targets; overlapping spans are the same
        data race the per-call duplicate check rejects, across calls."""

        for target in {d for _, d in perm}:
            for prior in self._writes.get(target, []):
                errors.check(
                    not self._pages_overlap(prior, page),
                    errors.ErrorClass.ERR_RANK,
                    f"target {target} already written this epoch "
                    f"(prior {prior}, new {page}): a window location may be "
                    "written by at most one origin per epoch",
                )
            self._writes.setdefault(target, []).append(page)

    def _check_target(self, target: int) -> None:
        errors.check(
            0 <= int(target) < self.comm.size(),
            errors.ErrorClass.ERR_RANK,
            f"target {target} out of range for window over {self.comm.size()} ranks",
        )

    def _pack_value(self, value: Any) -> list[jax.Array]:
        """An origin-side value, packed to match the window layout."""

        if self._datatype is None:
            v = jnp.asarray(value, self._buffers[0].dtype)
            errors.check(
                tuple(v.shape) == tuple(self._buffers[0].shape),
                errors.ErrorClass.ERR_TRUNCATE,
                f"value shape {v.shape} does not match window shape "
                f"{self._buffers[0].shape}",
            )
            return [v]
        bufs = self._datatype.pack(value)
        return [b.astype(w.dtype) for b, w in zip(bufs, self._buffers)]

    def _is_target(self, perm: Sequence[tuple[int, int]]) -> jax.Array:
        """Scalar boolean: does this rank's window receive under ``perm``?
        (One shape for the empty and non-empty cases.)"""

        targets = sorted({d for _, d in perm})
        if not targets:
            return jnp.zeros((), jnp.bool_)
        return jnp.any(jnp.asarray(targets, jnp.int32) == self.comm.rank())

    def _page_bounds(self, num_pages: int) -> list[list[tuple[int, int]]]:
        if self._datatype is not None:
            return self._datatype.page_bounds(num_pages)
        b = self._buffers[0]
        errors.check(
            b.ndim >= 1 or num_pages == 1,
            errors.ErrorClass.ERR_COUNT,
            "paged transfer needs a window with a leading axis",
        )
        size = b.shape[0] if b.ndim >= 1 else 1
        return [datatypes.even_page_bounds(size, num_pages)]

    # -- put / get ----------------------------------------------------------

    def _apply_put(
        self,
        value: Any,
        perm: Sequence[tuple[int, int]],
        page: tuple[int, int] | None,
    ) -> Any:
        vals = self._pack_value(value)
        is_target = self._is_target(perm)
        new_buffers = []
        if page is None:
            for v, b in zip(vals, self._buffers):
                moved = collectives.send_recv(self.comm, v, perm)
                new_buffers.append(jnp.where(is_target, moved, b))
        else:
            index, num_pages = page     # validated by _resolve_page at issue
            bounds = self._page_bounds(num_pages)
            for v, b, bd in zip(vals, self._buffers, bounds):
                start, length = bd[index]
                if length == 0:
                    new_buffers.append(b)
                    continue
                piece = lax.slice_in_dim(v, start, start + length, axis=0)
                moved = collectives.send_recv(self.comm, piece, perm)
                merged = lax.dynamic_update_slice_in_dim(b, moved, start, axis=0)
                new_buffers.append(jnp.where(is_target, merged, b))
        self._buffers = new_buffers
        return self.buffer

    def _resolve_page(
        self, page: int | tuple[int, int] | None
    ) -> tuple[int, int] | None:
        # a bare index is a page of the spec's configured count; validation
        # happens here — at issue time — so rput errors before any tracing
        # and before the write ledger indexes the bounds
        if isinstance(page, int):
            page = (page, self.spec.num_pages)
        if page is not None:
            index, num_pages = page
            errors.check(
                num_pages >= 1 and 0 <= index < num_pages,
                errors.ErrorClass.ERR_COUNT,
                f"page {index} out of range for {num_pages} pages",
            )
        return page

    def put(
        self,
        value: Any,
        perm: Sequence[tuple[int, int]],
        *,
        page: int | tuple[int, int] | None = None,
    ) -> "Window":
        """``MPI_Put``: origin ``s`` overwrites target ``d``'s window, for the
        static pattern ``perm``.  Ranks not targeted keep their buffer.
        ``page=(i, n)`` moves only page ``i`` of ``n`` over the window's
        packed extent (leading axis for plain arrays); a bare ``page=i``
        divides by ``spec.num_pages``."""

        self._check_epoch()
        self._validate_perm(perm, writes=True)
        page = self._resolve_page(page)
        self._check_attached(page)
        self._note_writes(perm, page)
        tool.pvar_count("rma_put")
        if analysis_events.RECORDING:
            analysis_events.record_rma_put(
                self._win_token, self._epoch_id,
                (d for _, d in perm), page, requested=False)
            analysis_events.record_rma_apply(
                self._win_token, self._epoch_id, self._epoch_id)
        self._apply_put(value, perm, page)
        return self

    def rput(
        self,
        value: Any,
        perm: Sequence[tuple[int, int]],
        *,
        page: int | tuple[int, int] | None = None,
    ) -> TraceFuture:
        """``MPI_Rput``: request-based put.  Validation happens at issue
        time; the transfer is traced when the returned future is forced
        (``get()``/``then()`` chain or the closing :meth:`fence`)."""

        self._check_epoch()
        self._validate_perm(perm, writes=True)
        page = self._resolve_page(page)
        self._check_attached(page)
        self._note_writes(perm, page)
        tool.pvar_count("rma_rput")
        if analysis_events.RECORDING:
            issue_epoch = self._epoch_id
            analysis_events.record_rma_put(
                self._win_token, issue_epoch,
                (d for _, d in perm), page, requested=True)

            def _thunk():
                # the apply records the epoch it actually runs in — a then()
                # continuation forced after the closing fence shows up as a
                # cross-epoch put in the ledger
                analysis_events.record_rma_apply(
                    self._win_token, issue_epoch, self._epoch_id)
                return self._apply_put(value, perm, page)

            fut = TraceFuture(_thunk, label="rput")
        else:
            fut = TraceFuture(lambda: self._apply_put(value, perm, page))
        self._pending.append(fut)
        return fut

    def get(self, perm: Sequence[tuple[int, int]]) -> Any:
        """``MPI_Get``: origin ``d`` reads target ``s``'s window for each
        ``(s, d)`` — i.e. the *reverse* data flow of ``put``.  Ranks not
        reading receive zeros (the SPMD convention)."""

        self._check_epoch()
        self._validate_perm(perm, writes=False)
        tool.pvar_count("rma_get")
        out = [collectives.send_recv(self.comm, b, perm) for b in self._buffers]
        if self._datatype is None:
            return out[0]
        return self._datatype.unpack(out)

    def rget(self, perm: Sequence[tuple[int, int]]) -> TraceFuture:
        """``MPI_Rget``: request-based get; the future's value is the fetched
        array/aggregate."""

        self._check_epoch()
        self._validate_perm(perm, writes=False)
        tool.pvar_count("rma_rget")
        fut = TraceFuture(lambda: self.get(perm))
        self._pending.append(fut)
        return fut

    # -- accumulate family --------------------------------------------------

    def _resolve_op(self, op: ReduceOp | None, *, fetch: bool) -> ReduceOp:
        op = self.spec.accumulate_op if op is None else op
        errors.check(
            op not in _LOC_OPS,
            errors.ErrorClass.ERR_OP,
            f"accumulate does not support {op} (no two-operand combine)",
        )
        errors.check(
            fetch or op is not ReduceOp.NO_OP,
            errors.ErrorClass.ERR_OP,
            "NO_OP is only valid for get_accumulate / fetch_and_op",
        )
        return op

    def _apply_accumulate(self, value: Any, target: int, op: ReduceOp) -> Any:
        """Reduce every origin's contribution into the target's window."""

        if op is ReduceOp.NO_OP:
            return self.buffer
        vals = self._pack_value(value)
        rank = self.comm.rank()
        new_buffers = []
        for v, b in zip(vals, self._buffers):
            if op is ReduceOp.REPLACE:
                # MPI leaves the multi-origin order undefined; the SPMD
                # serialization is deterministic: the lowest-ranked origin's
                # contribution is the one deposited (it must still CROSS
                # ranks — the target's own copy would mean no data movement)
                new = collectives.broadcast(self.comm, v, root=0)
            else:
                total = collectives._reduce_array(v, self.comm.axis_names, op)
                new = collectives.combine(op, b, total)
            new_buffers.append(jnp.where(rank == target, new.astype(b.dtype), b))
        self._buffers = new_buffers
        return self.buffer

    def accumulate(
        self,
        value: Any,
        target: int,
        op: ReduceOp | None = None,
    ) -> "Window":
        """``MPI_Accumulate``: every origin's contribution reduces into the
        target's window (here: all ranks contribute; pass the op's identity
        to opt out — the SPMD convention for a static program).  ``op``
        defaults to ``spec.accumulate_op``; the full :class:`ReduceOp` set
        lowers through the collectives reduction kernels.  The RMA-only
        ``REPLACE`` (put semantics) deposits the **lowest-ranked** origin's
        contribution — MPI leaves the multi-origin order undefined, the SPMD
        serialization pins it."""

        self._check_epoch()
        self._check_target(target)
        tool.pvar_count("rma_accumulate")
        self._apply_accumulate(value, target, self._resolve_op(op, fetch=False))
        return self

    def raccumulate(
        self,
        value: Any,
        target: int,
        op: ReduceOp | None = None,
    ) -> TraceFuture:
        """``MPI_Raccumulate``: request-based accumulate."""

        self._check_epoch()
        self._check_target(target)
        op = self._resolve_op(op, fetch=False)
        tool.pvar_count("rma_accumulate")
        fut = TraceFuture(lambda: self._apply_accumulate(value, target, op))
        self._pending.append(fut)
        return fut

    def get_accumulate(
        self,
        value: Any,
        target: int,
        op: ReduceOp | None = None,
    ) -> Any:
        """``MPI_Get_accumulate``: atomically fetch the target's *prior*
        window value (delivered to every origin) and reduce the contributions
        in.  ``op=NO_OP`` is a pure fetch."""

        self._check_epoch()
        self._check_target(target)
        op = self._resolve_op(op, fetch=True)
        old = [collectives.broadcast(self.comm, b, root=target) for b in self._buffers]
        self._apply_accumulate(value, target, op)
        if self._datatype is None:
            return old[0]
        return self._datatype.unpack(old)

    def fetch_and_op(
        self,
        value: Any,
        target: int,
        op: ReduceOp | None = None,
        *,
        index: int = 0,
    ) -> jax.Array:
        """``MPI_Fetch_and_op``: the single-element ``get_accumulate`` —
        fetch element ``index`` of the target's window (flattened), combine
        ``value`` in.  Plain-array windows only (MPI restricts this call to
        one predefined-datatype element)."""

        self._check_epoch()
        self._check_target(target)
        op = self._resolve_op(op, fetch=True)
        errors.check(
            self._datatype is None,
            errors.ErrorClass.ERR_TYPE,
            "fetch_and_op operates on a plain-array window (one element)",
        )
        buf = self._buffers[0]
        flat = buf.reshape(-1)
        errors.check(
            0 <= index < flat.shape[0],
            errors.ErrorClass.ERR_COUNT,
            f"element index {index} out of range for window of {flat.shape[0]}",
        )
        cur = lax.dynamic_slice(flat, (index,), (1,))
        old = collectives.broadcast(self.comm, cur, root=target)
        if op is not ReduceOp.NO_OP:
            v = jnp.asarray(value, buf.dtype).reshape(())
            if op is ReduceOp.REPLACE:
                # lowest-ranked origin's value, as in _apply_accumulate
                new = collectives.broadcast(self.comm, v.reshape(1), root=0)
            else:
                total = collectives._reduce_array(v, self.comm.axis_names, op)
                new = collectives.combine(op, cur, total.reshape(1))
            updated = lax.dynamic_update_slice(flat, new.astype(buf.dtype), (index,))
            merged = jnp.where(self.comm.rank() == target, updated, flat)
            self._buffers = [merged.reshape(buf.shape)]
        return old.reshape(())

    def compare_and_swap(
        self,
        compare: Any,
        value: Any,
        target: int,
        *,
        index: int = 0,
    ) -> jax.Array:
        """``MPI_Compare_and_swap``: fetch element ``index`` of the target's
        window; iff it equals ``compare``, replace it with ``value``.
        Returns the fetched (prior) element on every origin."""

        self._check_epoch()
        self._check_target(target)
        errors.check(
            self._datatype is None,
            errors.ErrorClass.ERR_TYPE,
            "compare_and_swap operates on a plain-array window (one element)",
        )
        buf = self._buffers[0]
        flat = buf.reshape(-1)
        errors.check(
            0 <= index < flat.shape[0],
            errors.ErrorClass.ERR_COUNT,
            f"element index {index} out of range for window of {flat.shape[0]}",
        )
        cur = lax.dynamic_slice(flat, (index,), (1,))
        old = collectives.broadcast(self.comm, cur, root=target)
        c = jnp.asarray(compare, buf.dtype).reshape(1)
        v = jnp.asarray(value, buf.dtype).reshape(1)
        swapped = jnp.where(cur == c, v, cur)
        updated = lax.dynamic_update_slice(flat, swapped, (index,))
        merged = jnp.where(self.comm.rank() == target, updated, flat)
        self._buffers = [merged.reshape(buf.shape)]
        return old.reshape(())


def create_window(comm: Communicator, local: Any, spec: WindowSpec | None = None):
    """``MPI_Win_create`` analogue (arrays and compliant aggregates)."""

    return Window(comm, local, spec)
