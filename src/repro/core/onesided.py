"""One-sided communication (paper §II, C1 — MPI 4.0 chapter 12, RMA).

A window (``MPI_Win``) exposes each rank's local buffer for remote ``put`` /
``get`` / ``accumulate``.  The SPMD adaptation: a :class:`Window` is the
per-rank array inside an SPMD region; RMA operations with *trace-time static*
target patterns lower to ``collective-permute`` (put/get) and masked ``psum``
(accumulate).  Epochs (``fence``) map to program-order barriers.

Honesty note (recorded in DESIGN.md): true *passive-target* progress —
one rank mutating another's memory while the target computes — has no
analogue in a statically scheduled SPMD program.  What transfers is the
*active-target* (fence-epoch) subset, which is also the portable subset MPI
codes rely on for correctness.
"""

from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import collectives, errors
from repro.core.communicator import Communicator
from repro.core.descriptors import ReduceOp, WindowSpec


class Window:
    """An RMA window over this rank's local array (inside ``spmd``)."""

    def __init__(self, comm: Communicator, local: jax.Array, spec: WindowSpec | None = None):
        self.comm = comm
        self.spec = spec or WindowSpec()
        self._buffer = jnp.asarray(local)
        self._epoch_open = False

    @property
    def buffer(self) -> jax.Array:
        return self._buffer

    def fence(self) -> "Window":
        """Open/close an access epoch (``MPI_Win_fence``)."""

        self._buffer = lax.optimization_barrier(self._buffer)
        self._epoch_open = not self._epoch_open
        return self

    def _check_epoch(self):
        errors.check(
            self._epoch_open,
            errors.ErrorClass.ERR_WIN,
            "RMA access outside a fence epoch; call win.fence() first",
        )

    def put(self, value: jax.Array, perm: Sequence[tuple[int, int]]) -> "Window":
        """``MPI_Put``: origin ``s`` overwrites target ``d``'s window, for the
        static pattern ``perm``.  Ranks not targeted keep their buffer."""

        self._check_epoch()
        n = self.comm.size()
        moved = collectives.send_recv(self.comm, jnp.asarray(value, self._buffer.dtype), perm)
        targets = {d for _, d in perm}
        rank = self.comm.rank()
        is_target = jnp.zeros((n,), jnp.bool_).at[jnp.array(sorted(targets), jnp.int32)].set(
            True
        )[rank] if targets else jnp.zeros((), jnp.bool_)
        self._buffer = jnp.where(is_target, moved, self._buffer)
        return self

    def get(self, perm: Sequence[tuple[int, int]]) -> jax.Array:
        """``MPI_Get``: origin ``d`` reads target ``s``'s window for each
        ``(s, d)`` — i.e. the *reverse* data flow of ``put``."""

        self._check_epoch()
        return collectives.send_recv(self.comm, self._buffer, perm)

    def accumulate(
        self,
        value: jax.Array,
        target: int,
        op: ReduceOp = ReduceOp.SUM,
    ) -> "Window":
        """``MPI_Accumulate``: every origin's contribution reduces into the
        target's window (here: all ranks contribute; pass zeros to opt out —
        the SPMD convention for a static program)."""

        self._check_epoch()
        errors.check(
            op is ReduceOp.SUM,
            errors.ErrorClass.ERR_OP,
            "accumulate supports SUM (psum lowering)",
        )
        total = lax.psum(jnp.asarray(value, self._buffer.dtype), self.comm.axis_names)
        rank = self.comm.rank()
        self._buffer = jnp.where(rank == target, self._buffer + total, self._buffer)
        return self


def create_window(comm: Communicator, local: jax.Array, spec: WindowSpec | None = None):
    """``MPI_Win_create`` analogue."""

    return Window(comm, local, spec)
