"""Requests as futures with continuations (paper §II, C3 — Listing 2).

Two layers, mirroring how MPI requests exist both in host code and inside the
parallel program:

* :class:`Future` — **host level**.  JAX dispatch is asynchronous: a jitted
  SPMD program returns immediately with unmaterialised arrays, exactly like
  an ``MPI_I*`` call returns a request.  ``get()`` = ``MPI_Wait`` =
  ``block_until_ready``; ``test()`` = ``MPI_Test``; :func:`when_all` /
  :func:`when_any` = ``MPI_Waitall`` / ``MPI_Waitany``; ``then()`` chains a
  continuation (the continuation may dispatch more work — the chain builds a
  dataflow task graph exactly as in Listing 2).

* :class:`TraceFuture` — **trace level** (inside ``comm.spmd`` regions).  An
  ``immediate_*`` collective returns a lazily-forced future; ``then()``
  chains continuations *into the traced program*, and decomposed collectives
  (:mod:`repro.core.overlap`) override forcing so a continuation can be fused
  chunk-wise with the communication schedule — the TPU-native meaning of
  "overlap nonblocking communication with computation".

* :class:`PersistentRequest` — persistent operations (``MPI_Send_init`` /
  ``MPI_Start``): the argument/plan setup is amortised by AOT lowering and
  compilation; ``start()`` re-fires the compiled executable.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Sequence

import jax

from repro.core import errors


def _is_ready(tree: Any) -> bool:
    for leaf in jax.tree_util.tree_leaves(tree):
        probe = getattr(leaf, "is_ready", None)
        if callable(probe) and not probe():
            return False
    return True


class Future:
    """Host-level future over dispatched (asynchronous) results."""

    def __init__(self, value: Any):
        self._value = value
        self._valid = True

    def valid(self) -> bool:
        return self._valid

    def get(self) -> Any:
        """``MPI_Wait`` + value retrieval (consumes the future)."""

        errors.check(self._valid, errors.ErrorClass.ERR_REQUEST, "future already consumed")
        self._valid = False
        jax.block_until_ready(self._value)
        return self._value

    def wait(self) -> "Future":
        """Block until complete (does not consume; ``get()`` does)."""

        errors.check(self._valid, errors.ErrorClass.ERR_REQUEST, "future already consumed")
        jax.block_until_ready(self._value)
        return self

    def test(self) -> bool:
        """Non-blocking completion probe (``MPI_Test``)."""

        return _is_ready(self._value)

    def then(self, fn: Callable[["Future"], Any]) -> "Future":
        """Chain a continuation.  ``fn`` receives *this* future (paper
        Listing 2) and returns a value or another future; dispatch remains
        asynchronous throughout."""

        result = fn(self)
        if isinstance(result, Future):
            return result
        return Future(result)


def when_all(futures: Sequence[Future]) -> Future:
    """``MPI_Waitall`` join: a future over the tuple of results.

    Like ``MPI_Waitall``, the joined requests are consumed: each input must
    still be valid (``ERR_REQUEST`` otherwise, exactly as a double ``get()``
    would raise) and is invalidated by the join.
    """

    seen: set[int] = set()
    for i, f in enumerate(futures):
        errors.check(
            f.valid() and id(f) not in seen,
            errors.ErrorClass.ERR_REQUEST,
            f"when_all: future {i} already consumed",
        )
        seen.add(id(f))
    values = tuple(f._value for f in futures)
    for f in futures:
        f._valid = False
    return Future(values)


def when_any(futures: Sequence[Future], poll_interval_s: float = 1e-4) -> tuple[Future, int]:
    """``MPI_Waitany`` join: first completed future and its index.

    Inputs must be valid (unconsumed); the winner is returned still valid so
    the caller retrieves its value with ``get()``.
    """

    errors.check(len(futures) > 0, errors.ErrorClass.ERR_REQUEST, "when_any of no futures")
    for i, f in enumerate(futures):
        errors.check(
            f.valid(),
            errors.ErrorClass.ERR_REQUEST,
            f"when_any: future {i} already consumed",
        )
    while True:
        for i, f in enumerate(futures):
            if f.test():
                return f, i
        time.sleep(poll_interval_s)


class TraceFuture:
    """Trace-level future: a lazily forced value inside an SPMD region."""

    def __init__(self, thunk: Callable[[], Any] | None = None, value: Any = None):
        self._thunk = thunk
        self._value = value
        self._forced = thunk is None

    @classmethod
    def ready(cls, value: Any) -> "TraceFuture":
        return cls(thunk=None, value=value)

    def valid(self) -> bool:
        return True

    def get(self) -> Any:
        """Force the communication into the trace and return its value."""

        if not self._forced:
            self._value = self._thunk()
            self._thunk = None
            self._forced = True
        return self._value

    def test(self) -> bool:
        return self._forced

    def then(self, fn: Callable[["TraceFuture"], Any]) -> "TraceFuture":
        """Sequential-asynchronous chaining (Listing 2).  Lazy: nothing is
        traced until the chain end is forced, letting decomposed collectives
        fuse continuations."""

        def thunk():
            result = fn(self)
            if isinstance(result, TraceFuture):
                return result.get()
            return result

        return TraceFuture(thunk)


def trace_when_all(futures: Sequence[TraceFuture]) -> TraceFuture:
    """``MPI_Waitall`` at trace level: forces all, yields the tuple."""

    return TraceFuture(lambda: tuple(f.get() for f in futures))


def trace_when_any(futures: Sequence[TraceFuture]) -> tuple[TraceFuture, int]:
    """``MPI_Waitany`` at trace level.  XLA programs are statically
    scheduled, so "whichever completes first" is not observable; the
    documented SPMD semantics is deterministic selection of the first
    pending future (their side effects all occur at their forcing points)."""

    errors.check(len(futures) > 0, errors.ErrorClass.ERR_REQUEST, "when_any of no futures")
    for i, f in enumerate(futures):
        if not f.test():
            return f, i
    return futures[0], 0


class PersistentRequest:
    """Persistent operation: AOT-compiled executable + ``start()``.

    ``MPI_Send_init`` fixes the argument list so repeated ``MPI_Start`` calls
    skip setup; the XLA analogue fixes shapes/shardings so repeated calls
    skip tracing, lowering and compilation.
    """

    def __init__(self, jitted: Any, example_args: tuple, example_kwargs: dict | None = None):
        self._lowered = jitted.lower(*example_args, **(example_kwargs or {}))
        self._compiled = self._lowered.compile()

    @property
    def compiled(self):
        return self._compiled

    def start(self, *args: Any) -> Future:
        """Fire the persistent operation; returns a host future."""

        return Future(self._compiled(*args))

    def cost_analysis(self):
        return self._compiled.cost_analysis()

    def as_text(self) -> str:
        return self._compiled.as_text()
