"""The request subsystem (paper §II, C3 — Listing 2; MPI 4.0 persistent and
partitioned operations).

Requests exist in three forms, mirroring how MPI operations exist both in
host code and inside the parallel program, and how MPI 4.0 extends them:

* :class:`Future` — **host level**.  JAX dispatch is asynchronous: a jitted
  SPMD program returns immediately with unmaterialised arrays, exactly like
  an ``MPI_I*`` call returns a request.  ``get()`` = ``MPI_Wait`` =
  ``block_until_ready``; ``test()`` = ``MPI_Test``; :func:`when_all` /
  :func:`when_any` = ``MPI_Waitall`` / ``MPI_Waitany``; ``then()`` chains a
  continuation (the continuation may dispatch more work — the chain builds a
  dataflow task graph exactly as in Listing 2).  Like ``MPI_Wait``, both
  ``get()`` *and* ``then()`` consume the request: a chained-then-read double
  use raises ``ERR_REQUEST``, consistent with :func:`when_all`.

* :class:`TraceFuture` — **trace level** (inside ``comm.spmd`` regions).  An
  ``immediate_*`` collective returns a lazily-forced future; ``then()``
  chains continuations *into the traced program*, and decomposed collectives
  (:mod:`repro.core.overlap`) override forcing so a continuation can be fused
  chunk-wise with the communication schedule — the TPU-native meaning of
  "overlap nonblocking communication with computation".

* :class:`DeferredFuture` — **host level, off the dispatch path**.  Some
  completions are not XLA values: background file I/O
  (:class:`repro.core.io.IORequest`), joins over such requests.  A deferred
  future resolves at wait time, ``then()`` chains lazily (the continuation
  runs when the chain is waited), and resolver errors propagate through
  ``get()``/``wait()`` — the error-forwarding thin wrappers lose.

* :class:`PersistentRequest` — persistent operations (``MPI_Send_init`` /
  ``MPI_Allreduce_init`` + ``MPI_Start``): the argument/plan setup is
  amortised by AOT lowering and compilation; ``start()`` re-fires the
  compiled executable with **zero re-tracing**.  The fixed argument list is
  enforced: starting with mismatched shapes, dtypes, tree structure or
  shardings raises ``ERR_REQUEST``.  Buffer donation (``donate_argnums``)
  aliases inputs into outputs; ``warm_start`` prefetches the executable with
  throwaway inputs so the first real ``start()`` pays no allocator cost;
  ``then()`` registers continuations applied to every start's host future.

* :class:`PartitionedRequest` — partitioned communication
  (``MPI_Psend_init`` / ``MPI_Pready``): one logical operation over a pytree
  is split into K partitions, each marked ready independently with
  :meth:`~PartitionedRequest.pready` and forced as a lazy
  :class:`TraceFuture` — so communication for ready partitions interleaves
  with the compute producing later ones.  Results are independent of the
  ``pready`` order; :meth:`~PartitionedRequest.wait` completes the operation.

:class:`PersistentCollective` combines the two MPI 4.0 additions with the C2
datatype layer: ``comm.allreduce_init(example)`` AOT-lowers **one collective
per dtype bucket** of the example aggregate, and every ``start()`` re-fires
the compiled executables on a new aggregate of the same datatype.

Neighborhood collectives (chapter 8, :mod:`repro.core.topology`) ride the
same engine: ``neighbor_allgather``/``neighbor_alltoall(v)`` return
:class:`TraceFuture`\\ s whose forcing points place the sparse exchanges in
the trace, and ``neighbor_alltoall_init`` reuses
:class:`PersistentCollective` for the ``MPI_Neighbor_alltoall_init`` form.

Request-based RMA (``MPI_Rput``/``MPI_Rget``/``MPI_Raccumulate``, chapter
12) rides the same engine: :class:`repro.core.onesided.Window` returns
:class:`TraceFuture`\\ s from ``rput``/``rget``/``raccumulate``, so one-sided
traffic chains with ``then()`` and joins with :func:`when_all` exactly like
nonblocking collectives; ``fence`` completes any outstanding RMA requests
(``MPI_Win_fence`` closes the epoch on unwaited requests).
"""

from __future__ import annotations

import time
import weakref
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.analysis import events as analysis_events
from repro.core import errors


def _is_ready(tree: Any) -> bool:
    for leaf in jax.tree_util.tree_leaves(tree):
        probe = getattr(leaf, "is_ready", None)
        if callable(probe) and not probe():
            return False
    return True


class Future:
    """Host-level future over dispatched (asynchronous) results."""

    def __init__(self, value: Any):
        self._value = value
        self._valid = True

    def valid(self) -> bool:
        return self._valid

    def get(self) -> Any:
        """``MPI_Wait`` + value retrieval (consumes the future)."""

        errors.check(self._valid, errors.ErrorClass.ERR_REQUEST, "future already consumed")
        self._valid = False
        return self._wait_value()

    def _wait_value(self) -> Any:
        """Block until the value is materialised and return it (no validity
        bookkeeping — ``get``/``wait`` own that)."""

        jax.block_until_ready(self._value)
        return self._value

    def wait(self) -> "Future":
        """Block until complete (does not consume; ``get()`` does)."""

        errors.check(self._valid, errors.ErrorClass.ERR_REQUEST, "future already consumed")
        self._wait_value()
        return self

    def test(self) -> bool:
        """Non-blocking completion probe (``MPI_Test``)."""

        return _is_ready(self._value)

    def then(self, fn: Callable[["Future"], Any]) -> "Future":
        """Chain a continuation.  ``fn`` receives *this* future (paper
        Listing 2) and returns a value or another future; dispatch remains
        asynchronous throughout.

        Chaining **consumes** the parent (``ERR_REQUEST`` on reuse): the
        continuation owns the request now, exactly as :func:`when_all`
        invalidates its joined inputs.
        """

        errors.check(
            self._valid, errors.ErrorClass.ERR_REQUEST, "then() on a consumed future"
        )
        result = fn(self)
        self._valid = False
        if result is self:
            # pass-through continuation: hand the value on in a fresh request
            return Future(self._value)
        if isinstance(result, Future):
            return result
        return Future(result)


class DeferredFuture(Future):
    """Host future whose value is produced by a *resolver* at completion
    time — the host-level request behind operations that finish off the XLA
    dispatch path (background file I/O, joins over such requests).

    ``get()``/``wait()`` run the resolver exactly once; an error raised
    there (e.g. ``ERR_IO`` from a failed background write) propagates to the
    caller — a failed operation can never read as success.  ``test()`` uses
    the optional ``probe`` (e.g. a thread-completion event); without one it
    reports completion only after resolution, like :class:`TraceFuture`.

    ``then()`` on a deferred request is itself deferred: the continuation
    runs when the *chained* request is waited, not at chain time, so a chain
    built over in-flight I/O does not block the issuing thread (the host
    analogue of :meth:`TraceFuture.then`).
    """

    def __init__(self, resolver: Callable[[], Any], probe: Callable[[], bool] | None = None):
        super().__init__(None)
        self._resolver = resolver
        self._probe = probe
        self._resolved = False

    def _wait_value(self) -> Any:
        if not self._resolved:
            self._value = self._resolver()
            self._resolved = True
        jax.block_until_ready(self._value)
        return self._value

    def test(self) -> bool:
        if self._resolved:
            return True
        if self._probe is not None:
            return bool(self._probe())
        return False

    def then(self, fn: Callable[["Future"], Any]) -> "DeferredFuture":
        errors.check(
            self._valid, errors.ErrorClass.ERR_REQUEST, "then() on a consumed future"
        )
        self._valid = False
        parent = self

        def resolver():
            # the chain owns the parent request now: re-validate it for the
            # continuation's own get()/wait(), exactly as the eager form
            # hands fn a still-valid future
            parent._valid = True
            try:
                result = fn(parent)
            finally:
                parent._valid = False
            if result is parent:
                return parent._wait_value()
            if isinstance(result, Future):
                return result._wait_value()
            return result

        # no probe: the continuation only runs at wait, so completion is not
        # observable earlier (same semantics as TraceFuture.then)
        return DeferredFuture(resolver)


def when_all(futures: Sequence[Future]) -> "Future | TraceFuture":
    """``MPI_Waitall`` join: a future over the tuple of results.

    Like ``MPI_Waitall``, the joined requests are consumed: each input must
    still be valid (``ERR_REQUEST`` otherwise, exactly as a double ``get()``
    would raise) and is invalidated by the join.

    A sequence of :class:`TraceFuture`\\ s (nonblocking collectives or RMA
    requests inside an SPMD region) dispatches to :func:`trace_when_all` —
    the join stays lazy and forces its inputs in issue order.
    """

    if len(futures) > 0 and all(isinstance(f, TraceFuture) for f in futures):
        return trace_when_all(futures)
    errors.check(
        not any(isinstance(f, TraceFuture) for f in futures),
        errors.ErrorClass.ERR_REQUEST,
        "when_all over mixed host and trace futures: a trace-level request "
        "cannot be joined outside its SPMD region (join each level separately)",
    )
    seen: set[int] = set()
    for i, f in enumerate(futures):
        errors.check(
            f.valid() and id(f) not in seen,
            errors.ErrorClass.ERR_REQUEST,
            f"when_all: future {i} already consumed",
        )
        seen.add(id(f))
    for f in futures:
        f._valid = False
    if any(isinstance(f, DeferredFuture) for f in futures):
        # a join over in-flight host I/O stays lazy: waiting the join waits
        # every input (in order) and surfaces the first failure (ERR_IO from
        # a background write propagates, MPI_Waitall-style)
        inputs = tuple(futures)
        return DeferredFuture(
            lambda: tuple(f._wait_value() for f in inputs),
            probe=lambda: all(f.test() for f in inputs),
        )
    values = tuple(f._value for f in futures)
    return Future(values)


def when_any(
    futures: Sequence[Future],
    poll_interval_s: float = 1e-4,
    timeout_s: float | None = None,
) -> tuple[Future, int]:
    """``MPI_Waitany`` join: first completed future and its index.

    Inputs must be valid (unconsumed); the winner is returned still valid so
    the caller retrieves its value with ``get()``.  With ``timeout_s`` set,
    ``ERR_PENDING`` is raised if no input completes in time (instead of
    busy-waiting forever on a never-ready future).
    """

    errors.check(len(futures) > 0, errors.ErrorClass.ERR_REQUEST, "when_any of no futures")
    for i, f in enumerate(futures):
        errors.check(
            f.valid(),
            errors.ErrorClass.ERR_REQUEST,
            f"when_any: future {i} already consumed",
        )
    deadline = None if timeout_s is None else time.monotonic() + timeout_s
    while True:
        for i, f in enumerate(futures):
            if f.test():
                return f, i
        if deadline is not None and time.monotonic() >= deadline:
            errors.fail(
                errors.ErrorClass.ERR_PENDING,
                f"when_any: none of {len(futures)} futures completed "
                f"within {timeout_s}s",
            )
        time.sleep(poll_interval_s)


class TraceFuture:
    """Trace-level future: a lazily forced value inside an SPMD region."""

    def __init__(
        self,
        thunk: Callable[[], Any] | None = None,
        value: Any = None,
        label: str = "",
    ):
        self._thunk = thunk
        self._value = value
        self._forced = thunk is None
        # under analysis recording, lazy futures carry a ledger token so the
        # lifecycle checker can see which were never consumed at trace exit
        # (already-forced ready() values hold no pending communication)
        self._token = 0
        if thunk is not None and analysis_events.RECORDING:
            self._token = analysis_events.next_token()
            analysis_events.record_future_create(self._token, label)

    @classmethod
    def ready(cls, value: Any) -> "TraceFuture":
        return cls(thunk=None, value=value)

    def valid(self) -> bool:
        return True

    def _consume(self, how: str) -> None:
        if self._token:
            analysis_events.record_future_consume(self._token, how)
            self._token = 0

    def get(self) -> Any:
        """Force the communication into the trace and return its value."""

        if not self._forced:
            self._consume("get")
            self._value = self._thunk()
            self._thunk = None
            self._forced = True
        return self._value

    def test(self) -> bool:
        return self._forced

    def then(self, fn: Callable[["TraceFuture"], Any]) -> "TraceFuture":
        """Sequential-asynchronous chaining (Listing 2).  Lazy: nothing is
        traced until the chain end is forced, letting decomposed collectives
        fuse continuations."""

        self._consume("then")

        def thunk():
            result = fn(self)
            if isinstance(result, TraceFuture):
                return result.get()
            return result

        return TraceFuture(thunk, label="then")


def trace_when_all(futures: Sequence[TraceFuture]) -> TraceFuture:
    """``MPI_Waitall`` at trace level: forces all, yields the tuple."""

    for f in futures:
        f._consume("when_all")
    return TraceFuture(lambda: tuple(f.get() for f in futures), label="when_all")


def trace_when_any(futures: Sequence[TraceFuture]) -> tuple[TraceFuture, int]:
    """``MPI_Waitany`` at trace level.  XLA programs are statically
    scheduled, so "whichever completes first" is not observable; the
    documented SPMD semantics is deterministic selection of the first
    pending future (their side effects all occur at their forcing points)."""

    errors.check(len(futures) > 0, errors.ErrorClass.ERR_REQUEST, "when_any of no futures")
    for i, f in enumerate(futures):
        if not f.test():
            return f, i
    return futures[0], 0


# ---------------------------------------------------------------------------
# persistent operations (MPI_*_init / MPI_Start)
# ---------------------------------------------------------------------------


def _leaf_signature(leaf: Any) -> tuple:
    shape = tuple(getattr(leaf, "shape", ()))
    dtype = getattr(leaf, "dtype", None)
    return (shape, None if dtype is None else jnp.dtype(dtype))


def _leaf_sharding(leaf: Any):
    # only committed jax.Arrays carry a checkable sharding; ShapeDtypeStructs
    # used as AOT stand-ins leave sharding to the executable
    if isinstance(leaf, jax.Array):
        return getattr(leaf, "sharding", None)
    return None


def argument_signature(tree: Any) -> tuple:
    """Hashable (treedef, per-leaf shape/dtype) key for one argument list —
    the signature a :class:`PersistentRequest` is bound to; also usable as a
    cache key for per-shape-bucket requests."""

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return treedef, tuple(_leaf_signature(l) for l in leaves)


class PersistentRequest:
    """Persistent operation: AOT-compiled executable + ``start()``.

    ``MPI_Send_init`` fixes the argument list so repeated ``MPI_Start`` calls
    skip setup; the XLA analogue fixes shapes/shardings so repeated calls
    skip tracing, lowering and compilation — the hot path dispatches the
    compiled executable directly and can never re-trace.

    * **validation** — ``start()`` checks tree structure, leaf shapes/dtypes
      and (for committed arrays) shardings against the init-time argument
      list; any mismatch raises ``ERR_REQUEST`` (a persistent request is
      *bound* to its arguments in MPI).
    * **donation** — pass ``donate_argnums`` to the jitted function (and
      mirror it here for bookkeeping): donated inputs are aliased into
      outputs by XLA, so steady-state steps allocate nothing new.
    * **warm start** — ``warm_start=True`` fires the executable once at init
      on throwaway zero inputs (safe under donation — the zeros are owned
      here), prefetching executable load and allocator state so the first
      real ``start()`` runs at steady-state cost.
    * **continuations** — ``then(fn)`` registers a continuation applied to
      every start's host future (the persistent analogue of Listing 2).
    """

    def __init__(
        self,
        jitted: Any,
        example_args: tuple,
        example_kwargs: dict | None = None,
        *,
        donate_argnums: tuple[int, ...] = (),
        warm_start: bool = False,
    ):
        from repro.core import tool

        tool.pvar_count("persistent_init")
        self._lowered = jitted.lower(*example_args, **(example_kwargs or {}))
        self._compiled = self._lowered.compile()
        self.donate_argnums = tuple(donate_argnums)
        self._continuations: list[Callable[[Future], Any]] = []
        # the bound argument list: treedef + per-leaf (shape, dtype, sharding)
        leaves, self._treedef = jax.tree_util.tree_flatten(example_args)
        self._leaf_sigs = [_leaf_signature(l) for l in leaves]
        self._leaf_shardings = [_leaf_sharding(l) for l in leaves]
        self._started = 0
        # analysis bookkeeping: the last start()'s chained future, held
        # weakly so the analyzer never extends buffer lifetimes
        self._token = 0
        self._last_future: weakref.ref | None = None
        if analysis_events.RECORDING:
            self._token = analysis_events.next_token()
            analysis_events.record_persistent_init(
                self._token, donated=bool(self.donate_argnums))
        if warm_start:
            self._warm_start(leaves)

    def _warm_start(self, example_leaves: list) -> None:
        """Prefetch: fire once on owned zero buffers (donation-safe)."""

        zeros = []
        for (shape, dtype), shard in zip(self._leaf_sigs, self._leaf_shardings):
            z = jnp.zeros(shape, dtype)
            if shard is not None:
                z = jax.device_put(z, shard)
            zeros.append(z)
        out = self._compiled(*jax.tree_util.tree_unflatten(self._treedef, zeros))
        jax.block_until_ready(out)

    @property
    def compiled(self):
        return self._compiled

    @property
    def starts(self) -> int:
        return self._started

    def _validate(self, args: tuple) -> None:
        leaves, treedef = jax.tree_util.tree_flatten(args)
        errors.check(
            treedef == self._treedef,
            errors.ErrorClass.ERR_REQUEST,
            f"persistent start: argument structure {treedef} does not match "
            f"the init-time structure {self._treedef}",
        )
        for i, (leaf, sig, shard) in enumerate(
            zip(leaves, self._leaf_sigs, self._leaf_shardings)
        ):
            errors.check(
                _leaf_signature(leaf) == sig,
                errors.ErrorClass.ERR_REQUEST,
                f"persistent start: argument leaf {i} is "
                f"{_leaf_signature(leaf)}, request was initialised with {sig}",
            )
            cur = _leaf_sharding(leaf)
            if shard is not None and cur is not None:
                errors.check(
                    cur.is_equivalent_to(shard, max(len(sig[0]), 1)),
                    errors.ErrorClass.ERR_REQUEST,
                    f"persistent start: argument leaf {i} sharding {cur} is "
                    f"not equivalent to the init-time sharding {shard}",
                )

    def __call__(self, *args: Any) -> Any:
        """Fire the persistent operation, returning the raw (asynchronously
        dispatched) outputs — the drop-in replacement for a jitted step.

        The hot path dispatches straight into the compiled executable (whose
        own C++-level signature check is free); only when that rejects the
        arguments does the Python validation run, to convert the drift into
        a precise ``ERR_REQUEST``.  Unrelated runtime failures re-raise
        unchanged."""

        from repro.core import tool

        try:
            out = self._compiled(*args)
        except (TypeError, ValueError):
            # the compiled executable rejects drifted argument lists with
            # TypeError (shape/dtype/pytree mismatch) or ValueError
            # (sharding mismatch) — the expected failures; anything else
            # propagates untouched
            if errors.error_checking_enabled():
                self._validate(args)     # raises ERR_REQUEST if args drifted
            raise
        # only successful dispatches count as MPI_Start events
        tool.pvar_count("persistent_start")
        self._started += 1
        return out

    def start(self, *args: Any) -> Future:
        """``MPI_Start``: fire the persistent operation; returns a host
        future, chained through any registered ``then()`` continuations."""

        if analysis_events.RECORDING and self._token:
            prev = self._last_future() if self._last_future else None
            analysis_events.record_persistent_start(
                self._token,
                donated=bool(self.donate_argnums),
                prev_outstanding=prev is not None and prev.valid(),
                has_continuations=bool(self._continuations),
            )
        fut = Future(self(*args))
        for fn in self._continuations:
            fut = fut.then(fn)
        if analysis_events.RECORDING and self._token:
            self._last_future = weakref.ref(fut)
        return fut

    def then(self, fn: Callable[[Future], Any]) -> "PersistentRequest":
        """Register a continuation applied to every start's future."""

        self._continuations.append(fn)
        return self

    def cost_analysis(self):
        return self._compiled.cost_analysis()

    def as_text(self) -> str:
        return self._compiled.as_text()


class PersistentCollective:
    """A persistent collective over a *datatype* (``MPI_Allreduce_init``).

    Built by ``comm.<op>_init(example)``: the example aggregate's datatype is
    derived (C2), and one :class:`PersistentRequest` is AOT-compiled per
    dtype bucket — single-array examples skip packing entirely and compile
    one request on the array's own shape.  ``start(value)`` packs the new
    value (same datatype enforced), fires every bucket's executable, and
    returns a host :class:`Future` over the reassembled aggregate (or the
    raw bucket list for shape-changing collectives, mirroring the blocking
    forms).
    """

    def __init__(self, name: str, datatype, requests: list[PersistentRequest],
                 *, unpackable: bool = True, signature: tuple | None = None):
        self.name = name
        self.datatype = datatype          # None => single-array fast path
        self._requests = requests
        self._unpackable = unpackable
        self._signature = signature       # init-time aggregate signature

    @property
    def requests(self) -> list[PersistentRequest]:
        return self._requests

    @property
    def starts(self) -> int:
        """``MPI_Start`` events fired so far (max over the dtype-bucket
        requests — one logical start fires every bucket once)."""

        return max((r.starts for r in self._requests), default=0)

    def as_text(self) -> str:
        return "\n".join(r.as_text() for r in self._requests)

    def start(self, value: Any) -> Future:
        if self.datatype is None:
            return Future(self._requests[0](value))
        if self._signature is not None and errors.error_checking_enabled():
            # bind the aggregate too: pack() would silently cast drifted leaf
            # dtypes to the init-time layout, so check the signature first
            errors.check(
                argument_signature(value) == self._signature,
                errors.ErrorClass.ERR_REQUEST,
                f"persistent {self.name} start: aggregate does not match the "
                f"init-time datatype (shape/dtype/structure drift)",
            )
        bufs = self.datatype.pack(value)
        outs = [req(b) for req, b in zip(self._requests, bufs)]
        if self._unpackable:
            return Future(self.datatype.unpack(outs))
        return Future(outs)


# ---------------------------------------------------------------------------
# partitioned communication (MPI_Psend_init / MPI_Pready)
# ---------------------------------------------------------------------------


class PartitionedRequest:
    """Partitioned operation at trace level (``MPI_Psend_init`` family).

    One logical operation is split into ``num_partitions`` independent
    partitions.  ``pready(i, payload)`` marks partition ``i`` ready and
    returns a lazy :class:`TraceFuture` over ``fn(i, payload)`` — nothing is
    traced until that future (or :meth:`wait`) forces it, so the schedule
    interleaves each partition's communication with the compute producing
    later partitions.  :meth:`wait` forces every partition **in index
    order**, making the result independent of the ``pready`` order.

    The request is persistent in the MPI sense: :meth:`start` re-activates
    it for another round (``ERR_REQUEST`` on double start / pready without
    start / duplicate pready; ``ERR_PENDING`` on wait with missing
    partitions).
    """

    def __init__(self, fn: Callable[[int, Any], Any], num_partitions: int):
        errors.check(
            num_partitions > 0,
            errors.ErrorClass.ERR_COUNT,
            f"partitioned request needs >= 1 partition, got {num_partitions}",
        )
        from repro.core import tool

        tool.pvar_count("partitioned_init")
        self._fn = fn
        self._n = num_partitions
        self._futures: list[TraceFuture | None] = [None] * num_partitions
        self._active = False

    @property
    def num_partitions(self) -> int:
        return self._n

    def start(self) -> "PartitionedRequest":
        """``MPI_Start``: activate the request for one round of pready/wait."""

        from repro.core import tool

        errors.check(
            not self._active,
            errors.ErrorClass.ERR_REQUEST,
            "partitioned start: request already active (wait() first)",
        )
        tool.pvar_count("partitioned_start")
        self._futures = [None] * self._n
        self._active = True
        return self

    def pready(self, index: int, payload: Any) -> TraceFuture:
        """``MPI_Pready``: partition ``index``'s payload is produced; returns
        the lazy future over its share of the operation."""

        from repro.core import tool

        errors.check(
            self._active,
            errors.ErrorClass.ERR_REQUEST,
            "pready before start() on a partitioned request",
        )
        errors.check(
            0 <= index < self._n,
            errors.ErrorClass.ERR_REQUEST,
            f"pready partition {index} out of range [0, {self._n})",
        )
        errors.check(
            self._futures[index] is None,
            errors.ErrorClass.ERR_REQUEST,
            f"pready: partition {index} already marked ready",
        )
        tool.pvar_count("partition_ready")
        fut = TraceFuture(lambda: self._fn(index, payload))
        self._futures[index] = fut
        return fut

    def parrived(self, index: int) -> bool:
        """``MPI_Parrived``: has partition ``index`` been forced yet?"""

        errors.check(
            0 <= index < self._n,
            errors.ErrorClass.ERR_REQUEST,
            f"parrived partition {index} out of range [0, {self._n})",
        )
        f = self._futures[index]
        return f is not None and f.test()

    def wait(self) -> list:
        """Complete the operation: force every partition in index order and
        return their results.  ``ERR_PENDING`` if some partition was never
        marked ready (the MPI program would deadlock)."""

        missing = [i for i, f in enumerate(self._futures) if f is None]
        errors.check(
            not missing,
            errors.ErrorClass.ERR_PENDING,
            f"partitioned wait: partitions {missing} never marked ready",
        )
        results = [f.get() for f in self._futures]
        self._active = False
        return results
