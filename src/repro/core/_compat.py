"""JAX version compatibility for the mesh/shard_map substrate.

The interface targets current JAX (``jax.shard_map``, ``check_vma``,
``jax.sharding.AxisType``) but must also run on older installs where
``shard_map`` lives in ``jax.experimental`` (``check_rep``) and ``make_mesh``
has no ``axis_types``.  Everything that builds a mesh or enters SPMD routes
through here so the rest of the codebase stays version-free.
"""

from __future__ import annotations

from typing import Any, Callable, Sequence

import jax

_HAS_AXIS_TYPE = hasattr(jax.sharding, "AxisType")
_HAS_TOPLEVEL_SHARD_MAP = hasattr(jax, "shard_map")


def make_mesh(
    shape: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Sequence[Any] | None = None,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with ``Auto`` axis types where supported."""

    shape, axis_names = tuple(shape), tuple(axis_names)
    if _HAS_AXIS_TYPE:
        try:
            return jax.make_mesh(
                shape,
                axis_names,
                devices=devices,
                axis_types=(jax.sharding.AxisType.Auto,) * len(shape),
            )
        except TypeError:  # axis_types kwarg not accepted on this version
            pass
    return jax.make_mesh(shape, axis_names, devices=devices)


def mesh_from_devices(device_array, axis_names: Sequence[str]) -> jax.sharding.Mesh:
    """Build a ``Mesh`` from an already-arranged device array, preserving the
    caller's device order exactly (``make_mesh`` may reorder for physical
    topology, which would break group-rank ↔ device contracts)."""

    axis_names = tuple(axis_names)
    if _HAS_AXIS_TYPE:
        try:
            return jax.sharding.Mesh(
                device_array,
                axis_names,
                axis_types=(jax.sharding.AxisType.Auto,) * len(axis_names),
            )
        except TypeError:
            pass
    return jax.sharding.Mesh(device_array, axis_names)


def abstract_mesh(
    shape: Sequence[int], axis_names: Sequence[str]
) -> "jax.sharding.AbstractMesh":
    """``AbstractMesh`` across the (axis_sizes, axis_names) /
    tuple-of-(name, size)-pairs signature change."""

    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(shape), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, shape)))


def shard_map(
    fn: Callable,
    *,
    mesh: jax.sharding.Mesh,
    in_specs: Any,
    out_specs: Any,
) -> Callable:
    """``shard_map`` without replication/varying-manual-axes checking,
    wherever the implementation lives on this JAX."""

    if _HAS_TOPLEVEL_SHARD_MAP:
        try:
            return jax.shard_map(
                fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
            )
        except TypeError:  # exported before the check_rep -> check_vma rename
            return jax.shard_map(
                fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
