"""repro.core — a modern JAX interface for XLA collective communication.

The paper's contribution ("A C++20 Interface for MPI 4.0") adapted to the
TPU/XLA substrate: the MPI 4.0 Sessions model (process sets → groups →
``Communicator.from_group``), communicators over mesh axes, automatic
datatype generation by aggregate reflection, requests as futures with
continuations (and compiler-visible overlap), scoped enums + description
objects + meaningful defaults, opt-in trace-time error checking, parallel IO
and the tool (pvar/cvar) interface.  See DESIGN.md (repo root) for the full
mapping.

Conventional import::

    from repro import core as mpx

    comm = mpx.world()          # shim over Session → "repro://world" → Group

    @comm.spmd
    def program():
        data = jnp.zeros(())
        return comm.broadcast(data, root=0)

Session-first construction (heterogeneous workloads on one platform)::

    sess = mpx.Session.init()
    half = sess.group("repro://world").incl(range(4))
    comm = mpx.Communicator.from_group(half, tag="repro://train")
"""

from repro.core import errors  # noqa: F401
from repro.core.session import (  # noqa: F401
    UNDEFINED,
    Group,
    GroupComparison,
    Session,
    default_session,
)
from repro.core.communicator import Communicator, world  # noqa: F401
from repro.core.datatypes import (  # noqa: F401
    DataType,
    datatype_of,
    is_compliant,
    pack,
    register_aggregate,
    unpack,
)
from repro.core.descriptors import (  # noqa: F401
    Algorithm,
    CollectiveSpec,
    Compression,
    FileSpec,
    Mode,
    ReduceOp,
    ThreadLevel,
    WindowSpec,
)
from repro.core.futures import (  # noqa: F401
    DeferredFuture,
    Future,
    PartitionedRequest,
    PersistentCollective,
    PersistentRequest,
    TraceFuture,
    trace_when_all,
    trace_when_any,
    when_all,
    when_any,
)
from repro.core.collectives import (  # noqa: F401
    allgather,
    allgatherv,
    allreduce,
    alltoall,
    alltoallv,
    barrier,
    broadcast,
    exscan,
    gather,
    reduce,
    reduce_scatter,
    scan,
    scatter,
    send_recv,
    shift,
)
from repro.core.overlap import (  # noqa: F401
    all_gather_matmul,
    halo_exchange,
    hierarchical_allreduce,
    matmul_reduce_scatter,
    merge_partial_attention,
    partitioned_allreduce,
    partitioned_ring_all_gather,
    partitioned_ring_reduce_scatter,
    pipeline_spmd,
    ring_all_gather,
    ring_all_gather_bidirectional,
    ring_attention,
    ring_reduce_scatter,
)
from repro.core.onesided import Window, create_window  # noqa: F401
from repro.core.topology import (  # noqa: F401
    PROC_NULL,
    CartComm,
    CartShift,
    DistGraphComm,
    cart_create,
    dist_graph_create_adjacent,
)
from repro.core import compress, io, tool  # noqa: F401
from repro.core import _methods  # noqa: F401  (binds the method facade)


def future(value) -> "Future | TraceFuture":
    """``mpi::future(request)`` analogue: wrap a value or pass futures
    through (requests returned by ``immediate_*`` already are futures)."""

    if isinstance(value, (Future, TraceFuture)):
        return value
    return Future(value)


set_error_checking = errors.set_error_checking
