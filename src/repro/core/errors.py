"""Error handling (paper §II, C5).

The paper converts MPI return codes into exceptions carrying an *error code*
that derives from an *error class*, with default codes scoped in the
``mpi::error`` namespace, and the whole machinery opt-in at compile time via a
macro.  The JAX analogue: validation runs at *trace time* (the closest thing
to compile time Python has) and raises typed exceptions; it is toggled by
:func:`set_error_checking` / the ``error_checking`` control variable in
:mod:`repro.core.tool` (the macro analogue).  Checks are zero-cost when
disabled and zero-*runtime*-cost when enabled — they never emit ops.
"""

from __future__ import annotations

import enum
from typing import Any, NoReturn


class ErrorClass(enum.IntEnum):
    """MPI 4.0 §9.4 error classes (the subset meaningful under SPMD)."""

    SUCCESS = 0
    ERR_BUFFER = 1
    ERR_COUNT = 2
    ERR_TYPE = 3
    ERR_TAG = 4
    ERR_COMM = 5
    ERR_RANK = 6
    ERR_REQUEST = 7
    ERR_ROOT = 8
    ERR_OP = 9
    ERR_GROUP = 10
    ERR_TOPOLOGY = 11
    ERR_DIMS = 12
    ERR_ARG = 13
    ERR_PENDING = 14
    ERR_TRUNCATE = 15
    ERR_IN_STATUS = 18
    ERR_FILE = 30
    ERR_NO_MEM = 34
    ERR_NOT_SAME = 35
    ERR_IO = 39
    ERR_WIN = 45
    ERR_UNSUPPORTED_OPERATION = 52
    ERR_RMA_RANGE = 55
    ERR_RMA_ATTACH = 56
    ERR_SESSION = 78
    # ULFM fault-tolerance classes (MPI 4.x errhandler proposal): a peer
    # process failed, or the communicator was revoked by the recovery
    # protocol and must be rebuilt from a shrunken group
    ERR_PROC_FAILED = 75
    ERR_REVOKED = 76
    ERR_OTHER = 16


class Error(Exception):
    """Base exception; carries an :class:`ErrorClass` (``error.klass``) and a
    code (``error.code``) as the paper's exceptions do."""

    klass: ErrorClass = ErrorClass.ERR_OTHER

    def __init__(self, message: str, *, code: int | None = None):
        super().__init__(f"[{self.klass.name}] {message}")
        self.code = self.klass.value if code is None else code
        self.message = message


class BufferError_(Error):
    klass = ErrorClass.ERR_BUFFER


class CountError(Error):
    klass = ErrorClass.ERR_COUNT


class TypeError_(Error):
    klass = ErrorClass.ERR_TYPE


class CommError(Error):
    klass = ErrorClass.ERR_COMM


class RankError(Error):
    klass = ErrorClass.ERR_RANK


class RequestError(Error):
    klass = ErrorClass.ERR_REQUEST


class RootError(Error):
    klass = ErrorClass.ERR_ROOT


class OpError(Error):
    klass = ErrorClass.ERR_OP


class TopologyError(Error):
    klass = ErrorClass.ERR_TOPOLOGY


class DimsError(Error):
    klass = ErrorClass.ERR_DIMS


class ArgError(Error):
    klass = ErrorClass.ERR_ARG


class PendingError(Error):
    klass = ErrorClass.ERR_PENDING


class TruncateError(Error):
    klass = ErrorClass.ERR_TRUNCATE


class FileError(Error):
    klass = ErrorClass.ERR_FILE


class IoError(Error):
    klass = ErrorClass.ERR_IO


class NoMemError(Error):
    klass = ErrorClass.ERR_NO_MEM


class WinError(Error):
    klass = ErrorClass.ERR_WIN


class RmaRangeError(Error):
    klass = ErrorClass.ERR_RMA_RANGE


class RmaAttachError(Error):
    klass = ErrorClass.ERR_RMA_ATTACH


class UnsupportedError(Error):
    klass = ErrorClass.ERR_UNSUPPORTED_OPERATION


class GroupError(Error):
    klass = ErrorClass.ERR_GROUP


class SessionError(Error):
    klass = ErrorClass.ERR_SESSION


class ProcFailedError(Error):
    klass = ErrorClass.ERR_PROC_FAILED


class RevokedError(Error):
    klass = ErrorClass.ERR_REVOKED


#: ``mpi::error`` namespace analogue — default codes as scoped variables.
buffer = ErrorClass.ERR_BUFFER
count = ErrorClass.ERR_COUNT
type = ErrorClass.ERR_TYPE  # noqa: A001 — mirrors mpi::error::type
comm = ErrorClass.ERR_COMM
rank = ErrorClass.ERR_RANK
request = ErrorClass.ERR_REQUEST
root = ErrorClass.ERR_ROOT
op = ErrorClass.ERR_OP
topology = ErrorClass.ERR_TOPOLOGY
dims = ErrorClass.ERR_DIMS
arg = ErrorClass.ERR_ARG
pending = ErrorClass.ERR_PENDING
truncate = ErrorClass.ERR_TRUNCATE
file = ErrorClass.ERR_FILE
no_mem = ErrorClass.ERR_NO_MEM
io = ErrorClass.ERR_IO
win = ErrorClass.ERR_WIN
rma_range = ErrorClass.ERR_RMA_RANGE
rma_attach = ErrorClass.ERR_RMA_ATTACH
group = ErrorClass.ERR_GROUP
session = ErrorClass.ERR_SESSION
proc_failed = ErrorClass.ERR_PROC_FAILED
revoked = ErrorClass.ERR_REVOKED
other = ErrorClass.ERR_OTHER


_CLASS_TO_EXC: dict[ErrorClass, Any] = {
    ErrorClass.ERR_BUFFER: BufferError_,
    ErrorClass.ERR_COUNT: CountError,
    ErrorClass.ERR_TYPE: TypeError_,
    ErrorClass.ERR_COMM: CommError,
    ErrorClass.ERR_RANK: RankError,
    ErrorClass.ERR_REQUEST: RequestError,
    ErrorClass.ERR_ROOT: RootError,
    ErrorClass.ERR_OP: OpError,
    ErrorClass.ERR_TOPOLOGY: TopologyError,
    ErrorClass.ERR_DIMS: DimsError,
    ErrorClass.ERR_ARG: ArgError,
    ErrorClass.ERR_PENDING: PendingError,
    ErrorClass.ERR_TRUNCATE: TruncateError,
    ErrorClass.ERR_FILE: FileError,
    ErrorClass.ERR_IO: IoError,
    ErrorClass.ERR_NO_MEM: NoMemError,
    ErrorClass.ERR_WIN: WinError,
    ErrorClass.ERR_RMA_RANGE: RmaRangeError,
    ErrorClass.ERR_RMA_ATTACH: RmaAttachError,
    ErrorClass.ERR_UNSUPPORTED_OPERATION: UnsupportedError,
    ErrorClass.ERR_GROUP: GroupError,
    ErrorClass.ERR_SESSION: SessionError,
    ErrorClass.ERR_PROC_FAILED: ProcFailedError,
    ErrorClass.ERR_REVOKED: RevokedError,
}


def exception(klass: ErrorClass, message: str) -> Error:
    """Build the exception type matching an error class."""

    return _CLASS_TO_EXC.get(klass, Error)(message)


_ERROR_CHECKING = True


def set_error_checking(enabled: bool) -> bool:
    """Toggle trace-time validation (the paper's compile-time macro).

    Returns the previous value so callers can restore it.
    """

    global _ERROR_CHECKING
    prev = _ERROR_CHECKING
    _ERROR_CHECKING = bool(enabled)
    return prev


def error_checking_enabled() -> bool:
    return _ERROR_CHECKING


def check(condition: bool, klass: ErrorClass, message: str) -> None:
    """Raise ``exception(klass, message)`` if checking is on and the
    condition is false.  Conditions must be trace-time static."""

    if _ERROR_CHECKING and not condition:
        raise exception(klass, message)


def fail(klass: ErrorClass, message: str) -> NoReturn:
    raise exception(klass, message)
