"""Decomposed, overlappable collective schedules (paper C3, performance side).

MPI programs overlap communication and computation by issuing ``MPI_I*``
operations and continuing to compute until ``MPI_Wait``.  XLA has no progress
thread; the TPU-native equivalent is to *decompose* a collective into a
``collective-permute`` ring whose steps are interleaved with compute chunks in
the dependence graph — then the scheduler overlaps ICI DMA of step ``s+1``
with MXU compute of step ``s``.  These schedules are what a
:class:`~repro.core.futures.TraceFuture` continuation fuses into.

Contents:

* :func:`ring_all_gather` / :func:`ring_reduce_scatter` — explicit ring
  algorithms (uni- or bidirectional), drop-in for the XLA collectives.
* :func:`all_gather_matmul` — "collective matmul": gathers the *contraction*
  dimension of a sharded weight while accumulating partial products
  (FSDP/TP forward overlap).
* :func:`matmul_reduce_scatter` — the reverse pattern (TP output reduction).
* :func:`hierarchical_allreduce` — reduce-scatter inside a fast axis,
  (optionally int8-compressed) reduction across a slow axis (DCN/pod),
  all-gather back — the cross-pod gradient reduction.
* :func:`merge_partial_attention` — flash-decoding combine for
  sequence-sharded KV caches.
* :func:`ring_rotate_compute` — the double-buffered rotate-while-compute
  schedule (generalizing :func:`halo_exchange`): step ``i+1``'s rotation is
  issued as a :class:`TraceFuture` before step ``i``'s compute and joined
  with ``when_all`` — the engine under ring attention
  (:mod:`repro.kernels.ring_attention`).
* :func:`ring_attention` — sequence-parallel attention for training: KV
  blocks circulate the ring; online-softmax state makes every step O(local).
* :func:`partitioned_allreduce` / :func:`partitioned_ring_reduce_scatter` /
  :func:`partitioned_ring_all_gather` — partitioned communication
  (``MPI_Psend_init``/``MPI_Pready``): one logical collective split into K
  independently-ready partitions, each a lazy :class:`TraceFuture` consumed
  in ``Pready`` order with chunk-wise fused continuations — the schedule
  behind backward-overlapped gradient sync (:mod:`repro.optim.grad_sync`).
* :func:`halo_exchange` / :func:`pipeline_spmd` — neighbor-structured
  schedules over a :class:`~repro.core.topology.CartComm` (MPI 4.0 ch. 8):
  halo boundary exchange as an overlappable :class:`TraceFuture`, and the
  pipeline-parallel microbatch schedule whose stage boundaries are
  ``cart_shift(+1)`` permutes — the production fabric of the pipeline
  Trainer mode (:mod:`repro.runtime.trainer`).
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import compress, errors
from repro.core.communicator import Communicator
from repro.core.descriptors import Compression
from repro.core.futures import PartitionedRequest, TraceFuture, when_all


def _ring_perm(n: int, offset: int = 1) -> list[tuple[int, int]]:
    return [(i, (i + offset) % n) for i in range(n)]


def _axis(comm: Communicator) -> tuple[str, int]:
    errors.check(
        len(comm.axis_names) == 1,
        errors.ErrorClass.ERR_TOPOLOGY,
        "ring schedules need a single-axis communicator (comm.split(axis))",
    )
    name = comm.axis_names[0]
    return name, comm.axis_size(name)


# ---------------------------------------------------------------------------
# ring all-gather
# ---------------------------------------------------------------------------


def ring_all_gather(comm: Communicator, x: jax.Array, *, axis: int = 0) -> jax.Array:
    """All-gather decomposed into ``n-1`` permute steps (tiled concat)."""

    name, n = _axis(comm)
    if n == 1:
        return x
    idx = lax.axis_index(name)
    block = x.shape[axis]
    out_shape = x.shape[:axis] + (block * n,) + x.shape[axis + 1 :]
    out = jnp.zeros(out_shape, x.dtype)
    chunk = x
    out = lax.dynamic_update_slice_in_dim(out, chunk, idx * block, axis=axis)
    for step in range(1, n):
        chunk = lax.ppermute(chunk, name, _ring_perm(n))
        src = (idx - step) % n
        out = lax.dynamic_update_slice_in_dim(out, chunk, src * block, axis=axis)
    return out


def ring_all_gather_bidirectional(
    comm: Communicator, x: jax.Array, *, axis: int = 0
) -> jax.Array:
    """Bidirectional ring: halves the steps by sending both ways, doubling
    effective link bandwidth on a bidirectional ICI ring."""

    name, n = _axis(comm)
    if n == 1:
        return x
    idx = lax.axis_index(name)
    block = x.shape[axis]
    out_shape = x.shape[:axis] + (block * n,) + x.shape[axis + 1 :]
    out = jnp.zeros(out_shape, x.dtype)
    out = lax.dynamic_update_slice_in_dim(out, x, idx * block, axis=axis)
    fwd = bwd = x
    steps_fwd = (n - 1 + 1) // 2
    steps_bwd = (n - 1) // 2
    for step in range(1, steps_fwd + 1):
        fwd = lax.ppermute(fwd, name, _ring_perm(n, +1))
        out = lax.dynamic_update_slice_in_dim(out, fwd, ((idx - step) % n) * block, axis=axis)
    for step in range(1, steps_bwd + 1):
        bwd = lax.ppermute(bwd, name, _ring_perm(n, -1))
        out = lax.dynamic_update_slice_in_dim(out, bwd, ((idx + step) % n) * block, axis=axis)
    return out


def ring_reduce_scatter(comm: Communicator, x: jax.Array, *, axis: int = 0) -> jax.Array:
    """Reduce-scatter decomposed into a ring of permute+add steps."""

    name, n = _axis(comm)
    if n == 1:
        return x
    idx = lax.axis_index(name)
    errors.check(
        x.shape[axis] % n == 0,
        errors.ErrorClass.ERR_COUNT,
        f"ring_reduce_scatter axis {axis} of {x.shape} not divisible by {n}",
    )
    block = x.shape[axis] // n

    def take(b):
        return lax.dynamic_slice_in_dim(x, b * block, block, axis=axis)

    # token for block b starts at rank b+1 and accumulates around the ring.
    acc = take((idx - 1) % n)
    for step in range(n - 1):
        acc = lax.ppermute(acc, name, _ring_perm(n))
        acc = acc + take((idx - 2 - step) % n)
    return acc


# ---------------------------------------------------------------------------
# fused compute/communication schedules
# ---------------------------------------------------------------------------


def all_gather_matmul(
    comm: Communicator,
    x: jax.Array,
    w_shard: jax.Array,
    *,
    precision=None,
    accumulate_dtype=jnp.float32,
) -> jax.Array:
    """``x @ all_gather(w_shard)`` without materialising the gather.

    ``w_shard``: this rank's ``(k/n, f)`` block of a ``(k, f)`` weight whose
    contraction dim is sharded over the communicator.  Each ring step matmuls
    the matching ``k``-slice of ``x`` against the block in flight, so DMA and
    MXU time overlap.  FLOPs are identical to gather-then-matmul; peak memory
    drops by the gathered weight.
    """

    name, n = _axis(comm)
    idx = lax.axis_index(name)
    kb = w_shard.shape[0]
    errors.check(
        x.shape[-1] == kb * n,
        errors.ErrorClass.ERR_COUNT,
        f"contraction mismatch: x has k={x.shape[-1]}, shards give {kb * n}",
    )

    def x_block(b):
        return lax.dynamic_slice_in_dim(x, b * kb, kb, axis=x.ndim - 1)

    def mm(xa, wb):
        return jnp.matmul(xa, wb, precision=precision).astype(accumulate_dtype)

    w_cur = w_shard
    acc = mm(x_block(idx), w_cur)
    for step in range(1, n):
        w_cur = lax.ppermute(w_cur, name, _ring_perm(n))
        acc = acc + mm(x_block((idx - step) % n), w_cur)
    return acc


def matmul_reduce_scatter(
    comm: Communicator,
    x: jax.Array,
    w: jax.Array,
    *,
    precision=None,
    accumulate_dtype=jnp.float32,
) -> jax.Array:
    """``reduce_scatter(x @ w, axis=-1)`` with the matmul chunked into the
    ring so each partial block is computed just-in-time for its hop.

    ``x``: ``(..., k_local)`` — contraction dim sharded over the comm (each
    rank holds a partial sum).  ``w``: ``(k_local, f)``.  Returns this rank's
    ``(..., f/n)`` block of the fully-reduced product.
    """

    name, n = _axis(comm)
    idx = lax.axis_index(name)
    f = w.shape[-1]
    errors.check(
        f % n == 0,
        errors.ErrorClass.ERR_COUNT,
        f"output dim {f} not divisible by communicator size {n}",
    )
    fb = f // n

    def partial_block(b):
        wb = lax.dynamic_slice_in_dim(w, b * fb, fb, axis=1)
        return jnp.matmul(x, wb, precision=precision).astype(accumulate_dtype)

    acc = partial_block((idx - 1) % n)
    for step in range(n - 1):
        acc = lax.ppermute(acc, name, _ring_perm(n))
        acc = acc + partial_block((idx - 2 - step) % n)
    return acc


def hierarchical_allreduce(
    x: jax.Array,
    inner: Communicator,
    outer: Communicator,
    *,
    compression: Compression = Compression.NONE,
) -> jax.Array:
    """All-reduce factored as RS(inner) → AR(outer) → AG(inner).

    ``inner`` is the fast fabric (intra-pod ICI), ``outer`` the slow one
    (inter-pod DCN).  The outer stage moves ``1/inner_size`` of the payload;
    with :data:`Compression.INT8` it moves ~1/4 of *that* (int8 + scales) —
    callers maintain error feedback (see ``repro.optim``).
    """

    ni = inner.size()
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % (ni * compress.BLOCK)
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), dtype)])
    rs = lax.psum_scatter(flat, inner.axis_names, scatter_dimension=0, tiled=True)
    if compression is Compression.INT8 and outer.size() > 1:
        q, scale, qpad = compress.quantize_int8(rs)
        qg = lax.all_gather(q, outer.axis_names, axis=0, tiled=False)
        sg = lax.all_gather(scale, outer.axis_names, axis=0, tiled=False)
        no = qg.shape[0]
        acc = jnp.zeros(rs.shape, jnp.float32)
        for r in range(no):
            acc = acc + compress.dequantize_int8(
                qg[r], sg[r], qpad, rs.shape, jnp.float32
            )
        red = acc.astype(dtype)
    else:
        red = lax.psum(rs, outer.axis_names)
    full = lax.all_gather(red, inner.axis_names, axis=0, tiled=True)
    if pad:
        full = full[:-pad]
    return full.reshape(shape)


# ---------------------------------------------------------------------------
# attention combiners (sequence-sharded KV)
# ---------------------------------------------------------------------------


def merge_partial_attention(
    o: jax.Array, m: jax.Array, l: jax.Array, comm: Communicator
) -> jax.Array:
    """Flash-decoding combine across a sequence-sharded KV cache.

    Each rank computed attention over its KV shard, yielding normalised
    output ``o`` (..., q, h, d), running max ``m`` (..., h, q) and
    normaliser ``l`` (..., h, q) — the flash-attention state convention.
    The exact global softmax is recovered with one ``pmax`` + two ``psum``\\ s
    of O(batch·heads) payload — versus all-gathering the full KV cache.
    """

    axes = comm.axis_names
    gm = lax.pmax(m, axes)
    l_corr = l * jnp.exp(m - gm)                      # (..., h, q)
    w = jnp.swapaxes(l_corr, -1, -2)[..., None]       # (..., q, h, 1)
    num = lax.psum(o * w, axes)
    den = lax.psum(w, axes)
    return num / jnp.maximum(den, 1e-30)


def ring_rotate_compute(rotate, buf, steps: int, step_fn, carry):
    """Double-buffered rotate-while-compute: the generic schedule behind
    ring attention, generalizing :func:`halo_exchange` from one boundary
    exchange to a full rotation.

    ``rotate(buf)`` returns the *in-flight* next buffer as a lazy
    :class:`TraceFuture` (e.g. ``cart.shift_exchange(buf, dim, 1)``);
    ``step_fn(carry, buf, step)`` folds the current buffer into the carry.
    Each round issues the rotation of step ``i+1`` *before* step ``i``'s
    compute and joins the two with :func:`~repro.core.futures.when_all` —
    the ``MPI_Isend`` / compute / ``MPI_Waitall`` triangle.  The dependence
    frontier this fixes (permute ``i+1`` needs only buffer ``i``, never
    carry ``i``) is exactly the freedom the XLA scheduler needs to overlap
    each permute's DMA with the current step's compute.  The last step
    rotates nothing: ``steps`` buffers cost ``steps - 1`` exchanges.
    """

    errors.check(
        steps >= 1,
        errors.ErrorClass.ERR_COUNT,
        f"ring schedule needs >= 1 step, got {steps}",
    )
    for step in range(steps):
        if step < steps - 1:
            in_flight = rotate(buf)
            compute = TraceFuture(
                lambda c=carry, b=buf, s=step: step_fn(c, b, s)
            )
            carry, buf = when_all([compute, in_flight]).get()
        else:
            carry = step_fn(carry, buf, step)
    return carry


def _online_block(q, k, v, m, l, acc, *, bias=None, scale):
    """One online-softmax accumulation step (fp32 state)."""

    s = jnp.einsum("...qhd,...khd->...hqk", q, k).astype(jnp.float32) * scale
    if bias is not None:
        s = s + bias
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1)
    pv = jnp.einsum("...hqk,...khd->...qhd", p.astype(v.dtype), v).astype(jnp.float32)
    acc_new = acc * corr.transpose(*range(corr.ndim - 2), -1, -2)[..., None] + pv
    return m_new, l_new, acc_new


def ring_attention(
    comm: Communicator,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    scale: float | None = None,
) -> jax.Array:
    """Sequence-parallel attention: KV blocks circulate a ring while each
    rank holds its Q shard; online softmax keeps state O(local).

    Shapes: ``q``(b, sq, h, d), ``k``/``v``(b, sk, hk, d) — the *local*
    shards; the global sequence is ``n × s``.  GQA is handled by repeating
    KV heads.  Returns the local output shard (b, sq, h, d).
    """

    name, n = _axis(comm)
    idx = lax.axis_index(name)
    b, sq, h, d = q.shape
    sk, hk = k.shape[1], k.shape[2]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if hk != h:
        rep = h // hk
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    m = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l = jnp.zeros((b, h, sq), jnp.float32)
    acc = jnp.zeros((b, sq, h, d), jnp.float32)

    q_pos = idx * sq + jnp.arange(sq)

    def rotate(kv):
        # one permute per step: K and V travel as a single stacked buffer
        return TraceFuture(lambda: lax.ppermute(kv, name, _ring_perm(n)))

    def step_fn(carry, kv, step):
        m, l, acc = carry
        src = (idx - step) % n
        k_pos = src * sk + jnp.arange(sk)
        bias = None
        if causal:
            mask = q_pos[:, None] >= k_pos[None, :]
            bias = jnp.where(mask, 0.0, -jnp.inf)[None, None]  # (1,1,sq,sk)
        return _online_block(q, kv[0], kv[1], m, l, acc, bias=bias, scale=scale)

    m, l, acc = ring_rotate_compute(
        rotate, jnp.stack([k, v]), n, step_fn, (m, l, acc)
    )
    norm = jnp.maximum(l, 1e-30).transpose(0, 2, 1)[..., None]  # (b,sq,h,1)
    return (acc / norm).astype(q.dtype)


# ---------------------------------------------------------------------------
# immediate (future-returning) forms
# ---------------------------------------------------------------------------


class RingAllGatherFuture(TraceFuture):
    """Future over a decomposed all-gather whose continuation may fuse.

    ``get()`` materialises the plain ring gather; ``then_matmul(w)`` — the
    continuation the paper chains with ``.then`` — *never* materialises the
    gather and lowers to :func:`all_gather_matmul` instead.
    """

    def __init__(self, comm: Communicator, x: jax.Array, axis: int = 0):
        super().__init__(thunk=partial(ring_all_gather, comm, x, axis=axis))
        self._comm = comm
        self._x = x

    def then_matmul(self, x_full: jax.Array, **kw) -> TraceFuture:
        """Fused continuation: ``x_full @ gathered`` (this future's payload is
        the contraction-sharded weight)."""

        fut = self

        def thunk():
            return all_gather_matmul(fut._comm, x_full, fut._x, **kw)

        return TraceFuture(thunk)


def immediate_all_gather(comm: Communicator, x: jax.Array, *, axis: int = 0):
    return RingAllGatherFuture(comm, x, axis=axis)


def immediate_all_reduce(comm: Communicator, x: jax.Array):
    from repro.core import collectives

    return TraceFuture(lambda: collectives.allreduce(comm, x))


def immediate_reduce_scatter(comm: Communicator, x: jax.Array, *, axis: int = 0):
    return TraceFuture(lambda: ring_reduce_scatter(comm, x, axis=axis))


def immediate_send_recv(comm: Communicator, x, perm):
    from repro.core import collectives

    return TraceFuture(lambda: collectives.send_recv(comm, x, perm))


# ---------------------------------------------------------------------------
# partitioned schedules (MPI_Psend_init / MPI_Pready over ring collectives)
# ---------------------------------------------------------------------------


def _partitioned(comm: Communicator, num_partitions: int, reduce_one, continuation):
    """A :class:`PartitionedRequest` whose partition ``i``, once
    ``pready(i, x)``, lowers ``reduce_one(x)`` and fuses the optional
    chunk-wise ``continuation(i, reduced)`` into the same trace future —
    consumed in ``Pready`` order, forced no later than ``wait()``."""

    def fn(i, x):
        y = reduce_one(x)
        return continuation(i, y) if continuation is not None else y

    req = PartitionedRequest(fn, num_partitions)
    return req.start()


def halo_exchange(
    cart,
    x: jax.Array,
    *,
    dim: int = 0,
    axis: int = 0,
    width: int = 1,
) -> TraceFuture:
    """Cartesian halo exchange (the ch. 8 stencil idiom): send the ``width``
    boundary slices of ``x`` (array dimension ``axis``) to the ∓ neighbors
    along cart dimension ``dim``; resolves to ``(from_minus, from_plus)`` —
    the neighbor boundary slices this rank receives (zeros beyond a
    non-periodic edge, the :data:`~repro.core.topology.PROC_NULL`
    convention).

    Returned lazily as a :class:`TraceFuture` so the issue point precedes
    interior compute and the forcing point sits at boundary consumption —
    the scheduler then overlaps the two axis-local ``collective-permute``\\ s
    with the interior work, the TPU-native ``MPI_Ineighbor_*`` + compute +
    ``MPI_Wait`` pattern.
    """

    errors.check(
        0 < width <= x.shape[axis],
        errors.ErrorClass.ERR_COUNT,
        f"halo width {width} invalid for array dim of size {x.shape[axis]}",
    )

    def impl():
        plus = cart.cart_shift(dim, 1)
        minus = cart.cart_shift(dim, -1)
        hi = lax.slice_in_dim(x, x.shape[axis] - width, x.shape[axis], axis=axis)
        lo = lax.slice_in_dim(x, 0, width, axis=axis)
        # my high boundary travels +1 and becomes the + rank's from_minus
        from_minus = lax.ppermute(hi, plus.axis_name, list(plus.axis_perm))
        from_plus = lax.ppermute(lo, minus.axis_name, list(minus.axis_perm))
        return from_minus, from_plus

    return TraceFuture(impl)


def pipeline_spmd(
    cart,
    *,
    stage_dim: int,
    num_microbatches: int,
    inject: Callable[[int], jax.Array],
    stage_fn: Callable[[jax.Array, int], jax.Array],
    extract: Callable[[int, jax.Array, jax.Array], Any],
) -> list:
    """Pipeline-parallel microbatch schedule over a cart ``stage`` dim.

    The classic pipeline loop, spelled in the ch. 8 vocabulary: at tick
    ``t`` every stage applies its local layers to the microbatch in flight,
    then the activation moves one stage down via the ``cart_shift(+1)``
    boundary exchange (one axis-local ``collective-permute``; the first
    stage's incoming edge is :data:`~repro.core.topology.PROC_NULL`, so the
    injected microbatch overwrites zeros).  Microbatch ``m`` enters stage 0
    at tick ``m`` and drains from stage ``S-1`` at tick ``m + S - 1`` —
    ``M + S - 1`` ticks total, the ``S-1``-tick bubble of a forward
    pipeline.

    Scheduling honesty: XLA programs are statically scheduled, so 1F1B-style
    forward/backward interleaving is not an imperative loop here — what this
    schedule fixes is the *dependence frontier* (stage ``s`` at tick ``t``
    needs stage ``s-1``'s tick-``t-1`` permute and nothing else), which is
    exactly the freedom the XLA scheduler needs to overlap each boundary
    permute with the next microbatch's compute; the backward program AD
    derives from this loop has the mirrored frontier (see DESIGN.md ch. 8).

    * ``inject(m)`` → the stage-0 input for microbatch ``m`` (computed on
      every rank, selected onto stage 0 — uniform SPMD program).
    * ``stage_fn(state, t)`` → this stage's local layers applied to the
      in-flight activation.
    * ``extract(m, state, is_last)`` → called once per drained microbatch
      with ``is_last`` (a trace-level predicate for "this rank is the final
      stage"); its results are returned in microbatch order.  Callers
      typically mask with ``jnp.where(is_last, ...)`` and ``psum`` over the
      stage axis.
    """

    dims = cart.dims
    errors.check(
        0 <= stage_dim < len(dims),
        errors.ErrorClass.ERR_DIMS,
        f"stage_dim {stage_dim} out of range for cart dims {dims}",
    )
    errors.check(
        num_microbatches >= 1,
        errors.ErrorClass.ERR_COUNT,
        f"pipeline needs >= 1 microbatch, got {num_microbatches}",
    )
    errors.check(
        not cart.periods[stage_dim],
        errors.ErrorClass.ERR_TOPOLOGY,
        "the pipeline stage dim must be non-periodic (activations drain at "
        "the last stage; a periodic shift would wrap them into stage 0)",
    )
    s = dims[stage_dim]
    axis_name = cart.axis_names[stage_dim]
    stage = lax.axis_index(axis_name)
    is_first = stage == 0
    is_last = stage == s - 1
    m = num_microbatches

    state = jnp.zeros_like(inject(0))
    outs = []
    for t in range(m + s - 1):
        state = jnp.where(is_first, inject(min(t, m - 1)), state)
        state = stage_fn(state, t)
        out_t = t - (s - 1)
        if out_t >= 0:
            outs.append(extract(out_t, state, is_last))
        if t < m + s - 2:
            state = cart.shift_exchange(state, stage_dim, 1).get()
    return outs


def partitioned_allreduce(
    comm: Communicator,
    num_partitions: int,
    *,
    continuation: Callable[[int, jax.Array], Any] | None = None,
) -> PartitionedRequest:
    """All-reduce split into independently-ready partitions.

    Each partition is a full ``psum`` over its own payload (numerically
    identical to reducing the concatenation), so partitions can be marked
    ready as their producers finish — per-bucket gradient reduction
    overlapping the still-running backward pass is exactly this schedule.
    """

    from repro.core import collectives

    return _partitioned(
        comm, num_partitions, lambda x: collectives.allreduce(comm, x), continuation
    )


def partitioned_ring_reduce_scatter(
    comm: Communicator,
    num_partitions: int,
    *,
    axis: int = 0,
    continuation: Callable[[int, jax.Array], Any] | None = None,
) -> PartitionedRequest:
    """Reduce-scatter rings, one per partition, consumed in ``Pready`` order."""

    return _partitioned(
        comm,
        num_partitions,
        lambda x: ring_reduce_scatter(comm, x, axis=axis),
        continuation,
    )


def partitioned_ring_all_gather(
    comm: Communicator,
    num_partitions: int,
    *,
    axis: int = 0,
    continuation: Callable[[int, jax.Array], Any] | None = None,
) -> PartitionedRequest:
    """All-gather rings, one per partition, consumed in ``Pready`` order."""

    return _partitioned(
        comm,
        num_partitions,
        lambda x: ring_all_gather(comm, x, axis=axis),
        continuation,
    )
