"""Paper-style method facade: binds the collective/future API onto
:class:`~repro.core.communicator.Communicator` so user code reads exactly
like the paper's examples::

    status = mpx.future(comm.immediate_broadcast(data, 0)) \
        .then(lambda f: ...) \
        .get()

Binding lives here (not in ``communicator.py``) to keep the functional core
import-cycle-free; counters for the MPI_T pvar interface are incremented at
this layer.
"""

from __future__ import annotations

import functools

from repro.core import collectives, overlap, tool
from repro.core.communicator import Communicator
from repro.core.futures import PersistentRequest, TraceFuture


def _counted(name, fn):
    @functools.wraps(fn)
    def wrapper(*a, **k):
        tool.pvar_count(name)
        return fn(*a, **k)

    return wrapper


def _bind() -> None:
    # blocking collectives (chapter 6)
    for name in (
        "broadcast",
        "allreduce",
        "reduce",
        "reduce_scatter",
        "allgather",
        "gather",
        "scatter",
        "alltoall",
        "allgatherv",
        "alltoallv",
        "scan",
        "exscan",
        "send_recv",
        "shift",
        "barrier",
    ):
        fn = getattr(collectives, name)

        def method(self, *a, _fn=fn, _name=name, **k):
            tool.pvar_count(_name)
            return _fn(self, *a, **k)

        method.__name__ = name
        method.__doc__ = fn.__doc__
        setattr(Communicator, name, method)

    # immediate (future-returning) forms — requests as futures (C3)
    def immediate(self, name, *a, **k):
        fn = getattr(collectives, name)
        tool.pvar_count(f"immediate_{name}")
        return TraceFuture(lambda: fn(self, *a, **k))

    for name in (
        "broadcast",
        "allreduce",
        "reduce",
        "reduce_scatter",
        "allgather",
        "gather",
        "scatter",
        "alltoall",
        "scan",
        "exscan",
        "send_recv",
        "shift",
        "barrier",
    ):

        def imethod(self, *a, _name=name, **k):
            return immediate(self, _name, *a, **k)

        imethod.__name__ = f"immediate_{name}"
        imethod.__doc__ = (
            f"Nonblocking {name}: returns a TraceFuture (MPI_I{name.capitalize()})."
        )
        setattr(Communicator, f"immediate_{name}", imethod)

    # decomposed/overlappable forms
    def immediate_ring_allgather(self, x, *, axis=0):
        tool.pvar_count("immediate_ring_allgather")
        return overlap.immediate_all_gather(self, x, axis=axis)

    Communicator.immediate_ring_allgather = immediate_ring_allgather

    # persistent operations (MPI_*_init / MPI_Start)
    def persistent(self, fn, *example_args, in_specs=None, out_specs=None, **spmd_kw):
        from jax.sharding import PartitionSpec as P

        tool.pvar_count("persistent_init")
        jitted = self.spmd(
            fn,
            in_specs=in_specs if in_specs is not None else P(),
            out_specs=out_specs if out_specs is not None else P(),
            **spmd_kw,
        )
        return PersistentRequest(jitted, example_args)

    Communicator.persistent = persistent


_bind()
