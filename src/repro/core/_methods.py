"""Paper-style method facade: binds the collective/future API onto
:class:`~repro.core.communicator.Communicator` so user code reads exactly
like the paper's examples::

    status = mpx.future(comm.immediate_broadcast(data, 0)) \
        .then(lambda f: ...) \
        .get()

Binding lives here (not in ``communicator.py``) to keep the functional core
import-cycle-free; counters for the MPI_T pvar interface are incremented at
this layer.
"""

from __future__ import annotations

import functools

from repro.analysis import events
from repro.core import collectives, datatypes, overlap, tool
from repro.core.communicator import Communicator
from repro.core.futures import (
    PersistentCollective,
    PersistentRequest,
    TraceFuture,
    argument_signature,
)


def _counted(name, fn):
    @functools.wraps(fn)
    def wrapper(*a, **k):
        tool.pvar_count(name)
        return fn(*a, **k)

    return wrapper


def _bind() -> None:
    # blocking collectives (chapter 6)
    for name in (
        "broadcast",
        "allreduce",
        "reduce",
        "reduce_scatter",
        "allgather",
        "gather",
        "scatter",
        "alltoall",
        "allgatherv",
        "alltoallv",
        "scan",
        "exscan",
        "send_recv",
        "shift",
        "barrier",
    ):
        fn = getattr(collectives, name)
        tool.pvar_register(name, f"blocking {name} calls issued (MPI_{name.capitalize()})")

        def method(self, *a, _fn=fn, _name=name, **k):
            tool.pvar_count(_name)
            if events.RECORDING and _name not in ("send_recv", "shift"):
                # send_recv/shift record a p2p matching round in
                # collectives.py instead (the deadlock checker's input)
                events.record_collective(self, _name, a[0] if a else None)
            return _fn(self, *a, **k)

        method.__name__ = name
        method.__doc__ = fn.__doc__
        setattr(Communicator, name, method)

    # immediate (future-returning) forms — requests as futures (C3)
    def immediate(self, name, *a, **k):
        fn = getattr(collectives, name)
        tool.pvar_count(f"immediate_{name}")
        if events.RECORDING and name not in ("send_recv", "shift"):
            events.record_collective(self, name, a[0] if a else None)
        return TraceFuture(lambda: fn(self, *a, **k), label=f"immediate_{name}")

    for name in (
        "broadcast",
        "allreduce",
        "reduce",
        "reduce_scatter",
        "allgather",
        "gather",
        "scatter",
        "alltoall",
        "scan",
        "exscan",
        "send_recv",
        "shift",
        "barrier",
    ):
        tool.pvar_register(
            f"immediate_{name}",
            f"nonblocking {name} futures issued (MPI_I{name.capitalize()})",
        )

        def imethod(self, *a, _name=name, **k):
            return immediate(self, _name, *a, **k)

        imethod.__name__ = f"immediate_{name}"
        imethod.__doc__ = (
            f"Nonblocking {name}: returns a TraceFuture (MPI_I{name.capitalize()})."
        )
        setattr(Communicator, f"immediate_{name}", imethod)

    # decomposed/overlappable forms
    tool.pvar_register("immediate_ring_allgather",
                       "ring-decomposed allgather futures (overlappable)")

    def immediate_ring_allgather(self, x, *, axis=0):
        tool.pvar_count("immediate_ring_allgather")
        if events.RECORDING:
            events.record_collective(self, "ring_allgather", x)
        return overlap.immediate_all_gather(self, x, axis=axis)

    Communicator.immediate_ring_allgather = immediate_ring_allgather

    # persistent operations (MPI_*_init / MPI_Start)
    def persistent(
        self,
        fn,
        *example_args,
        in_specs=None,
        out_specs=None,
        donate_argnums=(),
        warm_start=False,
        **spmd_kw,
    ):
        from jax.sharding import PartitionSpec as P

        jitted = self.spmd(
            fn,
            in_specs=in_specs if in_specs is not None else P(),
            out_specs=out_specs if out_specs is not None else P(),
            donate_argnums=tuple(donate_argnums),
            **spmd_kw,
        )
        return PersistentRequest(
            jitted, example_args, donate_argnums=tuple(donate_argnums),
            warm_start=warm_start,
        )

    persistent.__doc__ = (
        "Persistent operation over this communicator (``MPI_Send_init`` "
        "analogue): AOT-lower ``fn`` under :meth:`spmd` for the example "
        "argument list and return a :class:`PersistentRequest` whose "
        "``start()`` re-fires the compiled executable with zero re-tracing."
    )
    Communicator.persistent = persistent

    # persistent collectives (MPI_Allreduce_init & friends, MPI 4.0 §6.12):
    # AOT-lower one executable per dtype bucket of the example's datatype.
    def _persistent_collective(self, name, example, *, unpackable=True, **opkw):
        import jax

        fn = getattr(collectives, name)
        if isinstance(example, jax.ShapeDtypeStruct) or collectives._is_leaf_operand(
            example
        ):
            # single-array fast path: compile on the array's own shape
            aval = (
                example
                if isinstance(example, jax.ShapeDtypeStruct)
                else jax.ShapeDtypeStruct(jax.numpy.shape(example),
                                          jax.numpy.result_type(example))
            )
            jitted = self.spmd(lambda b, _fn=fn: _fn(self, b, **opkw))
            return PersistentCollective(
                name, None, [PersistentRequest(jitted, (aval,))]
            )
        dt = datatypes.datatype_of(example)
        requests = []
        for sds in dt.shape_dtype_structs():
            jitted = self.spmd(lambda b, _fn=fn: _fn(self, b, **opkw))
            requests.append(PersistentRequest(jitted, (sds,)))
        return PersistentCollective(
            name, dt, requests, unpackable=unpackable,
            signature=argument_signature(example),
        )

    def _bind_init(name, unpackable=True):
        tool.pvar_register(
            f"{name}_init",
            f"persistent {name} constructors (MPI_{name.capitalize()}_init)",
        )

        def init_method(self, example, _name=name, _u=unpackable, **k):
            tool.pvar_count(f"{_name}_init")
            return _persistent_collective(self, _name, example, unpackable=_u, **k)

        init_method.__name__ = f"{name}_init"
        init_method.__doc__ = (
            f"Persistent {name} (``MPI_{name.capitalize()}_init``): AOT-lower "
            f"one {name} per dtype bucket of ``example``'s datatype; "
            f"``start(value)`` re-fires the compiled executables."
        )
        setattr(Communicator, f"{name}_init", init_method)

    _bind_init("allreduce")
    _bind_init("alltoall")
    # shape-changing collectives return raw per-dtype buckets for aggregates
    _bind_init("reduce_scatter", unpackable=False)
    _bind_init("allgather", unpackable=False)

    # partitioned communication (MPI_Psend_init / MPI_Pready)
    def partitioned_allreduce(self, num_partitions, *, continuation=None):
        return overlap.partitioned_allreduce(
            self, num_partitions, continuation=continuation
        )

    partitioned_allreduce.__doc__ = overlap.partitioned_allreduce.__doc__
    Communicator.partitioned_allreduce = partitioned_allreduce


_bind()
