"""Trace-level collectives (paper §II, C1 — MPI 4.0 chapters 5–6).

Every MPI collective used by mpiBench (and the rest of chapter 6) is exposed
as a function over a :class:`~repro.core.communicator.Communicator`, usable
inside ``comm.spmd`` regions.  All of them accept either arrays or arbitrary
*compliant aggregates* (paper Listing 1): aggregates are packed through the
reflection system in :mod:`repro.core.datatypes` so one collective moves one
buffer per dtype group.

Lowering notes (the "hardware adaptation" of MPI semantics to XLA SPMD):

* rooted collectives (``broadcast``/``reduce``/``gather``) lower to their
  unrooted XLA forms (masked ``all-reduce`` / ``all-gather``) because XLA has
  no rooted collectives — the result is *replicated*, a strictly stronger
  guarantee at identical wire cost on a ring;
* ``scatter`` lowers to ``all-to-all`` + root row selection (1/n the bytes of
  a broadcast);
* vector (``v``) variants emulate raggedness with per-rank static counts +
  padding, because SPMD programs are shape-static by construction;
* ``send``/``recv`` pairs are expressed as :func:`send_recv` permutes
  (``collective-permute``): partner patterns must be trace-time static, the
  SPMD analogue of a matched send/recv.
"""

from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis import events as analysis_events
from repro.core import datatypes, errors
from repro.core.communicator import Communicator
from repro.core.descriptors import CollectiveSpec, ReduceOp, resolve

Axes = tuple[str, ...]


def _is_leaf_operand(x: Any) -> bool:
    return isinstance(x, (jax.Array, np.ndarray, np.generic, int, float, bool, complex))


def _check_root(comm: Communicator, root: int) -> None:
    errors.check(
        0 <= int(root) < comm.size(),
        errors.ErrorClass.ERR_ROOT,
        f"root {root} out of range for communicator of size {comm.size()}",
    )


def _single_axis(comm: Communicator) -> str:
    errors.check(
        len(comm.axis_names) == 1,
        errors.ErrorClass.ERR_TOPOLOGY,
        "this operation requires a single-axis communicator; use comm.split()",
    )
    return comm.axis_names[0]


# ---------------------------------------------------------------------------
# reduction kernels
# ---------------------------------------------------------------------------


def combine(op: ReduceOp, a: jax.Array, b: jax.Array) -> jax.Array:
    """Two-operand combine for a :class:`ReduceOp` — the binary form the
    gather-based fallbacks fold with, and the ``buffer ⊕ contribution`` step
    of RMA ``accumulate`` (:mod:`repro.core.onesided`).  Logical ops return
    booleans; callers preserve buffer dtypes themselves."""

    if op is ReduceOp.SUM:
        return a + b
    if op is ReduceOp.PROD:
        return a * b
    if op is ReduceOp.MAX:
        return jnp.maximum(a, b)
    if op is ReduceOp.MIN:
        return jnp.minimum(a, b)
    if op is ReduceOp.LAND:
        return (a != 0) & (b != 0)
    if op is ReduceOp.LOR:
        return (a != 0) | (b != 0)
    if op is ReduceOp.LXOR:
        return (a != 0) ^ (b != 0)
    if op is ReduceOp.BAND:
        return jnp.bitwise_and(a, b)
    if op is ReduceOp.BOR:
        return jnp.bitwise_or(a, b)
    if op is ReduceOp.BXOR:
        return jnp.bitwise_xor(a, b)
    errors.fail(errors.ErrorClass.ERR_OP, f"{op} has no two-operand combine")


def _reduce_array(x: jax.Array, axes: Axes, op: ReduceOp):
    x = jnp.asarray(x)
    if op is ReduceOp.SUM:
        if x.dtype == jnp.bool_:
            return lax.psum(x.astype(jnp.int32), axes) > 0
        return lax.psum(x, axes)
    if op is ReduceOp.MAX:
        return lax.pmax(x, axes)
    if op is ReduceOp.MIN:
        return lax.pmin(x, axes)
    if op is ReduceOp.LAND:
        return lax.pmin((x != 0).astype(jnp.uint8), axes) != 0
    if op is ReduceOp.LOR:
        return lax.pmax((x != 0).astype(jnp.uint8), axes) != 0
    if op is ReduceOp.LXOR:
        return (lax.psum((x != 0).astype(jnp.int32), axes) % 2) != 0
    # gather-based fallbacks (PROD and the bitwise family have no psum form)
    g = lax.all_gather(x, axes, axis=0, tiled=False)
    if op in (ReduceOp.PROD, ReduceOp.BAND, ReduceOp.BOR, ReduceOp.BXOR):
        return functools.reduce(functools.partial(combine, op), _unstack(g))
    errors.fail(errors.ErrorClass.ERR_OP, f"unsupported reduction {op}")


def _unstack(g: jax.Array) -> list[jax.Array]:
    return [g[i] for i in range(g.shape[0])]


# ---------------------------------------------------------------------------
# collectives
# ---------------------------------------------------------------------------


def broadcast(comm: Communicator, value: Any, root: int = 0, spec: CollectiveSpec | None = None):
    """``MPI_Bcast``: every rank receives root's value.

    Lowering: masked ``psum`` (zero everywhere but root), the standard SPMD
    broadcast.  Accepts compliant aggregates.
    """

    _check_root(comm, root)
    axes = comm.axis_names
    rank = comm.rank()

    def bcast_leaf(x: jax.Array):
        masked = jnp.where(rank == root, x, jnp.zeros_like(x))
        return _reduce_array(masked, axes, ReduceOp.SUM).astype(x.dtype)

    if _is_leaf_operand(value):
        return datatypes.apply_leafwise(bcast_leaf, value)
    return datatypes.apply_packed(bcast_leaf, value)


def allreduce(
    comm: Communicator,
    value: Any,
    op: ReduceOp = ReduceOp.SUM,
    spec: CollectiveSpec | None = None,
):
    """``MPI_Allreduce``."""

    spec = resolve(spec, op=op)
    axes = comm.axis_names

    def ar_leaf(x):
        return _reduce_array(x, axes, spec.op)

    if _is_leaf_operand(value):
        return datatypes.apply_leafwise(ar_leaf, value)
    return datatypes.apply_packed(ar_leaf, value)


def reduce(
    comm: Communicator,
    value: Any,
    root: int = 0,
    op: ReduceOp = ReduceOp.SUM,
    spec: CollectiveSpec | None = None,
):
    """``MPI_Reduce``.  The result is replicated (stronger than MPI's
    root-only guarantee; identical ring cost — see module docstring)."""

    _check_root(comm, root)
    return allreduce(comm, value, op=op, spec=spec)


def reduce_scatter(
    comm: Communicator,
    value: Any,
    op: ReduceOp = ReduceOp.SUM,
    spec: CollectiveSpec | None = None,
):
    """``MPI_Reduce_scatter_block``: reduce then split dim ``spec.axis``."""

    spec = resolve(spec, op=op)
    errors.check(
        spec.op is ReduceOp.SUM,
        errors.ErrorClass.ERR_OP,
        "reduce_scatter lowers to psum-scatter; only SUM is supported",
    )
    axes = comm.axis_names
    n = comm.size()

    def rs_leaf(x):
        x = jnp.asarray(x)
        errors.check(
            x.ndim > spec.axis and x.shape[spec.axis] % n == 0,
            errors.ErrorClass.ERR_COUNT,
            f"reduce_scatter axis {spec.axis} of shape {x.shape} not divisible by {n}",
        )
        return lax.psum_scatter(x, axes, scatter_dimension=spec.axis, tiled=True)

    if _is_leaf_operand(value):
        return datatypes.apply_leafwise(rs_leaf, value)
    # packed buffers are 1-D; scatter over dim 0
    def rs_packed(buf):
        errors.check(
            buf.shape[0] % n == 0,
            errors.ErrorClass.ERR_COUNT,
            "packed extent not divisible by communicator size",
        )
        return lax.psum_scatter(buf, axes, scatter_dimension=0, tiled=True)

    # NOTE: scattered aggregates cannot be unpacked (shape changed); return buffers.
    bufs, _ = datatypes.pack(value)
    return [rs_packed(b) for b in bufs]


def allgather(comm: Communicator, value: Any, spec: CollectiveSpec | None = None):
    """``MPI_Allgather``: concatenate (``tiled``) or stack ranks' values."""

    spec = resolve(spec)
    axes = comm.axis_names

    def ag_leaf(x):
        x = jnp.asarray(x)
        return lax.all_gather(x, axes, axis=spec.axis, tiled=spec.tiled)

    return datatypes.apply_leafwise(ag_leaf, value)


def gather(comm: Communicator, value: Any, root: int = 0, spec: CollectiveSpec | None = None):
    """``MPI_Gather`` (result replicated; see module docstring)."""

    _check_root(comm, root)
    return allgather(comm, value, spec=spec)


def scatter(comm: Communicator, value: Any, root: int = 0, spec: CollectiveSpec | None = None):
    """``MPI_Scatter``: rank ``i`` receives root's ``i``-th block along
    ``spec.axis``.  Lowering: ``all-to-all`` + root row selection."""

    _check_root(comm, root)
    spec = resolve(spec)
    axes = comm.axis_names
    n = comm.size()

    def sc_leaf(x):
        x = jnp.asarray(x)
        errors.check(
            x.ndim > spec.axis and x.shape[spec.axis] % n == 0,
            errors.ErrorClass.ERR_COUNT,
            f"scatter axis {spec.axis} of shape {x.shape} not divisible by {n}",
        )
        # rank r's row j goes to rank j; afterwards select the root's row.
        blocks = lax.all_to_all(
            x, axes, split_axis=spec.axis, concat_axis=spec.axis, tiled=True
        )
        block = x.shape[spec.axis] // n
        return lax.dynamic_slice_in_dim(blocks, root * block, block, axis=spec.axis)

    return datatypes.apply_leafwise(sc_leaf, value)


def alltoall(
    comm: Communicator,
    value: Any,
    split_axis: int = 0,
    concat_axis: int = 0,
    spec: CollectiveSpec | None = None,
):
    """``MPI_Alltoall``."""

    axes = comm.axis_names
    n = comm.size()

    def a2a_leaf(x):
        x = jnp.asarray(x)
        errors.check(
            x.shape[split_axis] % n == 0,
            errors.ErrorClass.ERR_COUNT,
            f"alltoall split axis {split_axis} of {x.shape} not divisible by {n}",
        )
        return lax.all_to_all(
            x, axes, split_axis=split_axis, concat_axis=concat_axis, tiled=True
        )

    return datatypes.apply_leafwise(a2a_leaf, value)


# -- vector (ragged) variants ------------------------------------------------


def allgatherv(comm: Communicator, value: jax.Array, counts: Sequence[int]):
    """``MPI_Allgatherv``: per-rank leading-dim counts (trace-time static).

    Each rank passes a buffer padded to ``max(counts)``; its valid prefix is
    ``counts[rank]``.  Returns the tight concatenation (static shape
    ``sum(counts)``) — raggedness via static counts, the SPMD idiom.
    """

    n = comm.size()
    errors.check(
        len(counts) == n,
        errors.ErrorClass.ERR_COUNT,
        f"counts has {len(counts)} entries for {n} ranks",
    )
    cmax = max(counts)
    x = jnp.asarray(value)
    errors.check(
        x.shape[0] == cmax,
        errors.ErrorClass.ERR_TRUNCATE,
        f"allgatherv buffers must be padded to max(counts)={cmax}, got {x.shape[0]}",
    )
    g = lax.all_gather(x, comm.axis_names, axis=0, tiled=False)  # (n, cmax, ...)
    pieces = [g[r, : counts[r]] for r in range(n)]
    return jnp.concatenate(pieces, axis=0)


def alltoallv(
    comm: Communicator,
    value: jax.Array,
    send_counts: Sequence[int],
):
    """``MPI_Alltoallv`` with a symmetric count matrix row (each rank sends
    ``send_counts[j]`` items to rank ``j``, padded blocks of ``max(counts)``).

    Returns ``(received, recv_counts)`` where ``received`` is the tight
    concatenation of the valid prefixes received from every peer.  Symmetric
    counts keep the pattern SPMD-static; asymmetric alltoallv would require
    per-rank programs (documented divergence).
    """

    n = comm.size()
    errors.check(
        len(send_counts) == n,
        errors.ErrorClass.ERR_COUNT,
        f"send_counts has {len(send_counts)} entries for {n} ranks",
    )
    cmax = max(send_counts)
    x = jnp.asarray(value)
    errors.check(
        x.shape[0] == n * cmax,
        errors.ErrorClass.ERR_TRUNCATE,
        f"alltoallv buffer must be (n*max_count, ...) = {n * cmax}, got {x.shape[0]}",
    )
    swapped = lax.all_to_all(x, comm.axis_names, split_axis=0, concat_axis=0, tiled=True)
    blocks = swapped.reshape((n, cmax) + swapped.shape[1:])
    pieces = [blocks[r, : send_counts[r]] for r in range(n)]
    return jnp.concatenate(pieces, axis=0), tuple(send_counts)


# -- prefix reductions --------------------------------------------------------


def scan(comm: Communicator, value: jax.Array, op: ReduceOp = ReduceOp.SUM):
    """``MPI_Scan`` (inclusive prefix reduction over ranks)."""

    return _prefix(comm, value, op, inclusive=True)


def exscan(comm: Communicator, value: jax.Array, op: ReduceOp = ReduceOp.SUM):
    """``MPI_Exscan`` (exclusive; rank 0 receives the identity)."""

    return _prefix(comm, value, op, inclusive=False)


def _prefix(comm: Communicator, value: jax.Array, op: ReduceOp, inclusive: bool):
    errors.check(
        op in (ReduceOp.SUM, ReduceOp.MAX, ReduceOp.MIN, ReduceOp.PROD),
        errors.ErrorClass.ERR_OP,
        f"scan does not support {op}",
    )
    x = jnp.asarray(value)
    rank = comm.rank()
    g = lax.all_gather(x, comm.axis_names, axis=0, tiled=False)  # (n, ...)
    n = comm.size()
    steps = jnp.arange(n).reshape((n,) + (1,) * x.ndim)
    limit = rank + 1 if inclusive else rank
    if op is ReduceOp.SUM:
        masked = jnp.where(steps < limit, g, jnp.zeros_like(g))
        return jnp.sum(masked, axis=0).astype(x.dtype)
    if op is ReduceOp.PROD:
        masked = jnp.where(steps < limit, g, jnp.ones_like(g))
        return jnp.prod(masked, axis=0).astype(x.dtype)
    if op is ReduceOp.MAX:
        fill = jnp.full_like(g, _type_min(x.dtype))
        return jnp.max(jnp.where(steps < limit, g, fill), axis=0)
    fill = jnp.full_like(g, _type_max(x.dtype))
    return jnp.min(jnp.where(steps < limit, g, fill), axis=0)


def _type_min(dtype):
    return (
        jnp.finfo(dtype).min if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).min
    )


def _type_max(dtype):
    return (
        jnp.finfo(dtype).max if jnp.issubdtype(dtype, jnp.floating) else jnp.iinfo(dtype).max
    )


# -- point-to-point -----------------------------------------------------------


def send_recv(
    comm: Communicator,
    value: Any,
    perm: Sequence[tuple[int, int]],
):
    """Matched ``MPI_Sendrecv``: rank ``s`` sends to ``d`` for each ``(s, d)``
    pair.  Ranks not receiving from anyone get zeros (the SPMD convention).
    Lowering: ``collective-permute``."""

    axis = _single_axis(comm)
    n = comm.size()
    for s, d in perm:
        errors.check(
            0 <= s < n and 0 <= d < n,
            errors.ErrorClass.ERR_RANK,
            f"send_recv pair ({s}, {d}) out of range for size {n}",
        )
    srcs = [s for s, _ in perm]
    errors.check(
        len(set(srcs)) == len(srcs),
        errors.ErrorClass.ERR_RANK,
        "a rank may send to at most one destination per send_recv",
    )
    if analysis_events.RECORDING:
        # the combined sendrecv form completes round-atomically — cycles are
        # legal here; the deadlock checker only rejects mode="sync" rounds
        analysis_events.record_p2p_round(comm, perm, mode="sendrecv", size=n)

    def p_leaf(x):
        return lax.ppermute(jnp.asarray(x), axis, list(map(tuple, perm)))

    if _is_leaf_operand(value):
        return datatypes.apply_leafwise(p_leaf, value)
    return datatypes.apply_packed(p_leaf, value)


def shift(comm: Communicator, value: Any, offset: int = 1, wrap: bool = True):
    """Ring shift (``MPI_Cart_shift`` + sendrecv): rank ``i`` sends to
    ``i + offset``."""

    n = comm.size()
    if wrap:
        perm = [(i, (i + offset) % n) for i in range(n)]
    else:
        perm = [(i, i + offset) for i in range(n) if 0 <= i + offset < n]
    return send_recv(comm, value, perm)


def barrier(comm: Communicator):
    """``MPI_Barrier``: a zero-byte all-reduce + optimization barrier, the
    SPMD synchronisation idiom (XLA's executional model already sequences
    collectives; the barrier pins program order)."""

    token = lax.psum(jnp.zeros((), jnp.float32), comm.axis_names)
    return lax.optimization_barrier(token)
