"""Trip-count-aware HLO cost analysis (the tool interface's deep pvar source).

``compiled.cost_analysis()`` counts each ``while`` body ONCE — verified with a
controlled experiment (scan of 10 matmuls reports 1/10th of the unrolled
flops).  Since every production model here scans its layer stack, both the
FLOP and the collective-byte roofline terms would be under-reported by ~the
layer count.  This module walks the post-optimization HLO computation graph,
multiplies loop bodies by their ``known_trip_count`` (emitted by XLA in
``backend_config``), and accumulates:

* ``flops`` — dot_general exactly (2 · |result| · K from the printed
  contracting dims), convolutions approximately, elementwise/reduce ops at
  1 flop per output element;
* ``bytes`` — operand + result bytes per materialising op (the HBM-traffic
  model ``HloCostAnalysis`` itself uses), excluding pure bookkeeping ops;
* ``collectives`` — per-kind counts / operand / result / ring-wire bytes
  (feeding the roofline collective term).

Raw ``cost_analysis()`` numbers are still recorded next to these for
comparison; EXPERIMENTS.md documents the discrepancy.
"""

from __future__ import annotations

import dataclasses
import re

from repro.core.tool import (
    COLLECTIVE_KINDS,
    CollectiveStats,
    _group_size,
    _line_shapes,
    _wire_factor,
)

# ops that move no data of their own
_BOOKKEEPING = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "opt-barrier", "partition-id", "replica-id", "iota",
}

# elementwise-ish ops costed at 1 flop / output element
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "rsqrt", "sqrt", "negate", "abs", "sign", "cosine", "sine", "logistic",
    "floor", "ceil", "round-nearest-afz", "select", "clamp", "compare",
    "and", "or", "xor", "not", "remainder", "atan2", "cbrt", "erf",
}

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{\s*$")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"\b([a-z][a-z0-9\-]*)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\s*{\s*"n":\s*"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_elems(segment: str) -> float:
    """Total element count of every shape token in ``segment``."""

    total = 0.0
    for m in re.finditer(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]", segment):
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


@dataclasses.dataclass
class _Line:
    name: str
    op: str
    result_bytes: float
    result_elems: float
    operand_names: list[str]
    operand_inline_bytes: float
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    lines: list[_Line]
    shapes_bytes: dict[str, float]      # result bytes by value name
    shapes_dims: dict[str, list[int]]   # result dims by value name
    param_names: dict[int, str] = dataclasses.field(default_factory=dict)

    def effective_param_read(self, index: int, full_bytes: float) -> float:
        """Bytes a callee actually reads from parameter ``index``: if every
        use is a dynamic-slice (the scan weight-slicing pattern), only the
        slices are streamed from HBM, not the stacked buffer."""

        pname = self.param_names.get(index)
        if pname is None:
            return full_bytes
        uses = [l for l in self.lines if pname in l.operand_names]
        if not uses:
            return full_bytes
        if all(u.op == "dynamic-slice" for u in uses):
            return sum(u.result_bytes for u in uses)
        return full_bytes


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: CollectiveStats = dataclasses.field(default_factory=CollectiveStats)

    def scaled(self, k: float) -> "HloCost":
        out = HloCost(self.flops * k, self.bytes * k)
        for kind in self.collectives.count:
            out.collectives.count[kind] = int(self.collectives.count[kind] * k)
            out.collectives.operand_bytes[kind] = self.collectives.operand_bytes[kind] * k
            out.collectives.result_bytes[kind] = self.collectives.result_bytes[kind] * k
            out.collectives.wire_bytes[kind] = self.collectives.wire_bytes[kind] * k
        return out

    def add(self, other: "HloCost") -> None:
        self.flops += other.flops
        self.bytes += other.bytes
        for kind in other.collectives.count:
            self.collectives.count[kind] += other.collectives.count[kind]
            self.collectives.operand_bytes[kind] += other.collectives.operand_bytes[kind]
            self.collectives.result_bytes[kind] += other.collectives.result_bytes[kind]
            self.collectives.wire_bytes[kind] += other.collectives.wire_bytes[kind]


def _first_dims(segment: str) -> list[int]:
    m = re.search(r"\b[a-z][a-z0-9]*\[([0-9,]*)\]", segment)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


def parse_computations(hlo: str) -> tuple[dict[str, Computation], str | None]:
    comps: dict[str, Computation] = {}
    entry: str | None = None
    cur: Computation | None = None
    for raw in hlo.splitlines():
        header = _COMP_HEADER_RE.match(raw.strip()) if raw.rstrip().endswith("{") else None
        if header and not raw.startswith(" " * 4) and "=" not in raw.split("(")[0]:
            cur = Computation(header.group(1), [], {}, {})
            comps[cur.name] = cur
            if raw.lstrip().startswith("ENTRY"):
                entry = cur.name
            # computation parameters carry inline shapes in the signature
            # (split on depth-0 commas: tuple-typed params nest parens, and
            # shape/layout tokens nest brackets/braces — f32[256,512]{1,0})
            depth, parts, token = 0, [], ""
            for ch in header.group(2):
                if ch in "([{":
                    depth += 1
                elif ch in ")]}":
                    depth -= 1
                if ch == "," and depth == 0:
                    parts.append(token)
                    token = ""
                else:
                    token += ch
            if token.strip():
                parts.append(token)
            for part in parts:
                if ":" not in part:
                    continue
                pname, ptype = part.split(":", 1)
                pname = pname.strip().lstrip("%")
                cur.shapes_bytes[pname] = sum(_line_shapes(ptype))
                cur.shapes_dims[pname] = _first_dims(ptype)
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        opm = _OP_RE.search(rhs)
        if not opm:
            continue
        op = opm.group(1)
        head = rhs[: rhs.find("(")] if "(" in rhs else rhs
        result_bytes = sum(_line_shapes(head))
        result_elems = _shape_elems(head)
        cur.shapes_bytes[name] = result_bytes
        cur.shapes_dims[name] = _first_dims(head)
        # split the op's top-level argument list (depth counts brackets and
        # braces too, so f32[256,512]{1,0} operand tokens stay whole)
        depth, args, token = 1, [], ""
        for ch in rhs[opm.end():]:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                args.append(token)
                token = ""
            else:
                token += ch
        if token.strip():
            args.append(token)
        names, inline = [], 0.0
        for a in args:
            a = a.strip()
            sh = _line_shapes(a)
            if sh:
                inline += sum(sh)
            nm = re.search(r"%([\w.\-]+)", a)
            if nm:
                names.append(nm.group(1))
            elif not sh and a:
                names.append(a)
        if op == "parameter":
            pm = re.search(r"parameter\((\d+)\)", rhs)
            if pm:
                cur.param_names[int(pm.group(1))] = name
        cur.lines.append(_Line(name, op, result_bytes, result_elems, names, inline, rhs))
    return comps, entry


def _dot_flops(line: _Line, comp: Computation) -> float:
    k = 1.0
    m = _CONTRACT_RE.search(line.raw)
    lhs_dims = (
        comp.shapes_dims.get(line.operand_names[0], []) if line.operand_names else []
    )
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * line.result_elems * k


def _operand_bytes(line: _Line, comp: Computation) -> float:
    total = line.operand_inline_bytes
    if not total:
        for nm in line.operand_names:
            total += comp.shapes_bytes.get(nm, 0.0)
    return total


def _analyze_comp(name: str, comps: dict[str, Computation], memo: dict[str, HloCost],
                  default_group: int) -> HloCost:
    if name in memo:
        return memo[name]
    memo[name] = HloCost()  # cycle guard
    comp = comps.get(name)
    if comp is None:
        return memo[name]
    cost = HloCost()
    for line in comp.lines:
        op = line.op
        if op in _BOOKKEEPING:
            continue
        kind = None
        for ck in COLLECTIVE_KINDS:
            if op == ck or op == ck + "-start":
                kind = ck
                break
        if kind is not None:
            ob = _operand_bytes(line, comp)
            n = _group_size(line.raw, default_group)
            payload = ob if kind in (
                "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"
            ) else line.result_bytes
            cost.collectives.count[kind] += 1
            cost.collectives.operand_bytes[kind] += ob
            cost.collectives.result_bytes[kind] += line.result_bytes
            cost.collectives.wire_bytes[kind] += payload * _wire_factor(kind, n)
            cost.bytes += ob + line.result_bytes
            continue
        if op == "while":
            trip = 1
            tm = _TRIP_RE.search(line.raw)
            if tm:
                trip = int(tm.group(1))
            body = _BODY_RE.search(line.raw)
            cond = _COND_RE.search(line.raw)
            inner = HloCost()
            if body:
                inner.add(_analyze_comp(body.group(1), comps, memo, default_group))
            if cond:
                inner.add(_analyze_comp(cond.group(1), comps, memo, default_group))
            cost.add(inner.scaled(trip))
            continue
        if op in ("fusion", "call", "async-start"):
            # a fusion's internals never touch HBM: take the callee's flops
            # and collectives, but charge only the fusion's own boundary
            # bytes — and for operands the callee merely dynamic-slices
            # (scan weight slicing), charge the slices, not the buffer.
            cm = _CALLS_RE.search(line.raw)
            callee = comps.get(cm.group(1)) if cm else None
            if cm:
                inner = _analyze_comp(cm.group(1), comps, memo, default_group)
                boundary = HloCost(inner.flops, 0.0)
                boundary.collectives = inner.collectives
                cost.add(boundary)
            if callee is not None and callee.param_names:
                for i, nm in enumerate(line.operand_names):
                    full = comp.shapes_bytes.get(nm, 0.0)
                    cost.bytes += callee.effective_param_read(i, full)
                cost.bytes += line.operand_inline_bytes + line.result_bytes
            else:
                cost.bytes += _operand_bytes(line, comp) + line.result_bytes
            continue
        if op == "conditional":
            bm = _BRANCHES_RE.search(line.raw)
            if bm:
                branches = [b.strip().lstrip("%") for b in bm.group(1).split(",")]
                sub = [_analyze_comp(b, comps, memo, default_group) for b in branches]
                if sub:
                    worst = max(sub, key=lambda c: c.flops + c.bytes)
                    cost.add(worst)
            continue
        if op == "dot":
            cost.flops += _dot_flops(line, comp)
            cost.bytes += _operand_bytes(line, comp) + line.result_bytes
            continue
        if op == "convolution":
            ob = _operand_bytes(line, comp)
            # depthwise/short-window convs here: approximate via window product
            wm = re.search(r"window=\{size=([0-9x]+)", line.raw)
            k = 1.0
            if wm:
                for d in wm.group(1).split("x"):
                    k *= int(d)
            cost.flops += 2.0 * line.result_elems * k
            cost.bytes += ob + line.result_bytes
            continue
        if op in ("reduce", "reduce-window"):
            # one flop per reduced input element
            in_dims = comp.shapes_dims.get(line.operand_names[0], []) if line.operand_names else []
            n_in = 1.0
            for d in in_dims:
                n_in *= d
            cost.flops += max(n_in, line.result_elems)
            cost.bytes += _operand_bytes(line, comp) + line.result_bytes
            continue
        if op in _ELEMENTWISE:
            cost.flops += line.result_elems
            cost.bytes += _operand_bytes(line, comp) + line.result_bytes
            continue
        if op == "dynamic-update-slice":
            # in-place DUS: traffic is the update region, not the buffer
            upd = (
                comp.shapes_bytes.get(line.operand_names[1], line.result_bytes)
                if len(line.operand_names) > 1
                else line.result_bytes
            )
            cost.bytes += 2.0 * upd
            continue
        if op == "dynamic-slice":
            cost.bytes += 2.0 * line.result_bytes
            continue
        # everything else (copy, transpose, reshape, broadcast, gather,
        # scatter, sort, rng, ...) moves bytes only
        cost.bytes += _operand_bytes(line, comp) + line.result_bytes
    memo[name] = cost
    return cost


def analyze_hlo(hlo: str, default_group: int = 1) -> HloCost:
    """Trip-count-corrected (flops, bytes, collectives) for one HLO module."""

    comps, entry = parse_computations(hlo)
    memo: dict[str, HloCost] = {}
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda n: len(comps[n].lines)) if comps else ""
    # subtract: called computations are reachable from entry; analyze entry only
    return _analyze_comp(entry, comps, memo, default_group)
