"""Virtual process topologies & neighborhood collectives (MPI 4.0 ch. 8).

Chapter 8 gives MPI programs *structured* rank spaces: Cartesian grids
(``MPI_Cart_create`` + ``cart_shift``/``cart_sub``/``cart_coords``) and
distributed graphs (``MPI_Dist_graph_create_adjacent``), and — since MPI 3 —
**neighborhood collectives** whose traffic follows the declared topology
instead of the dense world: ``MPI_Neighbor_allgather`` / ``_alltoall`` /
``_alltoallv``.  On a pod this is the natural spelling of the sparse,
neighbor-structured traffic that dominates pipeline and expert parallelism:
a pipeline stage talks to ``cart_shift(+1)``; an MoE rank talks to the ranks
owning the experts its router can reach.

Adaptation to the XLA substrate:

* A :class:`CartComm` folds a :class:`~repro.core.session.Group` onto a
  ``dims`` grid **through the group algebra**:  ``cart_create`` carves
  ``group.incl(range(prod(dims)))`` out of the parent (excess ranks get no
  membership, MPI's ``MPI_COMM_NULL`` for them), registers the grid as a
  session process set (``repro://cart/<dims>``) and hands the group to
  :meth:`~repro.core.communicator.Communicator.from_group` — the canonical
  constructor stays canonical.  ``reorder=True`` is accepted but performs no
  renumbering: under jax, logical-rank→device binding is fixed by the mesh,
  so reorder could only relabel, never migrate data (see DESIGN.md).
* ``cart_shift`` is host-level: it returns the full source/destination
  tables (``PROC_NULL`` at non-periodic boundaries) *and* the trace-time
  static permutation lists that lower to ``collective-permute`` — per-axis
  pairs for single-dim shifts, so a shift over one cart dimension of a
  multi-dim grid emits a subgroup permute, never a world-sized collective.
* Neighborhood collectives return :class:`~repro.core.futures.TraceFuture`\\ s
  and chain ``then()`` / :func:`~repro.core.futures.when_all` into the C3
  request engine exactly like ``immediate_*`` collectives; the persistent
  ``neighbor_alltoall_init`` AOT-compiles one executable per dtype bucket
  (the :class:`~repro.core.futures.PersistentCollective` pattern).
* Lowering is **sparse by construction**: a Cartesian neighborhood is
  ``2·ndims`` axis-local permutes; a distributed graph is decomposed into
  matchings (edge-colouring) of its edge set, one ``collective-permute``
  per matching round.  ``benchmarks/hlo_parity.py`` checks the compiled
  artifact contains no dense ``all-to-all``.

SPMD shape rules (all divergences documented, none silent): every rank runs
the same program, so neighbor buffers are padded to the *maximum* in/out
degree over ranks — ``PROC_NULL`` slots and absent edges read as zeros, and
``neighbor_alltoallv`` returns the per-rank valid counts as a trace-level
vector next to the padded blocks.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.analysis import events as analysis_events
from repro.core import datatypes, errors, tool
from repro.core.communicator import Communicator
from repro.core.futures import (
    PersistentCollective,
    PersistentRequest,
    TraceFuture,
    argument_signature,
)
from repro.core.session import CART_PSET_PREFIX, Group, default_session

#: ``MPI_PROC_NULL``: the non-existent neighbor beyond a non-periodic edge.
PROC_NULL = -1


# ---------------------------------------------------------------------------
# host-level cart arithmetic (testable without devices)
# ---------------------------------------------------------------------------


def cart_coords_of(dims: Sequence[int], rank: int) -> tuple[int, ...]:
    """``MPI_Cart_coords``: row-major coordinates of ``rank`` in ``dims``."""

    n = math.prod(dims)
    errors.check(
        0 <= rank < n,
        errors.ErrorClass.ERR_RANK,
        f"rank {rank} out of range for cart grid {tuple(dims)}",
    )
    return tuple(int(c) for c in np.unravel_index(rank, tuple(dims)))


def cart_rank_of(
    dims: Sequence[int], periods: Sequence[bool], coords: Sequence[int]
) -> int:
    """``MPI_Cart_rank``: periodic dims wrap; out-of-range coordinates on a
    non-periodic dim are erroneous (``ERR_RANK``, as in the standard)."""

    errors.check(
        len(coords) == len(dims),
        errors.ErrorClass.ERR_DIMS,
        f"{len(coords)} coordinates for a {len(dims)}-dim grid",
    )
    fixed = []
    for c, d, p in zip(coords, dims, periods):
        c = int(c)
        if p:
            c %= d
        errors.check(
            0 <= c < d,
            errors.ErrorClass.ERR_RANK,
            f"coordinate {c} out of range for non-periodic dim of size {d}",
        )
        fixed.append(c)
    return int(np.ravel_multi_index(tuple(fixed), tuple(dims)))


def cart_shift_tables(
    dims: Sequence[int], periods: Sequence[bool], dim: int, disp: int = 1
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """``MPI_Cart_shift``: per-rank ``(sources, destinations)`` tables.

    ``sources[r]`` is the rank whose data arrives at ``r`` under the shift
    (``MPI_Cart_shift``'s ``rank_source``), ``destinations[r]`` where ``r``'s
    data goes; :data:`PROC_NULL` beyond a non-periodic boundary.
    """

    dims = tuple(int(d) for d in dims)
    errors.check(
        0 <= dim < len(dims),
        errors.ErrorClass.ERR_DIMS,
        f"shift dimension {dim} out of range for {len(dims)}-dim grid",
    )
    n = math.prod(dims)
    srcs, dsts = [], []
    for r in range(n):
        coords = list(cart_coords_of(dims, r))

        def _neighbor(offset: int) -> int:
            c = coords[dim] + offset
            if periods[dim]:
                c %= dims[dim]
            elif not (0 <= c < dims[dim]):
                return PROC_NULL
            nc = list(coords)
            nc[dim] = c
            return int(np.ravel_multi_index(tuple(nc), dims))

        dsts.append(_neighbor(disp))
        srcs.append(_neighbor(-disp))
    return tuple(srcs), tuple(dsts)


@dataclasses.dataclass(frozen=True)
class CartShift:
    """The result of :meth:`CartComm.cart_shift`.

    * ``sources`` / ``destinations`` — host tables, rank-indexed, with
      :data:`PROC_NULL` at non-periodic boundaries (``MPI_Cart_shift``'s two
      output ranks, for every rank at once — the SPMD program needs the full
      pattern, not one rank's view).
    * ``perm`` — flat-rank ``(src, dst)`` pairs for
      :func:`repro.core.collectives.send_recv` over the whole communicator.
    * ``axis_name`` / ``axis_perm`` — the same shift as *axis-local* pairs
      over just the shifted mesh axis: ``lax.ppermute(x, axis_name,
      axis_perm)`` lowers to a subgroup ``collective-permute`` (every color
      of the other axes shifts in the same program).
    """

    dim: int
    disp: int
    sources: tuple[int, ...]
    destinations: tuple[int, ...]
    perm: tuple[tuple[int, int], ...]
    axis_name: str
    axis_perm: tuple[tuple[int, int], ...]


# ---------------------------------------------------------------------------
# graph adjacency + matching decomposition (the sparse lowering engine)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Edge:
    src: int
    dst: int
    out_slot: int  # position in src's destination list
    in_slot: int   # position in dst's source list


def _matching_rounds(edges: Sequence[_Edge]) -> list[list[_Edge]]:
    """Greedy edge-colouring: split the edge set into rounds where every
    rank appears at most once as a source and once as a destination — the
    legality condition of one ``collective-permute``.  Round count is
    bounded by ~max degree (Vizing), the sparse analogue of the dense
    collective's O(world) steps."""

    rounds: list[tuple[set, set, list[_Edge]]] = []
    for e in edges:
        for srcs, dsts, members in rounds:
            if e.src not in srcs and e.dst not in dsts:
                srcs.add(e.src)
                dsts.add(e.dst)
                members.append(e)
                break
        else:
            rounds.append(({e.src}, {e.dst}, [e]))
    return [members for _, _, members in rounds]


def _build_edges(
    sources: Sequence[Sequence[int]], destinations: Sequence[Sequence[int]]
) -> list[_Edge]:
    """Pair every declared out-edge with its matching in-edge.  Repeated
    edges pair by occurrence order (k-th ``s`` in ``sources[d]`` matches the
    k-th ``d`` in ``destinations[s]``); a declaration present on one side
    only is ``ERR_TOPOLOGY`` — both endpoints of an edge must agree, exactly
    as ``MPI_Dist_graph_create_adjacent`` requires."""

    taken: dict[tuple[int, int], int] = {}
    edges: list[_Edge] = []
    for s, dsts in enumerate(destinations):
        for out_slot, d in enumerate(dsts):
            if d == PROC_NULL:
                continue
            occurrence = taken.get((s, d), 0)
            taken[(s, d)] = occurrence + 1
            matches = [j for j, x in enumerate(sources[d]) if x == s]
            errors.check(
                occurrence < len(matches),
                errors.ErrorClass.ERR_TOPOLOGY,
                f"edge {s}->{d} declared in destinations[{s}] but rank {d} "
                f"lists only {len(matches)} in-edges from {s}",
            )
            edges.append(_Edge(s, d, out_slot, matches[occurrence]))
    # the reverse check: every declared in-edge was produced by an out-edge
    for d, srcs in enumerate(sources):
        for s in srcs:
            if s == PROC_NULL:
                continue
            declared = sum(1 for x in destinations[s] if x == d)
            listed = sum(1 for x in srcs if x == s)
            errors.check(
                declared == listed,
                errors.ErrorClass.ERR_TOPOLOGY,
                f"rank {d} lists {listed} in-edges from {s} but rank {s} "
                f"declares {declared} out-edges to {d}",
            )
    return edges


def cart_edges(
    dims: Sequence[int], periods: Sequence[bool]
) -> list[_Edge]:
    """The Cartesian neighbor edge set with its slot pairing made explicit:
    the out-slot ``2d`` (−) send lands in the receiver's + slot (``2d+1``)
    and vice versa.  The generic occurrence-order pairing of
    :func:`_build_edges` would get this wrong exactly when both slots of a
    dim name the same rank (size-2 or size-1 periodic dims),
    desynchronising the neighbor_alltoallv recv-count table from the
    physical exchange."""

    dims = tuple(int(d) for d in dims)
    n = math.prod(dims)
    edges: list[_Edge] = []
    for dim in range(len(dims)):
        sources, destinations = cart_shift_tables(dims, periods, dim, 1)
        for r in range(n):
            if destinations[r] != PROC_NULL:
                edges.append(_Edge(r, destinations[r], 2 * dim + 1, 2 * dim))
            if sources[r] != PROC_NULL:
                edges.append(_Edge(r, sources[r], 2 * dim, 2 * dim + 1))
    return edges


class _NeighborComm(Communicator):
    """Shared engine: a communicator with a neighbor structure.

    Subclasses populate ``_sources`` / ``_destinations`` (per-rank ordered
    neighbor slot lists, :data:`PROC_NULL` allowed) and the derived matching
    ``_rounds``; the neighborhood collectives below are generic over them.
    """

    _sources: tuple[tuple[int, ...], ...]
    _destinations: tuple[tuple[int, ...], ...]
    _rounds: list[list[_Edge]]

    # -- degrees ------------------------------------------------------------

    def indegree(self, rank: int | None = None) -> int:
        """Neighbor slots on the receive side (``PROC_NULL`` slots count:
        the buffer keeps their position, as in MPI cart neighborhoods)."""

        if rank is None:
            return max(len(s) for s in self._sources)
        return len(self._sources[rank])

    def outdegree(self, rank: int | None = None) -> int:
        if rank is None:
            return max(len(d) for d in self._destinations)
        return len(self._destinations[rank])

    # -- the exchange kernel -------------------------------------------------

    def _round_tables(self):
        n = self.size()
        tables = []
        for round_edges in self._rounds:
            out_slot = np.full((n,), -1, np.int32)
            in_slot = np.full((n,), -1, np.int32)
            perm = []
            for e in round_edges:
                out_slot[e.src] = e.out_slot
                in_slot[e.dst] = e.in_slot
                perm.append((e.src, e.dst))
            tables.append((out_slot, in_slot, tuple(perm)))
        return tables

    def _exchange(self, x: jax.Array, *, alltoall: bool) -> jax.Array:
        """One neighborhood exchange: per matching round, each rank selects
        its block (slot slice for alltoall, the whole buffer for allgather),
        one ``collective-permute`` moves the round's edges, and receivers
        scatter the arrival into the in-slot.  Non-participants are masked
        by the ``-1`` table entries; ``PROC_NULL`` slots stay zero."""

        x = jnp.asarray(x)
        d_in = self.indegree()
        if alltoall:
            errors.check(
                x.ndim >= 1 and x.shape[0] == self.outdegree(),
                errors.ErrorClass.ERR_COUNT,
                f"neighbor_alltoall buffer needs leading dim {self.outdegree()}"
                f" (max outdegree), got {tuple(x.shape)}",
            )
            block_shape = x.shape[1:]
        else:
            block_shape = x.shape
        out = jnp.zeros((d_in,) + tuple(block_shape), x.dtype)
        if not self._rounds:
            return out
        rank = self.rank()
        axes = self.axis_names if len(self.axis_names) > 1 else self.axis_names[0]
        for out_slot, in_slot, perm in self._round_tables():
            if analysis_events.RECORDING:
                analysis_events.record_p2p_round(
                    self, perm, mode="sendrecv", op="neighbor_exchange")
            if alltoall:
                osl = jnp.asarray(out_slot)[rank]
                send = lax.dynamic_index_in_dim(
                    x, jnp.maximum(osl, 0), axis=0, keepdims=False
                )
            else:
                send = x
            arrived = lax.ppermute(send, axes, list(perm))
            isl = jnp.asarray(in_slot)[rank]
            safe = jnp.maximum(isl, 0)
            cur = lax.dynamic_index_in_dim(out, safe, axis=0, keepdims=False)
            out = lax.dynamic_update_index_in_dim(
                out, jnp.where(isl >= 0, arrived, cur), safe, axis=0
            )
        return out

    # -- neighborhood collectives (TraceFutures, C3 engine) ------------------

    def neighbor_allgather(self, value: Any) -> TraceFuture:
        """``MPI_Neighbor_allgather``: each rank receives its in-neighbors'
        buffers, stacked ``(max_indegree, *shape)`` in neighbor-slot order
        (zeros at ``PROC_NULL`` / absent slots).  Lazily forced — a
        :class:`TraceFuture` chaining into ``then()``/``when_all``."""

        tool.pvar_count("neighbor_allgather")
        return TraceFuture(lambda: self._exchange(value, alltoall=False),
                           label="neighbor_allgather")

    def neighbor_alltoall(self, value: Any) -> TraceFuture:
        """``MPI_Neighbor_alltoall``: block ``k`` of ``value`` (leading dim
        = max outdegree) goes to out-neighbor ``k``; the result's slot ``j``
        holds the block sent by in-neighbor ``j``."""

        tool.pvar_count("neighbor_alltoall")
        return TraceFuture(lambda: self._exchange(value, alltoall=True),
                           label="neighbor_alltoall")

    def neighbor_alltoallv(
        self, value: Any, send_counts: Sequence[Sequence[int]] | Sequence[int]
    ) -> TraceFuture:
        """``MPI_Neighbor_alltoallv`` with trace-time static counts.

        ``send_counts`` is per-rank per-out-slot (``counts[rank][slot]``), or
        one shared per-slot row applied to every rank.  Buffers are padded
        blocks ``(max_outdegree, max_count, ...)``; the future resolves to
        ``(blocks, recv_counts)`` where ``blocks`` is the padded
        ``(max_indegree, max_count, ...)`` receive buffer (entries beyond
        the valid count zeroed) and ``recv_counts`` the per-slot valid
        counts for *this* rank as a trace-level vector — raggedness via
        static counts, the SPMD idiom (see ``collectives.alltoallv``).
        """

        tool.pvar_count("neighbor_alltoallv")
        n, d_out, d_in = self.size(), self.outdegree(), self.indegree()
        counts = np.asarray(send_counts, dtype=np.int64)
        if counts.ndim == 1:
            counts = np.tile(counts, (n, 1))
        errors.check(
            counts.shape == (n, d_out),
            errors.ErrorClass.ERR_COUNT,
            f"send_counts must be ({n}, {d_out}) (ranks x max outdegree), "
            f"got {counts.shape}",
        )
        errors.check(
            bool((counts >= 0).all()),
            errors.ErrorClass.ERR_COUNT,
            "send_counts must be non-negative",
        )
        cmax = int(counts.max()) if counts.size else 0
        # receive counts: slot j of rank d gets the count its in-edge's
        # source declared for the matching out-slot
        recv = np.zeros((n, d_in), np.int32)
        for round_edges in self._rounds:
            for e in round_edges:
                recv[e.dst, e.in_slot] = counts[e.src, e.out_slot]

        def impl():
            x = jnp.asarray(value)
            errors.check(
                x.ndim >= 2 and x.shape[:2] == (d_out, cmax),
                errors.ErrorClass.ERR_TRUNCATE,
                f"neighbor_alltoallv buffer must be padded to "
                f"({d_out}, {cmax}, ...), got {tuple(x.shape)}",
            )
            blocks = self._exchange(x, alltoall=True)
            rc = jnp.asarray(recv)[self.rank()]                  # (d_in,)
            valid = jnp.arange(cmax)[None, :] < rc[:, None]      # (d_in, cmax)
            mask = valid.reshape(valid.shape + (1,) * (blocks.ndim - 2))
            return jnp.where(mask, blocks, jnp.zeros_like(blocks)), rc

        return TraceFuture(impl, label="neighbor_alltoallv")

    # -- persistent neighborhood collectives (MPI 4.0 §6.12 pattern) ---------

    def neighbor_alltoall_init(self, example: Any) -> PersistentCollective:
        """Persistent ``neighbor_alltoall`` (``MPI_Neighbor_alltoall_init``):
        AOT-lower one exchange per dtype bucket of ``example``'s datatype;
        ``start(value)`` re-fires the compiled executables with zero
        re-tracing.  Aggregate buckets are split into ``max_outdegree``
        equal chunks (``ERR_COUNT`` if a bucket does not divide); the
        reassembled aggregate is only returned when in/out degrees match
        (the exchange is shape-preserving then), raw buckets otherwise.
        """

        tool.pvar_count("neighbor_alltoall_init")
        d_out, d_in = self.outdegree(), self.indegree()

        def fire(b):
            return self.neighbor_alltoall(b).get()

        if isinstance(example, jax.ShapeDtypeStruct) or isinstance(
            example, (jax.Array, np.ndarray)
        ):
            aval = (
                example
                if isinstance(example, jax.ShapeDtypeStruct)
                else jax.ShapeDtypeStruct(np.shape(example), example.dtype)
            )
            jitted = self.spmd(fire)
            return PersistentCollective(
                "neighbor_alltoall", None, [PersistentRequest(jitted, (aval,))]
            )
        dt = datatypes.datatype_of(example)
        requests = []
        for sds in dt.shape_dtype_structs():
            extent = int(np.prod(sds.shape))
            errors.check(
                extent % d_out == 0,
                errors.ErrorClass.ERR_COUNT,
                f"packed bucket extent {extent} not divisible by the "
                f"outdegree {d_out}",
            )

            def bucket_fire(b, _shape=sds.shape):
                out = fire(b.reshape((d_out, -1) + _shape[1:]))
                return out.reshape((-1,) + _shape[1:])

            jitted = self.spmd(bucket_fire)
            requests.append(PersistentRequest(jitted, (sds,)))
        return PersistentCollective(
            "neighbor_alltoall",
            dt,
            requests,
            unpackable=(d_in == d_out),
            signature=argument_signature(example),
        )


# ---------------------------------------------------------------------------
# Cartesian topology
# ---------------------------------------------------------------------------


class CartComm(_NeighborComm):
    """``MPI_Cart_create`` result: a communicator whose ranks live on a
    ``dims`` grid with per-dim periodicity.

    The neighbor structure (for the neighborhood collectives) follows the
    standard's cart convention: ``2·ndims`` slots ordered (dim 0 −, dim 0 +,
    dim 1 −, …); ``PROC_NULL`` slots at non-periodic boundaries stay in the
    buffer and read as zeros.  Exchanges lower to one *axis-local* permute
    per (dim, direction) — subgroup ``collective-permute``\\ s, independent
    of world size.
    """

    def __init__(
        self,
        mesh,
        axis_names,
        *,
        dims: Sequence[int],
        periods: Sequence[bool],
        managed: bool = False,
        tag: str = "",
    ):
        super().__init__(mesh, axis_names, managed=managed, tag=tag)
        self.dims = tuple(int(d) for d in dims)
        self.periods = tuple(bool(p) for p in periods)
        errors.check(
            len(self.dims) == len(self.periods) == len(self.axis_names),
            errors.ErrorClass.ERR_DIMS,
            f"dims {self.dims}, periods {self.periods} and axes "
            f"{self.axis_names} must have equal length",
        )
        for d, a in zip(self.dims, self.axis_names):
            errors.check(
                mesh.shape[a] == d,
                errors.ErrorClass.ERR_DIMS,
                f"cart dim {d} does not match mesh axis {a!r} "
                f"of size {mesh.shape[a]}",
            )
        n = self.size()
        # per-dim shift tables are rank-independent: compute once per dim
        shifts = [
            cart_shift_tables(self.dims, self.periods, dim, 1)
            for dim in range(len(self.dims))
        ]
        srcs, dsts = [], []
        for r in range(n):
            s_r, d_r = [], []
            for sources, destinations in shifts:
                # slot order per MPI: (dim −, dim +): the − slot receives
                # from the lower neighbor, i.e. the +1 shift's source
                s_r += [sources[r], destinations[r]]
                d_r += [sources[r], destinations[r]]
            srcs.append(tuple(s_r))
            dsts.append(tuple(d_r))
        self._sources = tuple(srcs)
        self._destinations = tuple(dsts)
        self._rounds = _matching_rounds(cart_edges(self.dims, self.periods))

    # -- cart queries -------------------------------------------------------

    @property
    def ndims(self) -> int:
        """``MPI_Cartdim_get``."""

        return len(self.dims)

    def cart_coords(self, rank: int) -> tuple[int, ...]:
        """``MPI_Cart_coords``."""

        return cart_coords_of(self.dims, rank)

    def cart_rank(self, coords: Sequence[int]) -> int:
        """``MPI_Cart_rank`` (periodic dims wrap)."""

        return cart_rank_of(self.dims, self.periods, coords)

    def cart_shift(self, dim: int, disp: int = 1) -> CartShift:
        """``MPI_Cart_shift``: source/destination tables plus the static
        permutations that move data by ``disp`` along ``dim``."""

        sources, destinations = cart_shift_tables(self.dims, self.periods, dim, disp)
        perm = tuple(
            (r, d) for r, d in enumerate(destinations) if d != PROC_NULL
        )
        size = self.dims[dim]
        if self.periods[dim]:
            axis_perm = tuple((i, (i + disp) % size) for i in range(size))
        else:
            axis_perm = tuple(
                (i, i + disp) for i in range(size) if 0 <= i + disp < size
            )
        return CartShift(
            dim=dim,
            disp=disp,
            sources=sources,
            destinations=destinations,
            perm=perm,
            axis_name=self.axis_names[dim],
            axis_perm=axis_perm,
        )

    def shift_exchange(self, value: Any, dim: int, disp: int = 1) -> TraceFuture:
        """``cart_shift`` + sendrecv in one call: every rank's ``value``
        moves ``disp`` steps along ``dim``; ranks whose source is
        :data:`PROC_NULL` receive zeros.  Lowers to a single axis-local
        ``collective-permute``; returns a :class:`TraceFuture` so the
        exchange can be overlapped (issue, compute, ``get()``)."""

        shift = self.cart_shift(dim, disp)
        if analysis_events.RECORDING:
            analysis_events.record_p2p_round(
                self, shift.axis_perm, mode="sendrecv",
                op=f"cart_shift[{dim}]", size=self.dims[dim])
        return TraceFuture(
            lambda: lax.ppermute(
                jnp.asarray(value), shift.axis_name, list(shift.axis_perm)
            ),
            label=f"cart_shift[{dim}]",
        )

    def cart_sub(self, remain_dims: Sequence[bool]) -> "CartComm":
        """``MPI_Cart_sub``: keep the dims flagged in ``remain_dims``.  The
        result spans the retained mesh axes; as with
        :meth:`~repro.core.communicator.Communicator.split`, the dropped
        axes become color axes and ``group(**coords)`` selects one
        sub-grid's process set (derived from the parent group — the group
        algebra keeps every construction path group-routed)."""

        remain = tuple(bool(x) for x in remain_dims)
        errors.check(
            len(remain) == self.ndims,
            errors.ErrorClass.ERR_DIMS,
            f"remain_dims has {len(remain)} entries for {self.ndims} dims",
        )
        errors.check(
            any(remain),
            errors.ErrorClass.ERR_DIMS,
            "cart_sub must retain at least one dimension",
        )
        keep = [i for i, k in enumerate(remain) if k]
        return CartComm(
            self.mesh,
            tuple(self.axis_names[i] for i in keep),
            dims=tuple(self.dims[i] for i in keep),
            periods=tuple(self.periods[i] for i in keep),
            managed=False,
            tag=self.tag,
        )

    # -- cart-specialised neighborhood exchange ------------------------------

    def _exchange(self, x: jax.Array, *, alltoall: bool) -> jax.Array:
        """Cart override of the generic engine: one axis-local permute per
        (dim, direction) instead of flat-rank rounds — ``2·ndims`` subgroup
        ``collective-permute``\\ s, the canonical halo-exchange lowering."""

        x = jnp.asarray(x)
        degree = 2 * self.ndims
        if alltoall:
            errors.check(
                x.ndim >= 1 and x.shape[0] == degree,
                errors.ErrorClass.ERR_COUNT,
                f"cart neighbor_alltoall buffer needs leading dim {degree} "
                f"(2*ndims), got {tuple(x.shape)}",
            )
        blocks = []
        for dim in range(self.ndims):
            plus = self.cart_shift(dim, 1)
            minus = self.cart_shift(dim, -1)
            if analysis_events.RECORDING:
                for sh in (plus, minus):
                    analysis_events.record_p2p_round(
                        self, sh.axis_perm, mode="sendrecv",
                        op=f"halo[{dim},{sh.disp:+d}]", size=self.dims[dim])
            if alltoall:
                # send slot 2d to the − neighbor, slot 2d+1 to the +; the
                # arrival fills the receiver's opposite slot
                from_minus = lax.ppermute(
                    x[2 * dim + 1], plus.axis_name, list(plus.axis_perm)
                )
                from_plus = lax.ppermute(
                    x[2 * dim], minus.axis_name, list(minus.axis_perm)
                )
            else:
                from_minus = lax.ppermute(x, plus.axis_name, list(plus.axis_perm))
                from_plus = lax.ppermute(x, minus.axis_name, list(minus.axis_perm))
            blocks += [from_minus, from_plus]
        return jnp.stack(blocks)

    def __repr__(self):
        return (
            f"CartComm(dims={self.dims}, periods={self.periods}, "
            f"axes={self.axis_names}, tag={self.tag!r})"
        )


# ---------------------------------------------------------------------------
# distributed graph topology
# ---------------------------------------------------------------------------


class DistGraphComm(_NeighborComm):
    """``MPI_Dist_graph_create_adjacent`` result: a communicator with an
    explicit (possibly weighted, possibly asymmetric) neighbor graph.

    The SPMD program needs the whole pattern, so adjacency is declared for
    every rank at once (``sources[r]`` / ``destinations[r]``) instead of
    rank-locally; both endpoints of every edge must agree, exactly as the
    standard requires of the adjacent constructor.  In/out degrees may
    differ per rank; buffers pad to the maxima (zeros in absent slots).
    """

    def __init__(
        self,
        mesh,
        axis_names,
        *,
        sources: Sequence[Sequence[int]],
        destinations: Sequence[Sequence[int]],
        source_weights: Sequence[Sequence[float]] | None = None,
        dest_weights: Sequence[Sequence[float]] | None = None,
        managed: bool = False,
        tag: str = "",
    ):
        super().__init__(mesh, axis_names, managed=managed, tag=tag)
        n = self.size()
        errors.check(
            len(sources) == n and len(destinations) == n,
            errors.ErrorClass.ERR_TOPOLOGY,
            f"adjacency must cover all {n} ranks "
            f"(got {len(sources)} source rows, {len(destinations)} destination rows)",
        )
        for name, rows in (("sources", sources), ("destinations", destinations)):
            for r, row in enumerate(rows):
                for x in row:
                    errors.check(
                        0 <= int(x) < n or int(x) == PROC_NULL,
                        errors.ErrorClass.ERR_RANK,
                        f"{name}[{r}] names rank {x}; valid: [0, {n}) or "
                        f"PROC_NULL ({PROC_NULL}) for a placeholder slot",
                    )
        self._sources = tuple(tuple(int(x) for x in row) for row in sources)
        self._destinations = tuple(tuple(int(x) for x in row) for row in destinations)

        def _weights(weights, rows, kind):
            if weights is None:
                return tuple(tuple(1.0 for _ in row) for row in rows)
            errors.check(
                len(weights) == n
                and all(len(w) == len(r) for w, r in zip(weights, rows)),
                errors.ErrorClass.ERR_ARG,
                f"{kind} weights must align with the {kind} lists",
            )
            return tuple(tuple(float(x) for x in row) for row in weights)

        self.source_weights = _weights(source_weights, self._sources, "source")
        self.dest_weights = _weights(dest_weights, self._destinations, "destination")
        self._rounds = _matching_rounds(
            _build_edges(self._sources, self._destinations)
        )

    def dist_graph_neighbors_count(self, rank: int) -> tuple[int, int]:
        """``MPI_Dist_graph_neighbors_count`` → (indegree, outdegree)."""

        return len(self._sources[rank]), len(self._destinations[rank])

    def dist_graph_neighbors(self, rank: int):
        """``MPI_Dist_graph_neighbors`` → (sources, source_weights,
        destinations, dest_weights) for ``rank``."""

        return (
            self._sources[rank],
            self.source_weights[rank],
            self._destinations[rank],
            self.dest_weights[rank],
        )

    def __repr__(self):
        return (
            f"DistGraphComm(size={self.size()}, "
            f"max_in={self.indegree()}, max_out={self.outdegree()}, "
            f"tag={self.tag!r})"
        )


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------


def cart_create(
    comm_or_group: Communicator | Group,
    dims: Sequence[int],
    periods: Sequence[bool] | None = None,
    *,
    reorder: bool = False,
    axis_names: Sequence[str] | None = None,
    session=None,
    tag: str | None = None,
) -> CartComm:
    """``MPI_Cart_create``: fold a communicator's group onto a grid.

    Routed through the group algebra: the leading ``prod(dims)`` members of
    the parent group are carved out with ``incl`` (ranks beyond get no
    membership — MPI returns ``MPI_COMM_NULL`` for them), the grid is
    registered as the session process set ``repro://cart/<dims>``, and the
    communicator is built by :meth:`Communicator.from_group` — the single
    canonical constructor.

    ``reorder=True`` is accepted for signature fidelity but performs no
    renumbering: jax binds logical ranks to devices through the mesh, so a
    reorder could only relabel ranks, never migrate their data (DESIGN.md's
    honesty note).
    """

    tool.pvar_count("cart_create")
    group = (
        comm_or_group.group()
        if isinstance(comm_or_group, Communicator)
        else comm_or_group
    )
    errors.check(
        isinstance(group, Group),
        errors.ErrorClass.ERR_GROUP,
        f"cart_create needs a Communicator or Group, got {type(comm_or_group).__name__}",
    )
    dims = tuple(int(d) for d in dims)
    errors.check(
        len(dims) > 0 and all(d > 0 for d in dims),
        errors.ErrorClass.ERR_DIMS,
        f"cart dims must be positive, got {dims}",
    )
    periods = (
        tuple(bool(p) for p in periods)
        if periods is not None
        else (False,) * len(dims)
    )
    errors.check(
        len(periods) == len(dims),
        errors.ErrorClass.ERR_DIMS,
        f"{len(periods)} periods for {len(dims)} dims",
    )
    n = math.prod(dims)
    errors.check(
        n <= group.size(),
        errors.ErrorClass.ERR_DIMS,
        f"cart grid {dims} needs {n} members, group has {group.size()}",
    )
    sub = group.incl(range(n))
    dims_str = "x".join(str(d) for d in dims)
    tag = tag if tag is not None else f"{CART_PSET_PREFIX}{dims_str}"
    sess = session if session is not None else default_session()
    # the default tag is keyed on dims alone: re-registering the SAME grid
    # is idempotent (trainer re-init, elastic re-create), but a different
    # group under the same name would silently clobber the first cart's
    # process set — require an explicit tag for that
    if tag in sess.psets():
        errors.check(
            sess.pset(tag) == tuple(sub.devices),
            errors.ErrorClass.ERR_ARG,
            f"process set {tag!r} already names a different device grid; "
            f"pass an explicit tag= to register a second {dims_str} cart",
        )
    sess.register_pset(tag, sub)
    if axis_names is None:
        axis_names = tuple(f"cart{i}" for i in range(len(dims)))
    axis_names = tuple(axis_names)
    base = Communicator.from_group(sub, tag=tag, shape=dims, axis_names=axis_names)
    return CartComm(
        base.mesh, axis_names, dims=dims, periods=periods, managed=True, tag=tag
    )


def cart_refold(
    cart: CartComm,
    group: Group,
    *,
    elastic_axis: int = 0,
    session=None,
    tag: str | None = None,
) -> CartComm:
    """Re-fold an existing Cartesian topology onto an *arbitrary* survivor
    (or grown) group — the ULFM shrink/grow rebuild step for carts.

    The grid keeps every dim except ``elastic_axis`` (the data axis by
    convention), which re-resolves to ``group.size() // prod(fixed)``; the
    leading ``prod(dims)`` members fold row-major and any excess idles
    (``MPI_COMM_NULL``).  Periods and axis names carry over.  Pass an
    explicit ``tag``: across epochs the same dims can bind different device
    tuples, which the dims-keyed default tag refuses by design.
    """

    fixed = math.prod(d for i, d in enumerate(cart.dims) if i != elastic_axis)
    errors.check(
        group.size() >= fixed,
        errors.ErrorClass.ERR_DIMS,
        f"{group.size()} survivors cannot fold onto {cart.dims} "
        f"(needs at least {fixed})",
    )
    dims = tuple(
        group.size() // fixed if i == elastic_axis else d
        for i, d in enumerate(cart.dims)
    )
    return cart_create(
        group,
        dims,
        cart.periods,
        axis_names=cart.axis_names,
        session=session,
        tag=tag,
    )


def dist_graph_create_adjacent(
    comm: Communicator,
    sources: Sequence[Sequence[int]],
    destinations: Sequence[Sequence[int]],
    *,
    source_weights: Sequence[Sequence[float]] | None = None,
    dest_weights: Sequence[Sequence[float]] | None = None,
    reorder: bool = False,
) -> DistGraphComm:
    """``MPI_Dist_graph_create_adjacent`` over an existing communicator's
    mesh (``reorder=False`` semantics: ranks keep their identity; the
    ``reorder=True`` honesty note of :func:`cart_create` applies)."""

    tool.pvar_count("dist_graph_create")
    return DistGraphComm(
        comm.mesh,
        comm.axis_names,
        sources=sources,
        destinations=destinations,
        source_weights=source_weights,
        dest_weights=dest_weights,
        managed=False,
        tag=comm.tag,
    )


# ---------------------------------------------------------------------------
# serving fan-out graphs (heterogeneous prefill:decode, e.g. 2:6 / 3:5)
# ---------------------------------------------------------------------------


def serving_fanout_adjacency(
    num_prefill: int, num_decode: int
) -> tuple[list[list[int]], list[list[int]]]:
    """Adjacency of a ``P:D`` serving fan-out over a bridge ordered
    prefill-then-decode: ranks ``0..P-1`` are prefill workers, ``P..P+D-1``
    decode workers; decode rank ``P+j`` receives its KV from prefill rank
    ``j % P`` (round-robin), so the decode fleet is partitioned into ``P``
    disjoint fan-out sets.  Returns ``(sources, destinations)`` in the
    all-ranks-at-once form :class:`DistGraphComm` requires.  This is the
    heterogeneous-ratio shape (2:6, 3:5, ...) an axis split cannot express —
    the graph, not a grid, is the topology."""

    p, d = int(num_prefill), int(num_decode)
    errors.check(
        p >= 1 and d >= 1,
        errors.ErrorClass.ERR_DIMS,
        f"serving fan-out needs at least one prefill and one decode rank, "
        f"got {p}:{d}",
    )
    errors.check(
        d >= p,
        errors.ErrorClass.ERR_DIMS,
        f"serving fan-out {p}:{d} leaves {p - d} prefill ranks with no "
        "decode targets; use num_decode >= num_prefill",
    )
    sources: list[list[int]] = []
    destinations: list[list[int]] = []
    for i in range(p):
        sources.append([])
        destinations.append([p + j for j in range(d) if j % p == i])
    for j in range(d):
        sources.append([j % p])
        destinations.append([])
    return sources, destinations


def fanout_routes(
    sources: Sequence[Sequence[int]], destinations: Sequence[Sequence[int]]
) -> list[tuple[int, int]]:
    """The KV routing pairs of a fan-out adjacency: every declared edge as
    an origin→target ``(src, dst)`` pair, in target order.  Each decode
    target is written by exactly one origin, so the per-epoch
    duplicate-target check holds by construction; but an origin may feed
    several targets, which a single ``send_recv`` cannot carry — split the
    routes into per-``rput`` permutations with :func:`fanout_rounds`."""

    edges = [
        (r, int(dst))
        for r, row in enumerate(destinations)
        for dst in row
        if int(dst) != PROC_NULL
    ]
    for dst, row in enumerate(sources):
        for src in row:
            if int(src) != PROC_NULL and (int(src), dst) not in edges:
                edges.append((int(src), dst))
    return sorted(set(edges), key=lambda e: (e[1], e[0]))


def fanout_rounds(
    routes: Sequence[tuple[int, int]],
) -> list[list[tuple[int, int]]]:
    """Split fan-out routes into ``send_recv``-legal rounds: within a round
    every origin sends to at most one target and every target is written by
    at most one origin, so each round is directly usable as the ``perm`` of
    a window :meth:`~repro.core.onesided.Window.rput`.  Greedy first-fit
    preserves the target order of :func:`fanout_routes`; a ``P:D`` fan-out
    yields ``ceil(D / P)`` rounds."""

    rounds: list[list[tuple[int, int]]] = []
    for src, dst in routes:
        for rnd in rounds:
            if all(s != src and d != dst for s, d in rnd):
                rnd.append((int(src), int(dst)))
                break
        else:
            rounds.append([(int(src), int(dst))])
    if analysis_events.RECORDING and rounds:
        size = 1 + max(max(s, d) for rnd in rounds for s, d in rnd)
        for rnd in rounds:
            analysis_events.record_p2p_round(
                "fanout", rnd, mode="sendrecv", op="fanout_round", size=size)
    return rounds


def serving_fanout_graph(
    comm: Communicator, num_prefill: int, num_decode: int
) -> DistGraphComm:
    """``MPI_Dist_graph_create_adjacent`` over a serving bridge with the
    ``P:D`` fan-out adjacency (:func:`serving_fanout_adjacency`)."""

    errors.check(
        num_prefill + num_decode == comm.size(),
        errors.ErrorClass.ERR_TOPOLOGY,
        f"fan-out {num_prefill}:{num_decode} needs a bridge of "
        f"{num_prefill + num_decode} ranks, communicator has {comm.size()}",
    )
    sources, destinations = serving_fanout_adjacency(num_prefill, num_decode)
    return dist_graph_create_adjacent(comm, sources, destinations)


# -- method facade (paper style: comm.cart_create(...)) -----------------------

Communicator.cart_create = cart_create
Communicator.dist_graph_create_adjacent = dist_graph_create_adjacent
