"""The Sessions model (MPI 4.0 §11, the paper's target standard).

MPI 4.0's headline addition is that a parallel program no longer starts from
one implicit ``MPI_COMM_WORLD``: an application opens a **session**, asks the
runtime which named **process sets** exist (``mpi://WORLD``, ``mpi://SELF``,
implementation sets such as per-node sets), derives an immutable **group**
from a set (``MPI_Group_from_session_pset``), refines it with the group
algebra, and only then builds a communicator with
``MPI_Comm_create_from_group``.  Construction is therefore compositional:
independent libraries in one process each open their own session and carve
their own communicators out of declared subsets of the machine without ever
touching a global.

The JAX analogue maps "process" to *device*:

* :class:`Session` enumerates the platform (``jax.devices()``) into named
  process sets — ``repro://world``, ``repro://self`` (this host's devices),
  one ``repro://host/<k>`` set per process index, one
  ``repro://platform/<name>`` set per backend platform — plus user-registered
  sets (:meth:`Session.register_pset`) and mesh sub-grid sets
  (:meth:`Session.register_mesh_psets`).
* :class:`Group` is an immutable ordered device set with the full MPI group
  algebra: ``union`` / ``intersection`` / ``difference`` / ``incl`` /
  ``excl`` / ``rank`` / ``size`` / ``translate_ranks`` / ``compare``.
* ``Communicator.from_group(group, tag=...)`` (in
  :mod:`repro.core.communicator`) is ``MPI_Comm_create_from_group``: the one
  canonical constructor every other construction path routes through.
  ``world()`` is a thin shim over
  ``default_session().group("repro://world")``.

Groups are deliberately device-agnostic containers (any hashable, ordered
members work), so the algebra is testable without multi-device hardware.
"""

from __future__ import annotations

import enum
from typing import Any, Iterable, Mapping, Sequence

import jax

from repro.core import errors

#: ``MPI_UNDEFINED`` analogue for rank queries that have no answer.
UNDEFINED = -1

#: The builtin process-set namespace.  ``mpi://`` spellings are accepted as
#: aliases (``mpi://world`` → ``repro://world``) since the paper's readers
#: know the standard's names.
_SCHEME = "repro://"
_ALIAS_SCHEME = "mpi://"

WORLD_PSET = _SCHEME + "world"
SELF_PSET = _SCHEME + "self"

#: Topology-registered process sets (MPI 4.0 ch. 8): ``cart_create``
#: registers each Cartesian grid's device set under this prefix
#: (``repro://cart/<d0>x<d1>...``).  These are *user* sets — preserved
#: across :meth:`Session.refresh`, re-registered by re-running the
#: topology constructor after an elastic resize.
CART_PSET_PREFIX = _SCHEME + "cart/"

_BUILTIN_PREFIXES = (f"{_SCHEME}host/", f"{_SCHEME}platform/", f"{_SCHEME}slice/")


def _is_builtin_pset(name: str) -> bool:
    return name in (WORLD_PSET, SELF_PSET) or name.startswith(_BUILTIN_PREFIXES)


class GroupComparison(enum.Enum):
    """``MPI_Group_compare`` results."""

    IDENT = "ident"        # same members, same order
    SIMILAR = "similar"    # same members, different order
    UNEQUAL = "unequal"


class Group:
    """Immutable ordered set of devices (``MPI_Group``).

    Rank *r* in the group is position *r* in :attr:`devices`.  All algebra
    follows MPI ordering rules: ``union`` keeps ``self``'s order then appends
    ``other``'s new members; ``intersection`` and ``difference`` are ordered
    by ``self``.
    """

    __slots__ = ("_devices", "_index")

    def __init__(self, devices: Iterable[Any] = ()):
        seen: dict[Any, int] = {}
        for d in devices:
            if d not in seen:
                seen[d] = len(seen)
        self._devices = tuple(seen)
        self._index = seen

    # -- introspection -----------------------------------------------------

    @property
    def devices(self) -> tuple[Any, ...]:
        return self._devices

    def size(self) -> int:
        """``MPI_Group_size``."""

        return len(self._devices)

    def rank(self, device: Any = None) -> int:
        """``MPI_Group_rank``: the calling process's rank, or
        :data:`UNDEFINED` if it is not a member.

        The SPMD analogue of "the calling process" is this host's first
        device that belongs to the group; pass ``device`` explicitly to ask
        about a specific member (``rank(dev)``).
        """

        if device is not None:
            return self._index.get(device, UNDEFINED)
        for d in _local_devices_safe():
            r = self._index.get(d)
            if r is not None:
                return r
        return UNDEFINED

    def device(self, rank: int) -> Any:
        """The member at ``rank`` (inverse of :meth:`rank`)."""

        errors.check(
            0 <= rank < len(self._devices),
            errors.ErrorClass.ERR_RANK,
            f"rank {rank} out of range for group of size {len(self._devices)}",
        )
        return self._devices[rank]

    def __len__(self) -> int:
        return len(self._devices)

    def __bool__(self) -> bool:
        return bool(self._devices)

    def __iter__(self):
        return iter(self._devices)

    def __contains__(self, device: Any) -> bool:
        return device in self._index

    def __eq__(self, other: Any) -> bool:
        return isinstance(other, Group) and self._devices == other._devices

    def __hash__(self) -> int:
        return hash(self._devices)

    def __repr__(self) -> str:
        return f"Group(size={len(self._devices)})"

    # -- algebra -----------------------------------------------------------

    def union(self, other: "Group") -> "Group":
        """``MPI_Group_union``: self's members, then other's new members."""

        return Group(self._devices + other._devices)

    def intersection(self, other: "Group") -> "Group":
        """``MPI_Group_intersection``: members of both, ordered by self."""

        return Group(d for d in self._devices if d in other)

    def difference(self, other: "Group") -> "Group":
        """``MPI_Group_difference``: members of self not in other."""

        return Group(d for d in self._devices if d not in other)

    __or__ = union
    __and__ = intersection
    __sub__ = difference

    def incl(self, ranks: Sequence[int]) -> "Group":
        """``MPI_Group_incl``: the subgroup at ``ranks``, in that order."""

        ranks = list(ranks)
        errors.check(
            len(set(ranks)) == len(ranks),
            errors.ErrorClass.ERR_RANK,
            f"incl ranks must be distinct: {ranks}",
        )
        return Group(self.device(r) for r in ranks)

    def excl(self, ranks: Sequence[int]) -> "Group":
        """``MPI_Group_excl``: everything but ``ranks``, order preserved."""

        ranks = list(ranks)
        errors.check(
            len(set(ranks)) == len(ranks),
            errors.ErrorClass.ERR_RANK,
            f"excl ranks must be distinct: {ranks}",
        )
        drop = {self.device(r) for r in ranks}
        return Group(d for d in self._devices if d not in drop)

    def translate_ranks(self, ranks: Sequence[int], other: "Group") -> list[int]:
        """``MPI_Group_translate_ranks``: where self's ``ranks`` sit in
        ``other`` (:data:`UNDEFINED` for non-members)."""

        return [other.rank(self.device(r)) for r in ranks]

    def compare(self, other: "Group") -> GroupComparison:
        """``MPI_Group_compare``."""

        if self._devices == other._devices:
            return GroupComparison.IDENT
        if set(self._devices) == set(other._devices):
            return GroupComparison.SIMILAR
        return GroupComparison.UNEQUAL


def _local_devices_safe() -> tuple[Any, ...]:
    try:
        return tuple(jax.local_devices())
    except RuntimeError:  # pragma: no cover - no backend at all
        return ()


def _normalize(name: str) -> str:
    name = name.lower()
    if name.startswith(_ALIAS_SCHEME):
        name = _SCHEME + name[len(_ALIAS_SCHEME):]
    return name


class Session:
    """``MPI_Session``: a handle onto the platform's named process sets.

    Lifecycle mirrors the standard: :meth:`init` opens a session (no global
    state is touched — independent components may each hold one),
    :meth:`finalize` closes it, after which every query raises
    ``ERR_SESSION``.  Usable as a context manager.
    """

    def __init__(self, devices: Sequence[Any] | None = None, *, info: Mapping | None = None):
        self._devices = tuple(devices) if devices is not None else tuple(jax.devices())
        errors.check(
            len(self._devices) > 0,
            errors.ErrorClass.ERR_SESSION,
            "a session needs at least one device",
        )
        self.info = dict(info or {})
        self._finalized = False
        self._psets: dict[str, tuple[Any, ...]] = {}
        self._enumerate()

    @classmethod
    def init(cls, devices: Sequence[Any] | None = None, *, info: Mapping | None = None) -> "Session":
        """``MPI_Session_init``."""

        return cls(devices, info=info)

    # -- platform enumeration ----------------------------------------------

    def _enumerate(self) -> None:
        self._psets[WORLD_PSET] = self._devices
        local_set = set(_local_devices_safe())
        local = [d for d in self._devices if d in local_set]
        self._psets[SELF_PSET] = tuple(local) or self._devices[:1]

        by_host: dict[int, list[Any]] = {}
        by_platform: dict[str, list[Any]] = {}
        for d in self._devices:
            by_host.setdefault(getattr(d, "process_index", 0), []).append(d)
            by_platform.setdefault(getattr(d, "platform", "unknown"), []).append(d)
        for host, devs in sorted(by_host.items()):
            self._psets[f"{_SCHEME}host/{host}"] = tuple(devs)
        for platform, devs in sorted(by_platform.items()):
            self._psets[f"{_SCHEME}platform/{platform}"] = tuple(devs)
        # per-slice sets where the backend reports slice topology (TPU pods)
        by_slice: dict[int, list[Any]] = {}
        for d in self._devices:
            s = getattr(d, "slice_index", None)
            if s is not None:
                by_slice.setdefault(s, []).append(d)
        for s, devs in sorted(by_slice.items()):
            self._psets[f"{_SCHEME}slice/{s}"] = tuple(devs)

    # -- lifecycle ---------------------------------------------------------

    def refresh(self, devices: "Sequence[Any] | None" = None) -> "Session":
        """Re-enumerate the platform (elastic resize): builtin process sets
        are rebuilt from the current device set; user-registered sets are
        preserved *modulo reality* — members that vanished from the platform
        are pruned (a pset naming dead hardware is a stale handle, the bug
        ULFM's revoke exists to prevent), and a user pset whose members all
        vanished is dropped entirely.

        ``devices`` overrides the enumeration source (default
        ``jax.devices()``) so elastic tests can model devices disappearing
        and re-appearing between refreshes on a single host."""

        self._live()
        user = {k: v for k, v in self._psets.items() if not _is_builtin_pset(k)}
        self._devices = tuple(jax.devices() if devices is None else devices)
        self._psets = {}
        self._enumerate()
        alive = set(self._devices)
        for name, members in user.items():
            survivors = tuple(d for d in members if d in alive)
            if survivors:
                self._psets[name] = survivors
        return self

    @property
    def finalized(self) -> bool:
        return self._finalized

    def finalize(self) -> None:
        """``MPI_Session_finalize``.  Idempotent."""

        self._finalized = True

    def __enter__(self) -> "Session":
        self._live()
        return self

    def __exit__(self, *exc) -> None:
        self.finalize()

    def _live(self) -> None:
        if self._finalized:
            errors.fail(
                errors.ErrorClass.ERR_SESSION,
                "session is finalized (MPI_Session_finalize was called)",
            )

    # -- process-set discovery ---------------------------------------------

    def num_psets(self) -> int:
        """``MPI_Session_get_num_psets``."""

        self._live()
        return len(self._psets)

    def psets(self) -> list[str]:
        """All process-set names (``MPI_Session_get_nth_pset``, vectorised)."""

        self._live()
        return list(self._psets)

    def pset(self, name: str) -> tuple[Any, ...]:
        """The device tuple behind a named process set."""

        self._live()
        key = _normalize(name)
        errors.check(
            key in self._psets,
            errors.ErrorClass.ERR_ARG,
            f"unknown process set {name!r}; known: {list(self._psets)}",
        )
        return self._psets[key]

    def pset_info(self, name: str) -> dict:
        """``MPI_Session_get_pset_info`` (the standard mandates ``mpi_size``)."""

        devs = self.pset(name)
        return {"mpi_size": len(devs), "size": len(devs), "name": _normalize(name)}

    def group(self, name: str = WORLD_PSET) -> Group:
        """``MPI_Group_from_session_pset``."""

        return Group(self.pset(name))

    # -- user-registered sets ----------------------------------------------

    def register_pset(self, name: str, members: "Group | Sequence[Any]") -> str:
        """Register a user process set (over devices or an existing group).

        Returns the normalised name.  Builtin sets cannot be shadowed.
        """

        self._live()
        key = _normalize(name)
        errors.check(
            not _is_builtin_pset(key),
            errors.ErrorClass.ERR_ARG,
            f"cannot shadow builtin process set {name!r}",
        )
        devices = tuple(
            dict.fromkeys(members.devices if isinstance(members, Group) else members)
        )
        errors.check(
            len(devices) > 0, errors.ErrorClass.ERR_GROUP, f"process set {name!r} is empty"
        )
        known = set(self._devices)
        for d in devices:
            errors.check(
                d in known,
                errors.ErrorClass.ERR_GROUP,
                f"device {d} of pset {name!r} is not part of this session",
            )
        self._psets[key] = devices
        return key

    def register_mesh_psets(self, mesh, *, prefix: str = _SCHEME + "mesh") -> list[str]:
        """Expose a mesh's sub-grids as process sets.

        For each mesh axis ``a`` and index ``i``, registers
        ``<prefix>/<a>/<i>`` holding the devices of that slice (the sub-grid
        with ``a`` fixed to ``i``) — the session-native spelling of
        "the i-th data-parallel replica" / "the i-th pipeline stage".
        """

        self._live()
        names = []
        for axis_pos, axis in enumerate(mesh.axis_names):
            for i in range(mesh.devices.shape[axis_pos]):
                sub = mesh.devices.take(i, axis=axis_pos)
                names.append(
                    self.register_pset(f"{prefix}/{axis}/{i}", sub.reshape(-1).tolist())
                )
        return names

    def __repr__(self) -> str:
        state = "finalized" if self._finalized else f"{len(self._psets)} psets"
        return f"Session(devices={len(self._devices)}, {state})"


_DEFAULT: Session | None = None


def default_session(refresh: bool = False) -> Session:
    """The process-default session backing :func:`repro.core.world`.

    ``refresh=True`` re-enumerates the platform (elastic resize); a finalized
    default is replaced automatically.
    """

    global _DEFAULT
    if _DEFAULT is None or _DEFAULT.finalized:
        _DEFAULT = Session.init()
    elif refresh:
        _DEFAULT.refresh()
    return _DEFAULT
