"""Tool interface (paper §II — MPI 4.0 chapter 15, ``MPI_T_``).

MPI_T exposes *performance variables* (pvars) and *control variables*
(cvars).  The XLA adaptation:

* **pvars** are extracted from compiled artifacts: per-collective operand
  bytes, ring-adjusted wire bytes, FLOPs and bytes accessed — the exact
  counters the roofline analysis consumes (``collective_bytes`` is not in
  ``cost_analysis()``; it is parsed from the HLO text here).
* **cvars** are a typed runtime configuration registry (error checking,
  default algorithms, compression) — the scoped, validated analogue of MPI's
  stringly-typed control variables.
* call-site counters (``pvar_counters``) count issued operations per kind,
  maintained by the interface layer.

Hardware model constants for the roofline (TPU v5e) also live here so every
consumer agrees on them.
"""

from __future__ import annotations

import dataclasses
import re
import threading
from collections import defaultdict
from typing import Any, Callable

from repro.analysis import events as analysis_events
from repro.core import errors

# --------------------------------------------------------------------------
# hardware model (TPU v5e, per task statement)
# --------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
HBM_BANDWIDTH = 819e9        # bytes/s per chip
ICI_BANDWIDTH = 50e9         # bytes/s per link
DCN_BANDWIDTH = 12.5e9       # bytes/s per host NIC (inter-slice collectives)
HBM_BYTES = 16 * 1024**3     # HBM capacity per chip
COLLECTIVE_LAUNCH_S = 3e-6   # fixed per-collective launch/latency cost

# --------------------------------------------------------------------------
# HLO parsing: collective bytes (pvars from compiled artifacts)
# --------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3fnuz": 1, "f8e5m2fnuz": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_KINDS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
)

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")


def _shape_bytes(dtype: str, dims: str) -> float:
    size = _DTYPE_BYTES.get(dtype)
    if size is None:
        return 0.0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * size


def _line_shapes(segment: str) -> list[float]:
    return [_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(segment)]


@dataclasses.dataclass
class CollectiveStats:
    """Aggregated collective pvars for one compiled module (per device)."""

    count: dict[str, int] = dataclasses.field(default_factory=lambda: defaultdict(int))
    operand_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    result_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )
    wire_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float)
    )

    @property
    def total_operand_bytes(self) -> float:
        return float(sum(self.operand_bytes.values()))

    @property
    def total_wire_bytes(self) -> float:
        return float(sum(self.wire_bytes.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.count.values()))

    def as_dict(self) -> dict[str, Any]:
        return {
            "count": dict(self.count),
            "operand_bytes": dict(self.operand_bytes),
            "result_bytes": dict(self.result_bytes),
            "wire_bytes": dict(self.wire_bytes),
            "total_operand_bytes": self.total_operand_bytes,
            "total_wire_bytes": self.total_wire_bytes,
        }


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        first = m.group(1)
        return max(1, len([t for t in first.split(",") if t.strip() != ""]))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(t) for t in m.group(1).split(",")]
        return max(1, dims[-1]) if dims else default
    return default


def _wire_factor(kind: str, n: int) -> float:
    """Ring-algorithm bytes crossing one device's link, as a multiple of the
    payload (operand bytes for reductions, result bytes for gathers)."""

    if kind in ("collective-permute", "collective-broadcast"):
        # permutes/broadcasts move the payload once regardless of group
        # size; they carry source-target pairs, not replica_groups, so the
        # parsed group size (default 1) must not zero them out — ring
        # schedules and ch. 8 neighbor exchanges are all permutes, and
        # their wire bytes used to read as 0 here
        return 1.0
    if n <= 1:
        return 0.0
    frac = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * frac
    if kind in ("all-gather", "reduce-scatter", "all-to-all"):
        return frac
    return 1.0


def parse_hlo_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    """Parse HLO text; sum operand sizes of every collective op (the roofline
    ``collective_bytes`` source mandated by the methodology), plus result and
    ring-adjusted wire bytes.

    ``-start`` variants are counted; their matching ``-done`` is skipped, as
    are dead "parameter"-only mentions.
    """

    shapes_by_name: dict[str, float] = {}
    stats = CollectiveStats()
    for raw in hlo_text.splitlines():
        m = _DEF_RE.match(raw)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        paren = rhs.find("(")
        head = rhs[:paren] if paren >= 0 else rhs
        result_bytes = sum(_line_shapes(head))
        shapes_by_name[name] = result_bytes

        opm = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        kind = None
        for k in COLLECTIVE_KINDS:
            if op == k or op == k + "-start":
                kind = k
                break
        if kind is None:
            continue
        # operand bytes: inline shapes if present, else resolve operand names.
        # Scan to the matching close-paren of the op's argument list.
        depth = 1
        top: list[str] = []
        cur = ""
        for ch in rhs[opm.end() :]:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if ch == "," and depth == 1:
                top.append(cur)
                cur = ""
            else:
                cur += ch
        if cur.strip():
            top.append(cur)
        operand_bytes = 0.0
        for arg in top:
            arg = arg.strip()
            inline = _line_shapes(arg)
            if inline:
                operand_bytes += sum(inline)
            else:
                ref = arg.lstrip("%").strip()
                operand_bytes += shapes_by_name.get(ref, 0.0)
        if op.endswith("-start") and kind in ("all-gather", "all-reduce"):
            # start result is (operand, result) tuples; fine — we use operands
            pass
        n = _group_size(raw, default_group)
        payload = operand_bytes if kind in ("all-reduce", "reduce-scatter", "all-to-all",
                                            "collective-permute") else result_bytes
        stats.count[kind] += 1
        stats.operand_bytes[kind] += operand_bytes
        stats.result_bytes[kind] += result_bytes
        stats.wire_bytes[kind] += payload * _wire_factor(kind, n)
    return stats


def flops_and_bytes(compiled) -> tuple[float, float]:
    """(HLO flops, HLO bytes accessed) from ``cost_analysis`` (per device)."""

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def roofline_terms(
    compiled,
    *,
    hlo_text: str | None = None,
    chips: int = 1,
    peak_flops: float = PEAK_FLOPS_BF16,
    hbm_bw: float = HBM_BANDWIDTH,
    link_bw: float = ICI_BANDWIDTH,
) -> dict[str, Any]:
    """The three roofline terms (seconds) for one compiled step.

    ``cost_analysis`` on an SPMD module reports *per device* numbers, so the
    ``chips`` division is already implicit; it is kept as a parameter for
    whole-model (unpartitioned) analyses.
    """

    flops, bytes_accessed = flops_and_bytes(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_hlo_collectives(text)
    compute_t = flops / (chips * peak_flops)
    memory_t = bytes_accessed / (chips * hbm_bw)
    collective_t = colls.total_operand_bytes / (chips * link_bw)
    wire_t = colls.total_wire_bytes / (chips * link_bw)
    terms = {
        "compute_s": compute_t,
        "memory_s": memory_t,
        "collective_s": collective_t,
        "collective_wire_s": wire_t,
        "hlo_flops": flops,
        "hlo_bytes": bytes_accessed,
        "collectives": colls.as_dict(),
    }
    dominant = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    terms["dominant"] = dominant
    return terms


# --------------------------------------------------------------------------
# control variables (cvars)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _Cvar:
    name: str
    type: type
    value: Any
    doc: str
    on_set: Callable[[Any], None] | None = None


_CVARS: dict[str, _Cvar] = {}


def cvar_register(
    name: str, type_: type, default: Any, doc: str, on_set: Callable[[Any], None] | None = None
) -> None:
    _CVARS[name] = _Cvar(name, type_, default, doc, on_set)
    if on_set:
        on_set(default)


def cvar_set(name: str, value: Any) -> None:
    v = _CVARS.get(name)
    if v is None:
        errors.fail(errors.ErrorClass.ERR_ARG, f"unknown control variable {name!r}")
    if not isinstance(value, v.type):
        errors.fail(
            errors.ErrorClass.ERR_TYPE,
            f"cvar {name!r} expects {v.type.__name__}, got {type(value).__name__}",
        )
    v.value = value
    if v.on_set:
        v.on_set(value)


def cvar_get(name: str) -> Any:
    v = _CVARS.get(name)
    if v is None:
        errors.fail(errors.ErrorClass.ERR_ARG, f"unknown control variable {name!r}")
    return v.value


def cvar_list() -> dict[str, str]:
    return {v.name: v.doc for v in _CVARS.values()}


# default cvars
cvar_register(
    "error_checking",
    bool,
    True,
    "trace-time argument validation (the paper's compile-time macro)",
    on_set=errors.set_error_checking,
)

cvar_register(
    "analysis_recording",
    bool,
    False,
    "record communication events into the repro.analysis ledger "
    "(MUST-style event-graph lint; off by default — disabled cost is one "
    "module-attribute read per call site)",
    on_set=analysis_events.set_recording,
)


# --------------------------------------------------------------------------
# pvar call-site counters
# --------------------------------------------------------------------------

pvar_counters: dict[str, int] = defaultdict(int)

# counters are bumped from I/O request threads too (io_bytes_*, commits) —
# `+=` on a dict entry is not atomic, so updates take this lock
_PVAR_LOCK = threading.Lock()

#: Documented performance variables (``MPI_T_pvar_get_info`` analogue).
#: Collective call-site counters are registered implicitly by the method
#: facade; the request-layer counters are registered here so tooling can
#: enumerate them before the first event fires.
PVARS: dict[str, str] = {}


def pvar_register(name: str, doc: str) -> None:
    """Describe a pvar (idempotent).  Counting does not require prior
    registration — unknown counters still count — but registered pvars are
    enumerable via :func:`pvar_info` with a zero initial value."""

    PVARS.setdefault(name, doc)


#: When True, counting an unregistered pvar is an ``ERR_ARG`` instead of a
#: silent new counter — the runtime half of the registry audit (the static
#: half lives in :mod:`repro.analysis.static`; dynamically-formatted names
#: can only be caught here).
PVAR_STRICT = False


def pvar_strict(enabled: bool) -> bool:
    """Toggle fail-fast on unregistered pvar writes; returns the previous
    value."""

    global PVAR_STRICT
    prev = PVAR_STRICT
    PVAR_STRICT = bool(enabled)
    return prev


def _pvar_check(op: str) -> None:
    if op not in PVARS:
        errors.fail(
            errors.ErrorClass.ERR_ARG,
            f"pvar {op!r} written but never registered — add a "
            f"pvar_register({op!r}, ...) where the counter is defined",
        )


def pvar_count(op: str) -> None:
    if PVAR_STRICT:
        _pvar_check(op)
    with _PVAR_LOCK:
        pvar_counters[op] += 1


def pvar_add(op: str, amount: int) -> None:
    """Add to an accumulating pvar (byte counters and the like)."""

    if PVAR_STRICT:
        _pvar_check(op)
    with _PVAR_LOCK:
        pvar_counters[op] += int(amount)


def pvar_reset() -> None:
    with _PVAR_LOCK:
        pvar_counters.clear()


def pvar_read() -> dict[str, int]:
    counts = {name: 0 for name in PVARS}
    with _PVAR_LOCK:
        counts.update(pvar_counters)
    return counts


def pvar_info() -> dict[str, str]:
    return dict(PVARS)


# request-layer pvars (persistent / partitioned operations, C3)
pvar_register("persistent_init", "persistent requests initialised (AOT lower+compile)")
pvar_register("persistent_start", "MPI_Start analogues fired on persistent requests")
pvar_register("partitioned_init", "partitioned requests constructed (Psend_init)")
pvar_register("partitioned_start", "partitioned request activations (MPI_Start)")
pvar_register("partition_ready", "partitions marked ready (MPI_Pready)")
pvar_register("cart_create", "Cartesian topologies constructed (MPI_Cart_create)")
pvar_register("dist_graph_create",
              "distributed graph topologies constructed (MPI_Dist_graph_create_adjacent)")
pvar_register("neighbor_allgather", "neighborhood allgathers issued (MPI_Neighbor_allgather)")
pvar_register("neighbor_alltoall", "neighborhood alltoalls issued (MPI_Neighbor_alltoall)")
pvar_register("neighbor_alltoallv", "vector neighborhood alltoalls issued (MPI_Neighbor_alltoallv)")
pvar_register("neighbor_alltoall_init",
              "persistent neighborhood alltoalls initialised (MPI_Neighbor_alltoall_init)")
pvar_register("rma_fence", "window fence epochs opened/closed (MPI_Win_fence)")
pvar_register("rma_put", "blocking window puts (MPI_Put)")
pvar_register("rma_rput", "request-based window puts (MPI_Rput)")
pvar_register("rma_get", "blocking window gets (MPI_Get)")
pvar_register("rma_rget", "request-based window gets (MPI_Rget)")
pvar_register("rma_accumulate", "window accumulates (MPI_Accumulate/Raccumulate)")
pvar_register("rma_attach", "pages attached to dynamic windows (MPI_Win_attach)")
pvar_register("rma_detach", "pages detached from dynamic windows (MPI_Win_detach)")

# file-I/O pvars (chapter 14) and the checkpoint subsystem built on it
pvar_register("io_write", "blocking collective file writes (MPI_File_write_at_all)")
pvar_register("io_read", "blocking collective file reads (MPI_File_read_at_all)")
pvar_register("io_iwrite", "nonblocking collective writes issued (MPI_File_iwrite_at_all)")
pvar_register("io_iread", "nonblocking collective reads issued (MPI_File_iread_at_all)")
pvar_register("io_split_begin", "split collectives begun (MPI_File_*_at_all_begin)")
pvar_register("io_set_view", "file views installed (MPI_File_set_view)")
pvar_register("io_manifest_commit", "manifest sync points written (MPI_File_sync)")
pvar_register("io_bytes_written", "fragment bytes written (accumulating)")
pvar_register("io_bytes_read", "fragment bytes read (accumulating)")
pvar_register("ckpt_save", "checkpoint saves issued (async or sync)")
pvar_register("ckpt_save_failed", "checkpoint saves that surfaced an I/O error")
pvar_register("ckpt_restore", "checkpoint restores")
pvar_register("ckpt_wait", "checkpoint completions joined (wait)")
