"""Elastic communicator epochs: ULFM-style shrink/grow on the Sessions model.

A long-running parallel job outlives its hardware.  The ULFM fault-tolerance
proposal (the chapter MPI 4.x reserves error classes 75/76 for) spells the
recovery loop as: detect → ``MPI_Comm_revoke`` → ``MPI_Comm_shrink`` →
rebuild from the survivor group → continue.  The Sessions model (MPI 4.0
§11, the paper's ch. 11) makes that loop *constructive*: process sets are
first-class and re-enumerable, groups have a full algebra
(``Group.difference`` is the shrink), and ``Communicator.from_group`` is the
one canonical constructor a rebuilt fabric routes through.

What was missing in this repo was a *home* for the loop's state: every layer
(Trainer, PartitionedGradSync, CheckpointManager, the serving engine) cached
a communicator and its AOT persistent requests privately, as if the world
were immortal.  :class:`CommEpoch` is that home — a **generation-numbered
bundle** of

* the session **process set** the epoch registered (``repro://epoch/<n>/<g>``),
* the member :class:`~repro.core.session.Group` (survivors fold row-major),
* the :class:`~repro.core.communicator.Communicator` (a
  :class:`~repro.core.topology.CartComm` when the epoch carries a Cartesian
  :class:`TopologySpec`),
* a **persistent-request cache**: named AOT executables derived from the
  epoch's fabric, built lazily, and *gone* when the epoch is (a persistent
  request is bound to its shardings — after a shrink it raises
  ``ERR_REQUEST`` on drift, and the new epoch rebuilds it on first use).

Every fabric consumer derives its comm state *from the current epoch*
instead of storing it.  On failure the runtime revokes the epoch (any
further use raises ``ERR_REVOKED``), shrinks the group
(``Group.difference``), and constructs generation ``g+1``; the reverse path
(:meth:`CommEpoch.grow`) hot-joins new members and re-folds the elastic
axis.  Excess survivors that do not fold onto the topology (e.g. 3 ranks
onto a ``(data, stage=2)`` grid) keep pool membership but get no comm —
MPI's ``MPI_COMM_NULL`` for them — and fold back in when a later grow makes
the count divisible.

The :class:`TopologySpec` marks **one elastic dimension** (``-1``, the data
axis in training) and any number of fixed dimensions (pipeline stages, ring
size, tensor width): re-folding resolves the elastic dim to
``floor(size / prod(fixed))``.

Groups and specs are device-agnostic, so epoch algebra (generations,
shrink/grow, cache invalidation) is testable without multi-device hardware;
only :attr:`CommEpoch.comm` touches jax, and it is built lazily.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Sequence

from repro.core import errors, tool
from repro.core.communicator import Communicator
from repro.core.session import Group, Session, default_session

tool.pvar_register("epoch:create", "communicator epochs constructed (generation 0)")
tool.pvar_register("epoch:advance", "epoch transitions (shrink + grow)")
tool.pvar_register("epoch:revoke", "epochs revoked (MPI_Comm_revoke analogue)")
tool.pvar_register("epoch:rebuild", "communicator fabrics built from an epoch's group")
tool.pvar_register(
    "epoch:request_rebuild",
    "per-epoch cached derivations built (persistent requests, topologies)",
)

#: The elastic-dimension placeholder in a :class:`TopologySpec` shape.
ELASTIC = -1

_EPOCH_PSET_PREFIX = "repro://epoch/"


@dataclasses.dataclass(frozen=True)
class TopologySpec:
    """How an epoch folds its group onto a fabric.

    ``shape`` may mark at most one dimension :data:`ELASTIC` (``-1``); it
    resolves to ``floor(size / prod(fixed))`` at fold time, so the same spec
    describes the topology at every world size.  ``periods=None`` builds a
    plain multi-axis communicator; a periods tuple builds a Cartesian
    topology (:func:`repro.core.topology.cart_create`) with the resolved
    dims.
    """

    shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    periods: tuple[bool, ...] | None = None

    def __post_init__(self):
        object.__setattr__(self, "shape", tuple(int(d) for d in self.shape))
        object.__setattr__(self, "axis_names", tuple(self.axis_names))
        if self.periods is not None:
            object.__setattr__(
                self, "periods", tuple(bool(p) for p in self.periods)
            )
        errors.check(
            len(self.shape) == len(self.axis_names),
            errors.ErrorClass.ERR_DIMS,
            f"{len(self.axis_names)} axis names for shape {self.shape}",
        )
        errors.check(
            self.periods is None or len(self.periods) == len(self.shape),
            errors.ErrorClass.ERR_DIMS,
            f"{len(self.periods or ())} periods for shape {self.shape}",
        )
        elastic = [d for d in self.shape if d == ELASTIC]
        fixed = [d for d in self.shape if d != ELASTIC]
        errors.check(
            len(elastic) <= 1,
            errors.ErrorClass.ERR_DIMS,
            f"at most one elastic (-1) dimension, got shape {self.shape}",
        )
        errors.check(
            all(d > 0 for d in fixed),
            errors.ErrorClass.ERR_DIMS,
            f"fixed dims must be positive, got shape {self.shape}",
        )

    @property
    def is_cart(self) -> bool:
        return self.periods is not None

    @property
    def fixed_size(self) -> int:
        """Product of the non-elastic dims — the fold granularity."""

        return math.prod(d for d in self.shape if d != ELASTIC)

    def resolve(self, size: int) -> tuple[int, ...]:
        """Concrete dims for a group of ``size`` members: the elastic dim
        becomes ``floor(size / fixed_size)`` (``ERR_DIMS`` when not even one
        fold fits).  Members beyond ``prod(dims)`` do not fold — they idle
        (``MPI_COMM_NULL``) until a grow makes the count divisible."""

        fixed = self.fixed_size
        errors.check(
            size >= fixed,
            errors.ErrorClass.ERR_DIMS,
            f"{size} members cannot fold onto {self.shape} "
            f"(needs at least {fixed})",
        )
        if ELASTIC not in self.shape:
            return self.shape
        return tuple(size // fixed if d == ELASTIC else d for d in self.shape)

    @classmethod
    def from_plan(cls, plan) -> "TopologySpec":
        """The spec a :class:`~repro.configs.base.ParallelPlan` folds to: the
        plan's fixed axes stay fixed, the data axis is marked
        :data:`ELASTIC` so the same plan re-folds at every survivor count.
        """

        dims = plan.fold_dims()
        return cls(
            (ELASTIC,) + tuple(dims[1:]),
            plan.fold_axes(),
            plan.fold_periods(),
        )

    @classmethod
    def from_communicator(cls, comm: Communicator, *, elastic_axis: int = 0) -> "TopologySpec":
        """Derive a spec from an existing communicator: its axes and sizes,
        with ``elastic_axis`` marked elastic (the data axis by convention).
        Cartesian communicators keep their periods."""

        from repro.core import topology

        shape = tuple(
            ELASTIC if i == elastic_axis else int(comm.mesh.shape[a])
            for i, a in enumerate(comm.axis_names)
        )
        periods = (
            comm.periods if isinstance(comm, topology.CartComm) else None
        )
        return cls(shape, comm.axis_names, periods)


def _sanitize(name: str) -> str:
    return "".join(c if c.isalnum() or c in "_-" else "_" for c in name) or "epoch"


class CommEpoch:
    """One generation of a rebuildable communication fabric.

    The epoch owns a **pool** (every device currently enrolled, survivors in
    fold order) and derives from it the **active** group — the leading
    ``prod(dims)`` members after :meth:`TopologySpec.resolve` — plus the
    communicator and any cached per-epoch state.  Construction of the jax
    fabric is lazy: epoch algebra works on plain groups.

    Lifecycle (the ULFM loop)::

        epoch = CommEpoch.create(comm)          # generation 0 adopts comm
        ...
        epoch.revoke()                          # MPI_Comm_revoke
        epoch = epoch.shrink([dead_rank])       # MPI_Comm_shrink -> gen+1
        step = epoch.cached("train_step", build)  # rebuilt lazily
        ...
        epoch = epoch.grow(spare_devices)       # hot-join -> gen+1
    """

    def __init__(
        self,
        pool: Group,
        spec: TopologySpec,
        *,
        session: Session | None = None,
        name: str = "train",
        generation: int = 0,
        _comm: Communicator | None = None,
    ):
        errors.check(
            isinstance(pool, Group) and pool.size() > 0,
            errors.ErrorClass.ERR_GROUP,
            "an epoch needs a non-empty member Group",
        )
        self.pool = pool
        self.spec = spec
        self.name = _sanitize(name)
        self.generation = int(generation)
        self._session = session
        self._revoked = False
        self._comm = _comm
        self._cache: dict[str, Any] = {}
        self.dims = spec.resolve(pool.size())
        #: the active group: leading prod(dims) pool members, fold order
        self.active = pool.incl(range(math.prod(self.dims)))
        if generation == 0:
            tool.pvar_count("epoch:create")

    # -- construction --------------------------------------------------------

    @classmethod
    def create(
        cls,
        comm_or_group: Communicator | Group,
        spec: TopologySpec | None = None,
        *,
        session: Session | None = None,
        name: str = "train",
    ) -> "CommEpoch":
        """Generation 0.  From a :class:`Communicator`, the epoch *adopts*
        it — the existing fabric (mesh identity included) stays live and the
        spec defaults to :meth:`TopologySpec.from_communicator`.  From a
        :class:`Group`, ``spec`` is required and the fabric is built lazily.
        """

        if isinstance(comm_or_group, Communicator):
            comm = comm_or_group
            derived = TopologySpec.from_communicator(comm)
            spec = spec if spec is not None else derived
            # adopt the live fabric (mesh identity preserved) only when the
            # requested spec IS the comm's own shape — a Cartesian spec over
            # a plain communicator must rebuild through cart_create
            return cls(
                comm.group(), spec, session=session, name=name,
                _comm=comm if spec == derived else None,
            )
        errors.check(
            spec is not None,
            errors.ErrorClass.ERR_ARG,
            "CommEpoch.create from a Group needs an explicit TopologySpec",
        )
        return cls(comm_or_group, spec, session=session, name=name)

    # -- liveness ------------------------------------------------------------

    @property
    def revoked(self) -> bool:
        return self._revoked

    def revoke(self) -> None:
        """``MPI_Comm_revoke``: mark the epoch dead.  Idempotent.  Every
        subsequent fabric access raises ``ERR_REVOKED`` — consumers must
        re-derive from the successor epoch.  Cooperative, like everything in
        the single-host SPMD simulation: nothing interrupts in-flight work.
        """

        if not self._revoked:
            tool.pvar_count("epoch:revoke")
        self._revoked = True

    def _live(self) -> None:
        if self._revoked:
            errors.fail(
                errors.ErrorClass.ERR_REVOKED,
                f"epoch {self.generation} of {self.name!r} is revoked; "
                f"re-derive from the successor epoch",
            )

    # -- the fabric ----------------------------------------------------------

    @property
    def session(self) -> Session:
        if self._session is None:
            self._session = default_session()
        return self._session

    @property
    def pset_name(self) -> str:
        return f"{_EPOCH_PSET_PREFIX}{self.name}/{self.generation}"

    @property
    def comm(self) -> Communicator:
        """The epoch's communicator, built lazily from the active group via
        the canonical constructors (``Communicator.from_group`` /
        ``cart_create``) and registered as the epoch's process set."""

        self._live()
        if self._comm is None:
            self._comm = self._build_comm()
        return self._comm

    @property
    def mesh(self):
        return self.comm.mesh

    def _build_comm(self) -> Communicator:
        from repro.core import topology

        tool.pvar_count("epoch:rebuild")
        self.session.register_pset(self.pset_name, self.active)
        if self.spec.is_cart:
            # epoch-scoped cart tag: membership changes across generations,
            # so the dims-keyed default tag would trip the clobber guard
            dims_str = "x".join(str(d) for d in self.dims)
            return topology.cart_create(
                self.active,
                self.dims,
                self.spec.periods,
                axis_names=self.spec.axis_names,
                session=self.session,
                tag=f"{self.pset_name}/cart/{dims_str}",
            )
        return Communicator.from_group(
            self.active,
            tag=self.pset_name,
            shape=self.dims,
            axis_names=self.spec.axis_names,
        )

    def axis_size(self, name: str) -> int:
        return self.dims[self.spec.axis_names.index(name)]

    # -- per-epoch derived state (persistent requests, topologies, buckets) --

    def cached(self, key: str, build: Callable[["CommEpoch"], Any]) -> Any:
        """Derived state bound to THIS epoch's fabric, built lazily once.

        The canonical tenant is a :class:`~repro.core.futures.PersistentRequest`
        AOT-compiled against the epoch's shardings: after a shrink the old
        epoch's request raises ``ERR_REQUEST`` on the new mesh's arrays, so
        consumers ask the *current* epoch and the request is rebuilt here on
        first use — lazy, exactly once per (epoch, key)."""

        self._live()
        if key not in self._cache:
            tool.pvar_count("epoch:request_rebuild")
            self._cache[key] = build(self)
        return self._cache[key]

    def peek(self, key: str) -> Any | None:
        """The cached value if already built (no build trigger)."""

        return self._cache.get(key)

    def invalidate(self, key: str | None = None) -> None:
        if key is None:
            self._cache.clear()
        else:
            self._cache.pop(key, None)

    # -- the ULFM transitions --------------------------------------------------

    def _successor(self, pool: Group) -> "CommEpoch":
        errors.check(
            pool.size() > 0,
            errors.ErrorClass.ERR_PROC_FAILED,
            f"epoch {self.generation} of {self.name!r} has no survivors",
        )
        tool.pvar_count("epoch:advance")
        return CommEpoch(
            pool,
            self.spec,
            session=self._session,
            name=self.name,
            generation=self.generation + 1,
        )

    def _as_devices(self, members: Iterable[Any]) -> list[Any]:
        """Ranks (ints, resolved in the ACTIVE group) or devices, mixed."""

        out = []
        for m in members:
            out.append(self.active.device(m) if isinstance(m, int) else m)
        return out

    def shrink(self, dead: Iterable[Any] | Group) -> "CommEpoch":
        """``MPI_Comm_shrink``: the successor epoch over the survivor pool
        (``Group.difference``).  ``dead`` is a Group, or an iterable of
        devices / active-group ranks.  Revokes this epoch."""

        dead_group = (
            dead if isinstance(dead, Group) else Group(self._as_devices(dead))
        )
        self.revoke()
        return self._successor(self.pool.difference(dead_group))

    def grow(self, new_members: Iterable[Any] | Group) -> "CommEpoch":
        """The reverse path: hot-join ``new_members`` (appended in pool
        order — ``Group.union`` keeps survivors' ranks stable) and re-fold
        the elastic axis.  Revokes this epoch."""

        new_group = (
            new_members
            if isinstance(new_members, Group)
            else Group(new_members)
        )
        self.revoke()
        return self._successor(self.pool.union(new_group))

    def __repr__(self) -> str:
        state = "revoked" if self._revoked else "live"
        return (
            f"CommEpoch({self.name!r}, gen={self.generation}, "
            f"dims={self.dims}, pool={self.pool.size()}, {state})"
        )
