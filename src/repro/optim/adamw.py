"""AdamW with sharded moments and optional 8-bit moment storage.

The moment pytrees inherit the parameters' shardings (FSDP: optimizer state
is sharded exactly like the weights — ZeRO-style, for free under GSPMD).
``moment_dtype='int8'`` stores both moments block-quantized (per-block absmax
scales, the kernels/quant scheme) and dequantizes on use — 4x optimizer-state
memory reduction, the standard trick for fitting 300B-scale optimizer state
(grok-1 / deepseek-v2 cells).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.kernels.quant import ops as quant

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class _Q8:
    """A block-quantized tensor (int8 payload + fp32 per-block scales)."""

    q: jax.Array
    scale: jax.Array
    meta: tuple[int, tuple[int, ...]] = dataclasses.field(
        metadata=dict(static=True), default=(0, ())
    )  # (pad, shape)


def _q8_of(x: jax.Array) -> _Q8:
    """SHAPE-PRESERVING int8 storage: payload keeps the parameter's shape
    (scales per last axis).  Flattened payloads were tried and refuted at
    scale (§Perf B5): a sharded 1-D buffer reshaped back to the parameter
    shape forces an all-gather, replicating 2·N f32 bytes of dequantized
    moments per device.  Shape-preserving storage inherits the parameter
    sharding through every elementwise step instead."""

    if x.ndim == 0:
        return _Q8(q=x.astype(jnp.int8), scale=jnp.ones((), jnp.float32),
                   meta=(0, tuple(x.shape)))
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return _Q8(q=q, scale=scale, meta=(0, tuple(x.shape)))


def _q8_read(z: _Q8) -> jax.Array:
    if not z.meta[1]:
        return z.q.astype(jnp.float32)
    return z.q.astype(jnp.float32) * z.scale


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    mu: Params
    nu: Params


def _moment_store(x: jax.Array, dtype: str):
    if dtype == "int8":
        return _q8_of(x)
    return x.astype(jnp.dtype(dtype))


def _moment_read(z) -> jax.Array:
    if isinstance(z, _Q8):
        return _q8_read(z)
    return z.astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class AdamW:
    """Functional AdamW: ``init(params) -> state``; ``update`` returns new
    (params, state).  ``lr`` may be a float or a ``step -> lr`` schedule."""

    lr: float | Callable = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    moment_dtype: str = "float32"   # float32 | bfloat16 | int8

    def init(self, params: Params) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: _moment_store(jnp.zeros(p.shape, jnp.float32), self.moment_dtype),
            params,
        )
        zeros2 = jax.tree.map(
            lambda p: _moment_store(jnp.zeros(p.shape, jnp.float32), self.moment_dtype),
            params,
        )
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros2)

    def _lr_at(self, step):
        return self.lr(step) if callable(self.lr) else jnp.asarray(self.lr, jnp.float32)

    def update(
        self, grads: Params, state: AdamWState, params: Params
    ) -> tuple[Params, AdamWState]:
        step = state.step + 1
        lr = self._lr_at(step)
        b1, b2 = self.b1, self.b2
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        is_q8 = lambda x: isinstance(x, _Q8)

        def upd(p, g, mu_z, nu_z):
            g = g.astype(jnp.float32)
            mu = b1 * _moment_read(mu_z) + (1 - b1) * g
            nu = b2 * _moment_read(nu_z) + (1 - b2) * g * g
            mu_hat = mu / bc1
            nu_hat = nu / bc2
            step_dir = mu_hat / (jnp.sqrt(nu_hat) + self.eps)
            if p.ndim >= 1:  # decoupled decay on matrices/vectors, not scalars
                step_dir = step_dir + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
            return new_p, _moment_store(mu, self.moment_dtype), _moment_store(
                nu, self.moment_dtype
            )

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_mu = treedef.flatten_up_to(state.mu)
        flat_nu = treedef.flatten_up_to(state.nu)
        out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_mu = treedef.unflatten([o[1] for o in out])
        new_nu = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(step=step, mu=new_mu, nu=new_nu)
