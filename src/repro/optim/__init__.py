"""Optimizer substrate: AdamW with sharded (and optionally 8-bit) moments,
LR schedules, global-norm clipping, and gradient synchronisation built on the
``repro.core`` interface (hierarchical / compressed cross-pod reduction)."""

from repro.optim.adamw import AdamW, AdamWState  # noqa: F401
from repro.optim.schedules import constant, cosine_warmup, linear_warmup  # noqa: F401
from repro.optim.grad_sync import (  # noqa: F401
    ErrorFeedbackState,
    PartitionedGradSync,
    sync_gradients,
)
from repro.optim.clip import clip_by_global_norm, global_norm  # noqa: F401
