"""Partition-overlapped gradient synchronisation through ``repro.core``.

Under pure ``jit`` (GSPMD), gradient reduction is implicit in the partitioned
backward pass; this module is the *explicit* path used when the trainer runs
data-parallel replicas under ``shard_map`` — and the home of the cross-pod
distributed-optimization tricks:

* **partitioned reduction** (MPI 4.0 partitioned communication): the bucketed
  gradient pytree becomes a :class:`~repro.core.futures.PartitionedRequest` —
  each per-dtype bucket is one partition, marked ready (``MPI_Pready``) as
  the backward pass produces its gradients and reduced as a lazy
  :class:`~repro.core.futures.TraceFuture`, so per-bucket communication
  interleaves with the compute producing later buckets.  Results are
  independent of the ready order (:meth:`PartitionedGradSync.__call__`
  accepts any ``pready_order``);
* hierarchical reduction (reduce-scatter intra-pod, all-reduce inter-pod,
  all-gather intra-pod) so only 1/inner_size of the payload crosses DCN;
* int8 compression with **error feedback** (EF-SGD, Karimireddy et al.):
  each rank compresses its *message* ``m = g + e``, transmits the compressed
  form, and carries the compression error ``e' = m - C(m)`` into the next
  step — which preserves SGD convergence under biased compressors;
* bucketed flattening via the datatype layer: one collective per dtype group
  instead of one per tensor (the MPI derived-datatype lesson applied to
  gradients).

:func:`sync_gradients` is the stable functional entry point; it constructs a
:class:`PartitionedGradSync` per call.  Long-lived callers (the trainer's
explicit-collective path) hold one :class:`PartitionedGradSync` and re-fire
it every step.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core import datatypes, errors
from repro.core.communicator import Communicator
from repro.core.descriptors import Compression
from repro.core.futures import PartitionedRequest
from repro.core.overlap import hierarchical_allreduce
from repro.kernels.quant import ops as quant

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Params  # same treedef as grads (fp32 leaves)

    @classmethod
    def init(cls, grads: Params) -> "ErrorFeedbackState":
        return cls(residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _compress_with_feedback(g: jax.Array, e: jax.Array) -> tuple[jax.Array, jax.Array]:
    """EF step for one leaf: returns (C(g+e) dequantized, new residual)."""

    m = g.astype(jnp.float32) + e
    flat = m.reshape(-1)
    q, scale, pad = quant.quantize_int8(flat)
    cm = quant.dequantize_int8(q, scale, pad, flat.shape, jnp.float32).reshape(m.shape)
    return cm, m - cm


class PartitionedGradSync:
    """Gradient all-reduce as a partitioned request over dtype buckets.

    One instance fixes the communicator topology and compression mode; each
    ``__call__`` packs the gradient pytree into per-dtype buckets, activates
    a :class:`PartitionedRequest` with one partition per bucket, marks each
    bucket ready (in ``pready_order`` — any order yields identical results),
    and waits.  Because every partition is a lazy trace future, XLA sees each
    bucket's reduction as an independent dependence-graph node anchored where
    its gradients are produced — the compiler overlaps bucket ``i``'s
    collective with the compute for bucket ``i+1`` (backward-overlap).
    """

    def __init__(
        self,
        inner: Communicator,
        outer: Communicator | None = None,
        *,
        compression: Compression = Compression.NONE,
        mean: bool = True,
    ):
        self.inner = inner
        self.outer = outer
        self.compression = compression
        self.mean = mean

    @classmethod
    def for_epoch(
        cls,
        epoch,
        *,
        compression: Compression = Compression.NONE,
        mean: bool = True,
        key: str = "grad_sync",
    ) -> "PartitionedGradSync":
        """The epoch-derived sync: one instance per
        :class:`~repro.core.epoch.CommEpoch`, held in the epoch's cache so a
        shrink/grow re-initialises the buckets against the successor fabric
        on first use (the revoked epoch raises ``ERR_REVOKED`` instead of
        silently reducing over dead ranks)."""

        return epoch.cached(
            key, lambda ep: cls(ep.comm, compression=compression, mean=mean)
        )

    # -- one bucket -----------------------------------------------------------

    def _reduce_bucket(self, index: int, buf: jax.Array) -> jax.Array:
        if self.outer is None:
            return jax.lax.psum(buf, self.inner.axis_names)
        return hierarchical_allreduce(
            buf, self.inner, self.outer, compression=self.compression
        )

    # -- the full pytree ------------------------------------------------------

    def __call__(
        self,
        grads: Params,
        ef: ErrorFeedbackState | None = None,
        *,
        pready_order: Sequence[int] | None = None,
    ) -> tuple[Params, ErrorFeedbackState | None]:
        """All-reduce a gradient pytree across data-parallel ranks.

        Single fabric (``outer is None``): one bucketed all-reduce per dtype
        group.  Two fabrics: hierarchical reduction; with
        ``compression=INT8`` the inter-pod stage additionally moves int8
        payloads, and — when ``ef`` is provided — the rank-local message is
        error-feedback compressed first.  Returns (synchronised grads, new
        error-feedback state).
        """

        n_total = self.inner.size() * (self.outer.size() if self.outer is not None else 1)
        scale = 1.0 / n_total if self.mean else 1.0

        new_ef = ef
        if self.compression is Compression.INT8 and ef is not None:
            flat_g, treedef = jax.tree.flatten(grads)
            flat_e = treedef.flatten_up_to(ef.residual)
            pairs = [_compress_with_feedback(g, e) for g, e in zip(flat_g, flat_e)]
            grads = treedef.unflatten([p[0] for p in pairs])
            new_ef = ErrorFeedbackState(residual=treedef.unflatten([p[1] for p in pairs]))

        # bucketed: the pytree packs into per-dtype buffers; buckets are the
        # partitions of one partitioned request, each reduced independently
        bufs, dtype_desc = datatypes.pack(grads)
        req = PartitionedRequest(self._reduce_bucket, len(bufs)).start()
        order = tuple(pready_order) if pready_order is not None else tuple(range(len(bufs)))
        errors.check(
            sorted(order) == list(range(len(bufs))),
            errors.ErrorClass.ERR_REQUEST,
            f"pready_order {order} is not a permutation of {len(bufs)} buckets",
        )
        for i in order:
            req.pready(i, bufs[i])
        reduced = req.wait()                 # index order: pready-order independent
        synced = datatypes.unpack(reduced, dtype_desc)

        out = jax.tree.map(lambda s: (s.astype(jnp.float32) * scale).astype(s.dtype), synced)
        return out, new_ef


def sync_gradients(
    grads: Params,
    inner: Communicator,
    outer: Communicator | None = None,
    *,
    compression: Compression = Compression.NONE,
    ef: ErrorFeedbackState | None = None,
    mean: bool = True,
    pready_order: Sequence[int] | None = None,
) -> tuple[Params, ErrorFeedbackState | None]:
    """Functional wrapper over :class:`PartitionedGradSync` (stable API)."""

    sync = PartitionedGradSync(inner, outer, compression=compression, mean=mean)
    return sync(grads, ef, pready_order=pready_order)
