"""Gradient synchronisation through the ``repro.core`` interface.

Under pure ``jit`` (GSPMD), gradient reduction is implicit in the partitioned
backward pass; this module is the *explicit* path used when the trainer runs
data-parallel replicas under ``shard_map`` — and the home of the cross-pod
distributed-optimization tricks:

* hierarchical reduction (reduce-scatter intra-pod, all-reduce inter-pod,
  all-gather intra-pod) so only 1/inner_size of the payload crosses DCN;
* int8 compression with **error feedback** (EF-SGD, Karimireddy et al.):
  each rank compresses its *message* ``m = g + e``, transmits the compressed
  form, and carries the compression error ``e' = m - C(m)`` into the next
  step — which preserves SGD convergence under biased compressors;
* bucketed flattening via the datatype layer: one collective per dtype group
  instead of one per tensor (the MPI derived-datatype lesson applied to
  gradients).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import datatypes
from repro.core.communicator import Communicator
from repro.core.descriptors import Compression
from repro.core.overlap import hierarchical_allreduce
from repro.kernels.quant import ops as quant

Params = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Params  # same treedef as grads (fp32 leaves)

    @classmethod
    def init(cls, grads: Params) -> "ErrorFeedbackState":
        return cls(residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def _compress_with_feedback(g: jax.Array, e: jax.Array) -> tuple[jax.Array, jax.Array]:
    """EF step for one leaf: returns (C(g+e) dequantized, new residual)."""

    m = g.astype(jnp.float32) + e
    flat = m.reshape(-1)
    q, scale, pad = quant.quantize_int8(flat)
    cm = quant.dequantize_int8(q, scale, pad, flat.shape, jnp.float32).reshape(m.shape)
    return cm, m - cm


def sync_gradients(
    grads: Params,
    inner: Communicator,
    outer: Communicator | None = None,
    *,
    compression: Compression = Compression.NONE,
    ef: ErrorFeedbackState | None = None,
    mean: bool = True,
) -> tuple[Params, ErrorFeedbackState | None]:
    """All-reduce a gradient pytree across data-parallel ranks.

    Single fabric (``outer is None``): one bucketed all-reduce per dtype
    group.  Two fabrics: hierarchical reduction; with ``compression=INT8``
    the inter-pod stage additionally moves int8 payloads, and — when ``ef``
    is provided — the rank-local message is error-feedback compressed first.
    Returns (synchronised grads, new error-feedback state).
    """

    n_total = inner.size() * (outer.size() if outer is not None else 1)
    scale = 1.0 / n_total if mean else 1.0

    new_ef = ef
    if compression is Compression.INT8 and ef is not None:
        flat_g, treedef = jax.tree.flatten(grads)
        flat_e = treedef.flatten_up_to(ef.residual)
        pairs = [_compress_with_feedback(g, e) for g, e in zip(flat_g, flat_e)]
        grads = treedef.unflatten([p[0] for p in pairs])
        new_ef = ErrorFeedbackState(residual=treedef.unflatten([p[1] for p in pairs]))

    def reduce_leaf(g):
        if outer is None:
            return jax.lax.psum(g, inner.axis_names)
        return hierarchical_allreduce(g, inner, outer, compression=compression)

    # bucketed: pack the whole pytree into per-dtype buffers, reduce each once
    bufs, dtype_desc = datatypes.pack(grads)
    reduced = [reduce_leaf(b) for b in bufs]
    synced = datatypes.unpack(reduced, dtype_desc)

    out = jax.tree.map(lambda s: (s.astype(jnp.float32) * scale).astype(s.dtype), synced)
    return out, new_ef
