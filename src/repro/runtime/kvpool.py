"""Paged KV block pool: sub-allocation accounting over a serving cache.

The continuous-batching engine (:mod:`repro.runtime.engine`) stores every
request's KV in a fixed slot table — a cache of ``num_slots`` rows, each
``slot_capacity`` tokens deep.  This module carves that storage into fixed
*blocks* of ``block_tokens`` tokens (the vLLM page) and accounts for them
like MPI sub-allocated window memory:

* block ``slot * blocks_per_slot + j`` backs tokens
  ``[j * block_tokens, (j + 1) * block_tokens)`` of ``slot`` — blocks are
  slot-affine because the cache layout is slot-major;
* a *budget* (``budget_blocks``) caps how many blocks may be live at once.
  The budget is what creates memory pressure: the engine admits and grows
  requests block-by-block and must preempt somebody when ``ensure`` raises
  ``ERR_NO_MEM``;
* bound to a *dynamic* RMA window (``WindowSpec(dynamic=True)``, the
  ``MPI_Win_create_dynamic`` analogue), every allocation attaches the
  matching window pages and every release detaches them — the attach set IS
  the free-list, and a ``put`` to an unallocated block fails with
  ``ERR_RMA_RANGE`` instead of silently landing in freed memory.

All accounting is host-side and trace-free; the arrays never move.
"""

from __future__ import annotations

import math

from repro.core import errors, tool

tool.pvar_register("kvpool_alloc", "KV blocks allocated (window pages attached)")
tool.pvar_register("kvpool_free", "KV blocks released (window pages detached)")


class KVBlockPool:
    """Free-list + per-slot block tables for a slot-major paged KV cache."""

    def __init__(
        self,
        *,
        num_slots: int,
        slot_capacity: int,
        block_tokens: int,
        budget_blocks: int | None = None,
    ):
        errors.check(
            num_slots >= 1 and slot_capacity >= 1 and block_tokens >= 1,
            errors.ErrorClass.ERR_ARG,
            f"pool needs positive num_slots/slot_capacity/block_tokens, got "
            f"{num_slots}/{slot_capacity}/{block_tokens}",
        )
        self.num_slots = int(num_slots)
        self.slot_capacity = int(slot_capacity)
        self.block_tokens = int(block_tokens)
        self.blocks_per_slot = math.ceil(slot_capacity / block_tokens)
        self.total_blocks = self.num_slots * self.blocks_per_slot
        self.budget_blocks = (
            self.total_blocks if budget_blocks is None else int(budget_blocks)
        )
        errors.check(
            self.blocks_per_slot <= self.budget_blocks <= self.total_blocks,
            errors.ErrorClass.ERR_NO_MEM,
            f"budget_blocks={self.budget_blocks} must cover at least one full "
            f"slot ({self.blocks_per_slot} blocks; a single request could "
            f"never run) and at most the pool ({self.total_blocks})",
        )
        self._held: dict[int, int] = {}   # slot -> blocks held (prefix count)
        self._live = 0
        self._window = None

    # -- geometry -----------------------------------------------------------

    def blocks_for(self, tokens: int) -> int:
        """Blocks needed to hold ``tokens`` cached tokens."""

        return math.ceil(int(tokens) / self.block_tokens)

    def block_ids(self, slot: int, count: int | None = None) -> list[int]:
        """The (slot-affine) block ids backing ``slot``'s first ``count``
        blocks (all held blocks when ``count`` is None)."""

        n = self._held.get(int(slot), 0) if count is None else int(count)
        base = int(slot) * self.blocks_per_slot
        return [base + j for j in range(n)]

    @property
    def live_blocks(self) -> int:
        return self._live

    @property
    def free_blocks(self) -> int:
        return self.budget_blocks - self._live

    def held(self, slot: int) -> int:
        return self._held.get(int(slot), 0)

    def fits(self, slot: int, tokens: int) -> bool:
        """Would :meth:`ensure` succeed without raising?"""

        grow = self.blocks_for(tokens) - self.held(slot)
        return grow <= 0 or self._live + grow <= self.budget_blocks

    # -- allocation ---------------------------------------------------------

    def ensure(self, slot: int, tokens: int) -> list[int]:
        """Grow ``slot``'s table to cover ``tokens`` cached tokens; returns
        the newly allocated block ids ([] when already covered).  Raises
        ``ERR_NO_MEM`` when the budget cannot absorb the growth — the signal
        the engine answers with preemption."""

        slot = int(slot)
        errors.check(
            0 <= slot < self.num_slots,
            errors.ErrorClass.ERR_ARG,
            f"slot {slot} outside pool of {self.num_slots}",
        )
        need = self.blocks_for(tokens)
        errors.check(
            need <= self.blocks_per_slot,
            errors.ErrorClass.ERR_RMA_RANGE,
            f"{tokens} tokens need {need} blocks, a slot holds only "
            f"{self.blocks_per_slot} ({self.slot_capacity} tokens)",
        )
        have = self.held(slot)
        if need <= have:
            return []
        grow = need - have
        if self._live + grow > self.budget_blocks:
            errors.fail(
                errors.ErrorClass.ERR_NO_MEM,
                f"slot {slot} needs {grow} more block(s); "
                f"{self.free_blocks} of {self.budget_blocks} free",
            )
        base = slot * self.blocks_per_slot
        ids = [base + j for j in range(have, need)]
        self._held[slot] = need
        self._live += grow
        tool.pvar_add("kvpool_alloc", grow)
        if self._window is not None:
            self._window.attach(ids)
        return ids

    def release(self, slot: int) -> list[int]:
        """Free every block ``slot`` holds (request retired or preempted);
        returns the freed ids.  Freed ids are reused verbatim by the next
        occupant of the slot — the block-table reuse the engine tests pin."""

        slot = int(slot)
        have = self._held.pop(slot, 0)
        if not have:
            return []
        base = slot * self.blocks_per_slot
        ids = [base + j for j in range(have)]
        self._live -= have
        tool.pvar_add("kvpool_free", have)
        if self._window is not None:
            self._window.detach(ids)
        return ids

    # -- RMA window binding --------------------------------------------------

    def bind_window(self, window) -> None:
        """Mirror the pool into a dynamic RMA window: one window page per
        block.  From here on ``ensure``/``release`` attach/detach the
        matching pages, so remote KV writes (prefill ``rput``\\ s into the
        decode ranks' window) can only target live blocks."""

        errors.check(
            getattr(window.spec, "dynamic", False),
            errors.ErrorClass.ERR_WIN,
            "pool binding needs a dynamic window (WindowSpec(dynamic=True))",
        )
        errors.check(
            window.spec.num_pages == self.total_blocks,
            errors.ErrorClass.ERR_RMA_RANGE,
            f"window has {window.spec.num_pages} pages, pool has "
            f"{self.total_blocks} blocks — one page per block required",
        )
        self._window = window
        live = [b for s in self._held for b in self.block_ids(s)]
        if live:
            window.attach(live)
