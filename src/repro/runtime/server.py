"""The Server: batched prefill + decode serving loop.

Continuous-batching-lite: requests are grouped into fixed-size batches
(padded to ``max_batch``), prefilled once, then decoded step-by-step over
the persistent KV/SSM cache.  The cache is sharded per
``repro.sharding.rules`` (batch over data axes, heads or sequence over model
axis; int8 cache when configured).

**Persistent decode engine**: the single-token decode step — the serving hot
loop — is built once per argument signature as a
:class:`~repro.core.futures.PersistentRequest` (AOT lower + compile, cache
donated) and re-fired ``MPI_Start``-style for every token; the prefill step
is persistent per prompt-shape bucket the same way.  Steady-state decode can
never re-trace (``trace:decode_step`` pvar stays at one per signature).

**Disaggregated prefill/decode** (:class:`DisaggregatedServer`): the serving
process set is split into a *prefill* group and a *decode* group (PR 1 group
algebra); prefill ranks compute the KV cache and ``rput`` it page-by-page
into an RMA window on the decode ranks (C1 one-sided, MPI 4.0 chapter 12),
and the decode group rides its existing persistent decode request.  At
``temperature=0`` the disaggregated pipeline is token-for-token identical to
the single-group :meth:`Server.generate`.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import collectives, errors, futures, onesided, tool, topology
from repro.core.communicator import Communicator
from repro.core.futures import PersistentRequest, argument_signature
from repro.core.session import Session, default_session
from repro.models import api as model_api
from repro.sharding import rules

tool.pvar_register("trace:prefill_step", "prefill executables traced (want 1 per shape bucket)")
tool.pvar_register("trace:decode_step", "decode executables traced (want 1 per shape bucket)")
tool.pvar_register("trace:kv_transfer", "KV-transfer executables traced (want 1 per shape)")


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 8
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0
    # generation stops for a row once it emits this token; ``None`` decodes
    # the full ``max_new_tokens`` budget for every row
    stop_token: int | None = None


@dataclasses.dataclass
class Request:
    tokens: np.ndarray             # (prompt_len,) int32
    extra: dict = dataclasses.field(default_factory=dict)


def generation_lengths(tokens: np.ndarray, stop_token: int | None) -> np.ndarray:
    """Per-request generated length: tokens up to and including the first
    stop token; the full row when it never stops (or no stop is configured).
    Tokens a row emits *after* its stop are padding, not throughput — the
    old ``tokens.size`` accounting billed them as served work."""

    b, n = tokens.shape
    if stop_token is None:
        return np.full((b,), n, np.int64)
    hit = tokens == stop_token
    return np.where(hit.any(axis=1), hit.argmax(axis=1) + 1, n).astype(np.int64)


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        pcfg: ParallelConfig,
        scfg: ServerConfig,
        comm: Communicator | Mesh,
    ):
        self.cfg, self.pcfg, self.scfg = cfg, pcfg, scfg
        # serving owns its process set: a session-derived communicator (a
        # bare Mesh is wrapped unmanaged for older call sites)
        self.comm = comm if isinstance(comm, Communicator) else Communicator(comm)
        self.mesh = mesh = self.comm.mesh
        self.bundle = model_api.build(cfg)
        with mesh:
            self.params = jax.jit(self.bundle.init)(jax.random.PRNGKey(scfg.seed))
            pspecs = rules.param_specs(self.params, mesh, pcfg)
            self.params = jax.device_put(self.params, rules.shardings(pspecs, mesh))
        # persistent steps, keyed by argument signature (shape bucket): one
        # AOT compile per bucket, MPI_Start re-fires ever after
        self._prefill_reqs: dict[tuple, PersistentRequest] = {}
        self._decode_reqs: dict[tuple, PersistentRequest] = {}
        # per-call PRNG counter: each generate() folds this into the seed key
        self._generate_calls = 0

    # -- persistent step construction -------------------------------------------

    def _prefill_request(self, batch, extra_capacity: int | None = None) -> PersistentRequest:
        # the decode headroom is part of the bucket key: the engine re-prefills
        # resumed requests with a *shrunken* extra so cache capacity stays at
        # the fixed prompt_bucket + max_new invariant
        extra = self.scfg.max_new_tokens if extra_capacity is None else int(extra_capacity)
        key = (argument_signature(batch), extra)
        req = self._prefill_reqs.get(key)
        if req is None:
            def prefill_step(p, b):
                tool.pvar_count("trace:prefill_step")
                # ring attention shards the prompt sequence over the model
                # axis (long prompts whose KV exceeds one device's budget);
                # the prefill needs the mesh to fold the cart ring onto
                mesh = self.mesh if self.pcfg.ring_attention else None
                return self.bundle.prefill(
                    p, b, self.pcfg, mesh, extra_capacity=extra,
                )

            req = PersistentRequest(jax.jit(prefill_step), (self.params, batch))
            self._prefill_reqs[key] = req
        return req

    def _decode_request(self, cache, tok) -> PersistentRequest:
        key = argument_signature((cache, tok))
        req = self._decode_reqs.get(key)
        if req is None:
            def decode_step(p, c, t):
                tool.pvar_count("trace:decode_step")
                return self.bundle.decode(p, c, t, self.pcfg, None)

            req = PersistentRequest(
                jax.jit(decode_step, donate_argnums=(1,)),
                (self.params, cache, tok),
                donate_argnums=(1,),
            )
            self._decode_reqs[key] = req
        return req

    # -- batching ---------------------------------------------------------------

    def _pad_batch(self, requests: list[Request]) -> tuple[dict, np.ndarray]:
        b = len(requests)
        pl = max(len(r.tokens) for r in requests)
        toks = np.zeros((b, pl), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, r in enumerate(requests):
            toks[i, pl - len(r.tokens):] = r.tokens  # left-pad: last token aligned
            lens[i] = len(r.tokens)
        batch = {"tokens": jnp.asarray(toks)}
        # the key set is the UNION over the batch (keying off requests[0]
        # would silently drop extras it happens to lack), and every request
        # must supply every key — a ragged batch is an argument error
        extra_keys = sorted({k for r in requests for k in r.extra})
        for k in extra_keys:
            vals = []
            for i, r in enumerate(requests):
                errors.check(
                    k in r.extra,
                    errors.ErrorClass.ERR_ARG,
                    f"request {i} is missing extra {k!r} present elsewhere in "
                    f"the batch (keys: {extra_keys})",
                )
                vals.append(jnp.asarray(r.extra[k]))
            batch[k] = jnp.stack(vals)
        return batch, lens

    # -- serving ------------------------------------------------------------------

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        logits = logits[:, -1, : self.cfg.vocab_size]
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)

    def _next_key(self) -> jax.Array:
        """Per-call PRNG key: the seed key folded with a call counter, so
        successive batches at ``temperature > 0`` sample fresh keys (a fixed
        ``PRNGKey(seed)`` made every batch sample identically)."""

        key = jax.random.fold_in(
            jax.random.PRNGKey(self.scfg.seed), self._generate_calls
        )
        self._generate_calls += 1
        return key

    def _decode_loop(self, cache, tok, key) -> list[jax.Array]:
        """The persistent decode loop: ``max_new_tokens - 1`` re-fires of the
        compiled decode step (shared verbatim by the disaggregated server so
        both paths are token-for-token identical)."""

        outs = [tok]
        decode = self._decode_request(cache, tok[:, None])
        for _ in range(self.scfg.max_new_tokens - 1):
            key, sub = jax.random.split(key)
            logits, cache = decode(self.params, cache, tok[:, None])
            tok = self._sample(logits, sub)
            outs.append(tok)
        jax.block_until_ready(tok)
        return outs

    def generate(self, requests: list[Request]) -> tuple[np.ndarray, dict]:
        """Prefill + greedy/temperature decode.  Returns (tokens
        (B, max_new), stats)."""

        t0 = time.perf_counter()
        batch, _lens = self._pad_batch(requests)
        key = self._next_key()
        with self.mesh:
            logits, cache = self._prefill_request(batch)(self.params, batch)
            t_prefill = time.perf_counter() - t0

            tok = self._sample(logits, key)
            t1 = time.perf_counter()
            outs = self._decode_loop(cache, tok, key)
            t_decode = time.perf_counter() - t1
        tokens = np.stack([np.asarray(t) for t in outs], axis=1)
        gen_lens = generation_lengths(tokens, self.scfg.stop_token)
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "gen_lens": gen_lens.tolist(),
            "generated_tokens": int(gen_lens.sum()),
            "tokens_per_s": int(gen_lens.sum()) / max(t_decode, 1e-9),
            "batch": len(requests),
        }
        return tokens, stats


# ---------------------------------------------------------------------------
# disaggregated prefill/decode serving (the RMA transport)
# ---------------------------------------------------------------------------


class DisaggregatedServer:
    """Prefill and decode on *disjoint* groups of one serving process set,
    with the KV cache crossing between them through an RMA window.

    The session pset is split with the PR 1 group algebra: the leading
    ``prefill_fraction`` of the set becomes ``<pset>/prefill``, the rest
    ``<pset>/decode`` (both registered on the session).  Three communicators
    are carved out of it:

    * ``prefill`` — a ``(k, 1)`` data×model grid; runs the persistent
      prefill request and samples the first token;
    * ``decode``  — a ``(m, 1)`` grid; rides the existing persistent decode
      request for every subsequent token;
    * ``bridge``  — one axis over the union, ordered prefill-then-decode;
      carries the KV handoff.

    The handoff itself is a :class:`~repro.core.futures.PersistentRequest`
    over the bridge (compiled once per cache signature) whose body is pure
    chapter-12 RMA: the decode ranks expose a zero-initialised window over
    the cache's derived datatype, prefill rank ``i`` ``rput``\\ s the packed
    cache page-by-page into decode rank ``i``'s window (each page's request
    chained onto the previous with ``then()``, joined with ``when_all``
    before the closing fence), and the epoch-close fence completes the
    transfer.  At ``temperature=0`` the generated tokens are identical to
    the single-group :meth:`Server.generate` baseline.

    With a single-device process set the groups degenerate to the same
    device (prefill == decode == the set); the transport still runs, over a
    one-rank bridge.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        pcfg: ParallelConfig,
        scfg: ServerConfig,
        session: Session | None = None,
        *,
        pset: str = "repro://world",
        prefill_fraction: float = 0.5,
        kv_pages: int = 4,
        fanout: tuple[int, int] | None = None,
    ):
        sess = session if session is not None else default_session()
        g = sess.group(pset)
        n = g.size()
        if fanout is not None:
            # explicit heterogeneous P:D split (2:6, 3:5, ...) — the KV
            # routing follows the dist-graph adjacency rather than the
            # paired i -> k+i bridge permutation
            pf, df = int(fanout[0]), int(fanout[1])
            errors.check(
                pf + df == n and n > 1,
                errors.ErrorClass.ERR_TOPOLOGY,
                f"fan-out {pf}:{df} needs a {pf + df}-rank process set, "
                f"pset {pset!r} has {n}",
            )
            k, prefill_g, decode_g = pf, g.incl(range(pf)), g.excl(range(pf))
        else:
            errors.check(
                0.0 < prefill_fraction < 1.0,
                errors.ErrorClass.ERR_ARG,
                f"prefill_fraction must be in (0, 1), got {prefill_fraction}",
            )
            if n > 1:
                k = min(n - 1, max(1, round(n * prefill_fraction)))
                prefill_g, decode_g = g.incl(range(k)), g.excl(range(k))
            else:
                k, prefill_g, decode_g = 1, g, g  # degenerate single-device set
        sess.register_pset(f"{pset}/prefill", prefill_g)
        sess.register_pset(f"{pset}/decode", decode_g)
        self.prefill = Server(
            cfg, pcfg, scfg,
            Communicator.from_group(
                prefill_g, tag=f"{pset}/prefill",
                shape=(prefill_g.size(), 1), axis_names=("data", "model"),
            ),
        )
        self.decode = Server(
            cfg, pcfg, scfg,
            Communicator.from_group(
                decode_g, tag=f"{pset}/decode",
                shape=(decode_g.size(), 1), axis_names=("data", "model"),
            ),
        )
        self.bridge = Communicator.from_group(
            prefill_g | decode_g, tag=f"{pset}/bridge"
        )
        # bridge ranks: prefill devices first, then decode's (group union
        # order); pair prefill i -> decode i (distinct targets: ERR_RANK
        # guards duplicates)
        if fanout is not None:
            # the routing IS the graph: every dist-graph edge becomes a
            # window rput pair, so decode rank P+j pulls from prefill j % P
            self.graph = topology.serving_fanout_graph(self.bridge, pf, df)
            self._perm = topology.fanout_routes(
                *topology.serving_fanout_adjacency(pf, df)
            )
            self._decode_root = pf
        else:
            self.graph = None
            pairs = min(prefill_g.size(), decode_g.size())
            if n > 1:
                self._perm = [(i, k + i) for i in range(pairs)]
                self._decode_root = k
            else:
                self._perm = [(0, 0)]
                self._decode_root = 0
        self.fanout = fanout
        self.kv_pages = int(kv_pages)
        self.scfg = scfg
        self._transfer_reqs: dict[tuple, PersistentRequest] = {}

    # -- the RMA transport --------------------------------------------------

    def _transfer_request(self, staged_cache) -> PersistentRequest:
        key = argument_signature(staged_cache)
        req = self._transfer_reqs.get(key)
        if req is None:
            bridge, pages, root = self.bridge, self.kv_pages, self._decode_root
            # a heterogeneous fan-out gives one prefill origin several decode
            # targets; send_recv carries at most one target per origin, so
            # each page goes out as one rput per round (targets are disjoint
            # across rounds — decode ranks have exactly one source)
            rounds = topology.fanout_rounds(self._perm)

            def move(cache):
                tool.pvar_count("trace:kv_transfer")
                win = onesided.Window(
                    bridge, jax.tree_util.tree_map(jnp.zeros_like, cache)
                )
                win.fence()

                def page_puts(p):
                    return futures.when_all(
                        [win.rput(cache, rnd, page=(p, pages)) for rnd in rounds]
                    )

                futs = [page_puts(0)]
                for p in range(1, pages):
                    # each page's request chains onto its predecessor: the
                    # continuation completes the previous transfer, then
                    # issues (and completes) the next page's rputs
                    futs.append(futs[-1].then(
                        lambda f, _p=p: (f.get(), page_puts(_p).get())[1]
                    ))
                futures.when_all(futs).get()   # MPI_Waitall before the close
                win.fence()                    # epoch close completes the epoch
                # replicate the decode group's window content so the output
                # is well-defined on every rank (the buffers started as
                # zeros: a value here *proved* the window carried it)
                return collectives.broadcast(bridge, win.buffer, root=root)

            req = self.bridge.persistent(move, staged_cache)
            self._transfer_reqs[key] = req
        return req

    def _transfer(self, cache) -> tuple[Any, dict]:
        """Move the prefill-side cache into the decode group via the window;
        returns (decode-side cache, transfer stats)."""

        t0 = time.perf_counter()
        staged = jax.device_put(cache, self.bridge.sharding(P()))
        moved = self._transfer_request(staged).start(staged).get()
        # land on the decode mesh under the serving cache rules: donation
        # aliases the decode step's cache output onto its input, so this
        # placement is the loop's sharding fixed point
        srv = self.decode
        specs = rules.cache_specs(moved, srv.mesh, srv.pcfg, srv.cfg)
        out = jax.device_put(moved, rules.shardings(specs, srv.mesh))
        jax.block_until_ready(out)
        leaves = jax.tree_util.tree_leaves(cache)
        kv_bytes = int(sum(l.size * jnp.dtype(l.dtype).itemsize for l in leaves))
        return out, {
            "transfer_s": time.perf_counter() - t0,
            "kv_bytes": kv_bytes,
            "kv_pages": self.kv_pages,
        }

    # -- serving ------------------------------------------------------------

    def generate(self, requests: list[Request]) -> tuple[np.ndarray, dict]:
        """Disaggregated prefill + decode; token-for-token equal to
        :meth:`Server.generate` at ``temperature=0``."""

        t0 = time.perf_counter()
        batch, _lens = self.prefill._pad_batch(requests)
        key = self.prefill._next_key()
        with self.prefill.mesh:
            logits, cache = self.prefill._prefill_request(batch)(
                self.prefill.params, batch
            )
            tok = self.prefill._sample(logits, key)
            jax.block_until_ready(tok)
        t_prefill = time.perf_counter() - t0

        cache, transfer_stats = self._transfer(cache)
        # the token lands batch-sharded like every later sampled token (the
        # decode request binds its argument shardings at init)
        b = int(tok.shape[0])
        data = int(self.decode.comm.axis_size("data"))
        tok_spec = P("data") if b % data == 0 else P()
        tok = jax.device_put(tok, self.decode.comm.sharding(tok_spec))

        t1 = time.perf_counter()
        with self.decode.mesh:
            outs = self.decode._decode_loop(cache, tok, key)
        t_decode = time.perf_counter() - t1
        tokens = np.stack([np.asarray(t) for t in outs], axis=1)
        gen_lens = generation_lengths(tokens, self.scfg.stop_token)
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "gen_lens": gen_lens.tolist(),
            "generated_tokens": int(gen_lens.sum()),
            "tokens_per_s": int(gen_lens.sum()) / max(t_decode, 1e-9),
            "batch": len(requests),
            "prefill_devices": self.prefill.comm.size(),
            "decode_devices": self.decode.comm.size(),
            **transfer_stats,
        }
        return tokens, stats
