"""The Server: batched prefill + decode serving loop.

Continuous-batching-lite: requests are grouped into fixed-size batches
(padded to ``max_batch``), prefilled once, then decoded step-by-step over
the persistent KV/SSM cache.  The cache is sharded per
``repro.sharding.rules`` (batch over data axes, heads or sequence over model
axis; int8 cache when configured).

**Persistent decode engine**: the single-token decode step — the serving hot
loop — is built once per argument signature as a
:class:`~repro.core.futures.PersistentRequest` (AOT lower + compile, cache
donated) and re-fired ``MPI_Start``-style for every token; the prefill step
is persistent per prompt-shape bucket the same way.  Steady-state decode can
never re-trace (``trace:decode_step`` pvar stays at one per signature).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import tool
from repro.core.communicator import Communicator
from repro.core.futures import PersistentRequest, argument_signature
from repro.models import api as model_api
from repro.sharding import rules


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 8
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


@dataclasses.dataclass
class Request:
    tokens: np.ndarray             # (prompt_len,) int32
    extra: dict = dataclasses.field(default_factory=dict)


class Server:
    def __init__(
        self,
        cfg: ModelConfig,
        pcfg: ParallelConfig,
        scfg: ServerConfig,
        comm: Communicator | Mesh,
    ):
        self.cfg, self.pcfg, self.scfg = cfg, pcfg, scfg
        # serving owns its process set: a session-derived communicator (a
        # bare Mesh is wrapped unmanaged for older call sites)
        self.comm = comm if isinstance(comm, Communicator) else Communicator(comm)
        self.mesh = mesh = self.comm.mesh
        self.bundle = model_api.build(cfg)
        with mesh:
            self.params = jax.jit(self.bundle.init)(jax.random.PRNGKey(scfg.seed))
            pspecs = rules.param_specs(self.params, mesh, pcfg)
            self.params = jax.device_put(self.params, rules.shardings(pspecs, mesh))
        # persistent steps, keyed by argument signature (shape bucket): one
        # AOT compile per bucket, MPI_Start re-fires ever after
        self._prefill_reqs: dict[tuple, PersistentRequest] = {}
        self._decode_reqs: dict[tuple, PersistentRequest] = {}

    # -- persistent step construction -------------------------------------------

    def _prefill_request(self, batch) -> PersistentRequest:
        key = argument_signature(batch)
        req = self._prefill_reqs.get(key)
        if req is None:
            def prefill_step(p, b):
                tool.pvar_count("trace:prefill_step")
                return self.bundle.prefill(
                    p, b, self.pcfg, None,
                    extra_capacity=self.scfg.max_new_tokens,
                )

            req = PersistentRequest(jax.jit(prefill_step), (self.params, batch))
            self._prefill_reqs[key] = req
        return req

    def _decode_request(self, cache, tok) -> PersistentRequest:
        key = argument_signature((cache, tok))
        req = self._decode_reqs.get(key)
        if req is None:
            def decode_step(p, c, t):
                tool.pvar_count("trace:decode_step")
                return self.bundle.decode(p, c, t, self.pcfg, None)

            req = PersistentRequest(
                jax.jit(decode_step, donate_argnums=(1,)),
                (self.params, cache, tok),
                donate_argnums=(1,),
            )
            self._decode_reqs[key] = req
        return req

    # -- batching ---------------------------------------------------------------

    def _pad_batch(self, requests: list[Request]) -> tuple[dict, np.ndarray]:
        b = len(requests)
        pl = max(len(r.tokens) for r in requests)
        toks = np.zeros((b, pl), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, r in enumerate(requests):
            toks[i, pl - len(r.tokens):] = r.tokens  # left-pad: last token aligned
            lens[i] = len(r.tokens)
        batch = {"tokens": jnp.asarray(toks)}
        if requests[0].extra:
            for k, v in requests[0].extra.items():
                batch[k] = jnp.stack([jnp.asarray(r.extra[k]) for r in requests])
        return batch, lens

    # -- serving ------------------------------------------------------------------

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        logits = logits[:, -1, : self.cfg.vocab_size]
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)

    def generate(self, requests: list[Request]) -> tuple[np.ndarray, dict]:
        """Prefill + greedy/temperature decode.  Returns (tokens
        (B, max_new), stats)."""

        t0 = time.perf_counter()
        batch, _lens = self._pad_batch(requests)
        key = jax.random.PRNGKey(self.scfg.seed)
        with self.mesh:
            logits, cache = self._prefill_request(batch)(self.params, batch)
            t_prefill = time.perf_counter() - t0

            outs = []
            tok = self._sample(logits, key)
            outs.append(tok)
            t1 = time.perf_counter()
            decode = self._decode_request(cache, tok[:, None])
            for i in range(self.scfg.max_new_tokens - 1):
                key, sub = jax.random.split(key)
                logits, cache = decode(self.params, cache, tok[:, None])
                tok = self._sample(logits, sub)
                outs.append(tok)
            jax.block_until_ready(tok)
            t_decode = time.perf_counter() - t1
        tokens = np.stack([np.asarray(t) for t in outs], axis=1)
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "tokens_per_s": tokens.size / max(t_decode, 1e-9),
            "batch": len(requests),
        }
        return tokens, stats
