"""Fault tolerance policies: failure injection, straggler mitigation, and
the restart protocol — testable on one host, designed for 1000+ nodes.

At production scale the runtime wraps every step in :class:`StepGuard`:

* **failure detection** — on a real cluster a device failure surfaces as an
  XLA error or a missed heartbeat; here :class:`FaultInjector` raises the
  same exception types on schedule so the recovery path is exercised in CI;
* **recovery** — the ``Trainer`` catches :class:`WorkerFailure`, re-forms the
  mesh over the survivors (elastic) or the replacement set, restores the
  newest complete checkpoint, and replays the data stream (stateless loader:
  nothing to replay but the step counter);
* **straggler mitigation** — each step is timed; steps slower than
  ``deadline_factor ×`` a robust running estimate (median of recent steps)
  mark the step "straggled".  On TPU pods the standard mitigation is
  re-dispatch of the same program (the input is deterministic), which is
  what :meth:`StragglerPolicy.should_retry` gates.  A persistent straggler
  triggers the failure path (treat-as-failed), matching production practice.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable

from repro.core import tool

tool.pvar_register("elastic:evictions", "ranks evicted by the fault injector")
tool.pvar_register("elastic:joins", "ranks hot-joined into a grown epoch")


class WorkerFailure(RuntimeError):
    """A (possibly injected) unrecoverable worker/device failure."""


class RankEvicted(WorkerFailure):
    """A *specific* rank died (ULFM ``MPI_ERR_PROC_FAILED`` analogue): the
    elastic recovery path shrinks the epoch to the survivors instead of
    restarting the whole job."""

    def __init__(self, step: int, rank: int):
        super().__init__(f"injected eviction of rank {rank} at step {step}")
        self.step = step
        self.rank = rank


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure schedule.

    * ``fail_at_steps`` — raise ``kind`` at those step numbers (device /
      worker failures; each fires once).
    * ``fail_fragments`` — raise ``OSError`` when a checkpoint fragment
      whose name contains one of these substrings is about to be written
      (each pattern fires once).  This is the torn-save injection: the
      background save must surface the error as ``ERR_IO`` from
      ``CheckpointManager.wait()`` and ``latest`` must not advance — a
      silently "successful" failed save is the defect this exists to catch.
    * ``evict_rank(step, rank)`` — raise :class:`RankEvicted` for that rank
      at that step (fires once): the ULFM shrink path.  Deterministic by
      construction — schedules key on the step counter, and the trainer's
      ``StepGuard.clock`` is frozen in elastic tests, so the same schedule
      replays bit-identically.
    * ``admit_rank(step, count)`` — offer ``count`` new ranks at that step
      (consumed once via :meth:`take_admissions`): the grow path.  Not an
      exception — joining is voluntary, the trainer polls.
    """

    fail_at_steps: tuple[int, ...] = ()
    kind: type[Exception] = WorkerFailure
    fail_fragments: tuple[str, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)
    _evictions: dict = dataclasses.field(default_factory=dict)
    _admissions: dict = dataclasses.field(default_factory=dict)

    def evict_rank(self, step: int, rank: int) -> "FaultInjector":
        """Schedule rank ``rank`` to die at step ``step``."""

        self._evictions[int(step)] = int(rank)
        return self

    def admit_rank(self, step: int, count: int = 1) -> "FaultInjector":
        """Schedule ``count`` new ranks to offer themselves at ``step``."""

        self._admissions[int(step)] = self._admissions.get(int(step), 0) + int(count)
        return self

    def take_admissions(self, step: int) -> int:
        """Consume (once) the number of ranks joining at this step."""

        key = ("admit", step)
        if step in self._admissions and key not in self._fired:
            self._fired.add(key)
            return self._admissions[step]
        return 0

    def check(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise self.kind(f"injected worker failure at step {step}")
        key = ("evict", step)
        if step in self._evictions and key not in self._fired:
            self._fired.add(key)
            tool.pvar_count("elastic:evictions")
            raise RankEvicted(step, self._evictions[step])

    def check_io(self, fragment: str) -> None:
        """Fragment-write hook (wired as ``File.write_hook``)."""

        for pattern in self.fail_fragments:
            key = ("io", pattern)
            if pattern in fragment and key not in self._fired:
                self._fired.add(key)
                raise OSError(
                    f"injected fragment-write fault on {fragment!r} "
                    f"(pattern {pattern!r})"
                )


@dataclasses.dataclass
class StragglerPolicy:
    """Step-deadline straggler detection + bounded re-dispatch."""

    deadline_factor: float = 3.0
    window: int = 32
    max_retries: int = 1
    min_samples: int = 5
    _history: deque = dataclasses.field(default_factory=deque)

    def __post_init__(self):
        # the history bound IS the configured window (it was silently
        # hardcoded to 32 before, making the field dead config)
        self._history = deque(self._history, maxlen=self.window)

    def observe(self, duration_s: float) -> None:
        self._history.append(duration_s)

    def median(self) -> float | None:
        if len(self._history) < self.min_samples:
            return None
        s = sorted(self._history)
        return s[len(s) // 2]

    def is_straggler(self, duration_s: float) -> bool:
        med = self.median()
        return med is not None and duration_s > self.deadline_factor * med

    def should_retry(self, attempts: int) -> bool:
        return attempts <= self.max_retries


@dataclasses.dataclass
class StepGuard:
    """Times one step, applies straggler policy, surfaces failures.

    ``clock`` is the injectable time source (``time.perf_counter`` in
    production).  Tests inject a fake clock advanced by the step function
    itself, so straggler behaviour is asserted deterministically — no
    wall-clock sleeps, no timing margins for a loaded CI machine to blow
    through.  :class:`StragglerPolicy` itself is already clock-free (it
    only ever sees durations).
    """

    straggler: StragglerPolicy
    injector: FaultInjector | None = None
    clock: Callable[[], float] = time.perf_counter

    def run(
        self,
        step: int,
        fn: Callable[[], object],
        *,
        retry_safe: bool = True,
        exempt: bool = False,
    ) -> tuple[object, dict]:
        """Run one step under the policy.

        ``retry_safe=False`` declares that ``fn`` cannot be re-dispatched
        with the same inputs — the persistent-step path donates its
        params/opt-state buffers, which a second dispatch would read after
        free.  A straggler then goes straight to the failure path
        (treat-as-failed → restore from checkpoint), the production practice
        for donated step buffers.

        ``exempt=True`` declares known interference — a background
        checkpoint save is stealing cycles from this step — so a slow step
        is *not* marked a straggler (it is not evidence of a sick worker)
        and its polluted duration is kept out of the running median.
        """

        attempts = 0
        while True:
            attempts += 1
            t0 = self.clock()
            if self.injector is not None:
                self.injector.check(step)
            out = fn()
            dt = self.clock() - t0
            if exempt:
                return out, {"duration_s": dt, "attempts": attempts, "straggled": False}
            straggled = self.straggler.is_straggler(dt)
            if straggled and retry_safe and self.straggler.should_retry(attempts):
                continue  # re-dispatch the same deterministic step
            if straggled:
                raise WorkerFailure(
                    f"step {step} straggled {attempts}x (last {dt:.3f}s, "
                    f"median {self.straggler.median():.3f}s)"
                )
            self.straggler.observe(dt)
            return out, {"duration_s": dt, "attempts": attempts, "straggled": straggled}
