"""Continuous-batching serving engine on the paged-KV slot table.

The :class:`~repro.runtime.server.Server` decodes fixed batches: every
request in a batch prefills together, decodes together, and the batch holds
its slots until the *slowest* member finishes.  This engine removes that
head-of-line blocking while reusing the Server's substrate unchanged:

* **slot table** — one cache of ``max_batch`` rows, each
  ``prompt_bucket + max_new_tokens`` tokens deep, with a *per-row* position
  vector (the model's decode path accepts ``pos`` as ``(B,)`` — see
  :func:`repro.models.attention.cache_layer_update`).  Rows decode at ragged
  depths inside one persistent decode request;
* **paged block pool** — the slot table is carved into fixed KV blocks
  (:class:`~repro.runtime.kvpool.KVBlockPool`); requests allocate blocks as
  they deepen and a budget cap forces *preemption* (``ERR_NO_MEM`` answered
  by evicting the latest-admitted row) under memory pressure;
* **in-flight admission** — new requests prefill in a side batch (the
  Server's persistent prefill request, bucketed by padded length) and are
  spliced into free slots of the *running* cache by a compiled insert-row
  request, joining the next decode iteration;
* **retirement** — a row leaves its slot the moment it emits the stop token
  or exhausts its own ``max_new`` budget; the freed blocks are reused
  verbatim by the next admission.

**Parity contract**: at ``temperature=0`` every request's generated tokens
are identical, token for token, to what :meth:`Server.generate` produces for
the same prompt left-padded to ``prompt_bucket`` — including requests
admitted mid-flight and requests preempted and resumed (resume re-prefills
``prompt + generated[:-1]`` at the same cache positions, so the recomputed
KV is bit-identical to the evicted KV).  The fixed-batch Server is therefore
the engine's oracle, and the tests pin it.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import errors, tool
from repro.core.futures import PersistentRequest, argument_signature
from repro.runtime.kvpool import KVBlockPool
from repro.runtime.server import Request, Server

tool.pvar_register("engine:admit", "requests admitted into a running decode batch")
tool.pvar_register("engine:retire", "requests retired from the continuous batch")
tool.pvar_register("engine:preempt", "requests preempted under block-pool pressure")
tool.pvar_register("trace:insert_row", "decode-row insert kernels traced (want 1 per shape)")


@dataclasses.dataclass
class EngineConfig:
    """Engine knobs on top of the Server's :class:`ServerConfig` (which
    contributes ``max_batch`` slots, the ``max_new_tokens`` ceiling,
    ``temperature``, ``seed`` and ``stop_token``)."""

    prompt_bucket: int = 8        # every prompt is left-padded to this length
    block_tokens: int = 4         # KV block (page) granularity in tokens
    pool_blocks: int | None = None  # live-block budget; None = uncapped pool


#: request lifecycle states (the admission/preemption state machine)
WAITING, RUNNING, PREEMPTED, FINISHED = "waiting", "running", "preempted", "finished"


@dataclasses.dataclass
class ServingRequest:
    """One request's ticket through the engine."""

    tokens: np.ndarray                 # (prompt_len,) int32, prompt_len <= bucket
    max_new: int                       # this request's own generation budget
    rid: int = -1
    state: str = WAITING
    slot: int | None = None
    generated: list = dataclasses.field(default_factory=list)
    cached_tokens: int = 0             # tokens currently materialised in KV
    admit_seq: int = -1                # admission order (preemption victims
                                       # are picked newest-first)
    preemptions: int = 0
    block_ids: list = dataclasses.field(default_factory=list)
    arrival_s: float = 0.0
    first_token_s: float | None = None
    finish_s: float | None = None


class Engine:
    """Continuous-batching scheduler over a Server's persistent requests."""

    def __init__(self, server: Server, ecfg: EngineConfig):
        cfg, scfg = server.cfg, server.scfg
        errors.check(
            cfg.family in ("dense", "moe"),
            errors.ErrorClass.ERR_UNSUPPORTED_OPERATION,
            f"the continuous-batching engine serves dense/moe LMs; "
            f"family {cfg.family!r} keeps the fixed-batch Server",
        )
        errors.check(
            cfg.sliding_window is None and cfg.layer_pattern == "uniform",
            errors.ErrorClass.ERR_UNSUPPORTED_OPERATION,
            "sliding-window / local_global caches are ring buffers; the "
            "paged slot table requires linear (uniform) cache layout",
        )
        errors.check(
            ecfg.prompt_bucket >= 1 and scfg.max_new_tokens >= 1,
            errors.ErrorClass.ERR_ARG,
            f"need prompt_bucket >= 1 and max_new_tokens >= 1, got "
            f"{ecfg.prompt_bucket}/{scfg.max_new_tokens}",
        )
        self.server = server
        self.ecfg = ecfg
        self.scfg = scfg
        self.num_slots = scfg.max_batch
        self.capacity = ecfg.prompt_bucket + scfg.max_new_tokens
        self.pool = KVBlockPool(
            num_slots=self.num_slots,
            slot_capacity=self.capacity,
            block_tokens=ecfg.block_tokens,
            budget_blocks=ecfg.pool_blocks,
        )
        self.waiting: collections.deque[ServingRequest] = collections.deque()
        self.active: list[ServingRequest | None] = [None] * self.num_slots
        self.finished: list[ServingRequest] = []
        # insert-row compiles are keyed by signature and shared across engine
        # instances over the same server (same params/mesh), like the
        # server's own prefill/decode request caches
        self._insert_reqs: dict[tuple, PersistentRequest] = server.__dict__.setdefault(
            "_engine_insert_reqs", {}
        )
        self._decode_req: PersistentRequest | None = None
        self._rid = 0
        self._admit_seq = 0
        self._key0 = jax.random.PRNGKey(scfg.seed)   # argmax path ignores it
        self._steps = 0
        self._preempt_count = 0
        self._generated_total = 0

        # the slot-table cache: a throwaway prefill at the bucket shape gives
        # the exact tree/dtypes/shardings the decode loop will carry, then the
        # scalar position becomes the per-row (all-empty) position vector
        toks = jnp.zeros((self.num_slots, ecfg.prompt_bucket), jnp.int32)
        batch = {"tokens": toks}
        with server.mesh:
            _, cache = server._prefill_request(batch)(server.params, batch)
            self.cache = {
                k: dataclasses.replace(
                    v, pos=jnp.zeros((self.num_slots,), jnp.int32)
                )
                for k, v in cache.items()
            }
            self.tok = jnp.zeros((self.num_slots, 1), jnp.int32)

    # -- submission -----------------------------------------------------------

    def submit(self, request, max_new: int | None = None) -> ServingRequest:
        """Queue a request (a server :class:`Request` or a raw token array).
        ``max_new`` caps this request's generation below the engine ceiling."""

        if isinstance(request, Request):
            errors.check(
                not request.extra,
                errors.ErrorClass.ERR_UNSUPPORTED_OPERATION,
                "the engine buckets prompts by length; per-request extras "
                "are a fixed-batch Server feature",
            )
            tokens = np.asarray(request.tokens, np.int32)
        else:
            tokens = np.asarray(request, np.int32)
        errors.check(
            1 <= len(tokens) <= self.ecfg.prompt_bucket,
            errors.ErrorClass.ERR_TRUNCATE,
            f"prompt of {len(tokens)} tokens does not fit the "
            f"{self.ecfg.prompt_bucket}-token bucket",
        )
        budget = self.scfg.max_new_tokens if max_new is None else int(max_new)
        errors.check(
            1 <= budget <= self.scfg.max_new_tokens,
            errors.ErrorClass.ERR_ARG,
            f"max_new={budget} outside [1, {self.scfg.max_new_tokens}]",
        )
        r = ServingRequest(
            tokens=tokens, max_new=budget, rid=self._rid,
            arrival_s=time.perf_counter(),
        )
        self._rid += 1
        self.waiting.append(r)
        return r

    # -- admission ------------------------------------------------------------

    def _padded_content(self, r: ServingRequest) -> np.ndarray:
        """What a (re-)prefill must materialise: the prompt left-padded to
        the bucket, plus all generated tokens *except* the pending one (the
        last sampled token's KV is written by its own decode step)."""

        bucket = self.ecfg.prompt_bucket
        out = np.zeros((bucket + max(0, len(r.generated) - 1),), np.int32)
        out[bucket - len(r.tokens):bucket] = r.tokens
        if len(r.generated) > 1:
            out[bucket:] = np.asarray(r.generated[:-1], np.int32)
        return out

    def _insert_request(self, pcache) -> PersistentRequest:
        key = (
            argument_signature((self.cache, self.tok)),
            argument_signature(pcache),
        )
        req = self._insert_reqs.get(key)
        if req is None:
            def insert_step(c, t_table, pc, dst, src, t):
                tool.pvar_count("trace:insert_row")

                def leaf(cd, cs):
                    if cd.ndim == 1:   # the position vector vs scalar pos
                        return cd.at[dst].set(cs.astype(cd.dtype))
                    row = jax.lax.dynamic_slice_in_dim(cs, src, 1, axis=1)
                    return jax.lax.dynamic_update_slice_in_dim(
                        cd, row.astype(cd.dtype), dst, axis=1
                    )

                new_c = jax.tree_util.tree_map(leaf, c, pc)
                return new_c, t_table.at[dst, 0].set(t)

            zero = jnp.zeros((), jnp.int32)
            req = PersistentRequest(
                jax.jit(insert_step, donate_argnums=(0, 1)),
                (self.cache, self.tok, pcache, zero, zero, zero),
                donate_argnums=(0, 1),
            )
            self._insert_reqs[key] = req
        return req

    def _admit(self, now: float) -> None:
        free = [s for s in range(self.num_slots) if self.active[s] is None]
        admitted: list[tuple[ServingRequest, int, int]] = []
        while free and self.waiting:
            r = self.waiting[0]
            plen = self.ecfg.prompt_bucket + max(0, len(r.generated) - 1)
            slot = free[0]
            if not self.pool.fits(slot, plen):
                break   # head-of-line under memory pressure: no skip-ahead
            self.waiting.popleft()
            free.pop(0)
            self.pool.ensure(slot, plen)
            admitted.append((r, slot, plen))
        if not admitted:
            return

        # prefill one side batch per padded length (resumed requests carry
        # their regenerated prefix, so their bucket is deeper); rows are
        # padded to the next power of two — a handful of compile buckets,
        # without paying a full max_batch prefill for a single admission
        by_len: dict[int, list[tuple[ServingRequest, int]]] = {}
        for r, slot, plen in admitted:
            by_len.setdefault(plen, []).append((r, slot))
        for plen, group in sorted(by_len.items()):
            nrows = min(self.num_slots, 1 << (len(group) - 1).bit_length())
            toks = np.zeros((nrows, plen), np.int32)
            for row, (r, _slot) in enumerate(group):
                toks[row] = self._padded_content(r)
            batch = {"tokens": jnp.asarray(toks)}
            extra = self.capacity - plen
            with self.server.mesh:
                logits, pcache = self.server._prefill_request(
                    batch, extra_capacity=extra
                )(self.server.params, batch)
                first = self.server._sample(logits, self.server._next_key())
                insert = self._insert_request(pcache)
                first_host = np.asarray(first)
                for row, (r, slot) in enumerate(group):
                    if r.generated:
                        t = int(r.generated[-1])   # resumed: pending token
                    else:
                        t = int(first_host[row])   # fresh: sample prefill logits
                        r.generated.append(t)
                        r.first_token_s = now
                        self._generated_total += 1
                        stopped = (
                            self.scfg.stop_token is not None
                            and t == self.scfg.stop_token
                        )
                        if stopped or r.max_new <= 1:
                            # done before ever occupying a decode slot
                            self.pool.release(slot)
                            r.state, r.finish_s = FINISHED, time.perf_counter()
                            self.finished.append(r)
                            tool.pvar_count("engine:retire")
                            continue
                    self.cache, self.tok = insert(
                        self.cache, self.tok, pcache,
                        jnp.asarray(slot, jnp.int32),
                        jnp.asarray(row, jnp.int32),
                        jnp.asarray(t, jnp.int32),
                    )
                    r.state, r.slot = RUNNING, slot
                    r.cached_tokens = plen
                    r.admit_seq = self._admit_seq
                    self._admit_seq += 1
                    r.block_ids = self.pool.block_ids(slot)
                    self.active[slot] = r
                    tool.pvar_count("engine:admit")

    # -- preemption -----------------------------------------------------------

    def _preempt(self, slot: int) -> None:
        r = self.active[slot]
        self.pool.release(slot)
        r.state, r.slot = PREEMPTED, None
        r.preemptions += 1
        self.active[slot] = None
        # front of the queue: a preempted request outranks fresh arrivals,
        # so eviction cannot starve it
        self.waiting.appendleft(r)
        self._preempt_count += 1
        tool.pvar_count("engine:preempt")

    def _grow_or_preempt(self) -> None:
        """Before firing the decode step, every running row must own a block
        for the token it is about to write; ``ERR_NO_MEM`` on growth evicts
        the latest-admitted row (possibly the grower itself)."""

        bt = self.ecfg.block_tokens
        if not any(
            r is not None and r.cached_tokens % bt == 0 for r in self.active
        ):
            return   # nobody crosses a block boundary this step
        order = sorted(
            (s for s in range(self.num_slots) if self.active[s] is not None),
            key=lambda s: self.active[s].admit_seq,
        )
        for s in order:
            r = self.active[s]
            if r is None:
                continue   # evicted earlier in this pass
            if r.cached_tokens % bt != 0:
                continue   # current block still has room for the next token
            while True:
                try:
                    self.pool.ensure(s, r.cached_tokens + 1)
                    r.block_ids = self.pool.block_ids(s)
                    break
                except errors.NoMemError:
                    victim = max(
                        (v for v in range(self.num_slots) if self.active[v] is not None),
                        key=lambda v: self.active[v].admit_seq,
                    )
                    self._preempt(victim)
                    if victim == s:
                        break   # the grower lost its own slot

    # -- the scheduler loop ---------------------------------------------------

    def step(self) -> list[ServingRequest]:
        """One scheduler iteration: admit, grow (preempting under pressure),
        fire the persistent decode step, append/retire.  Returns the
        requests that finished this step."""

        now = time.perf_counter()
        self._admit(now)
        self._grow_or_preempt()
        if not any(r is not None for r in self.active):
            return []

        with self.server.mesh:
            # the slot table's signature never changes, so the persistent
            # request is resolved once and re-fired ever after (the per-step
            # signature hash would otherwise be the scheduler's biggest tax)
            if self._decode_req is None:
                self._decode_req = self.server._decode_request(self.cache, self.tok)
            logits, self.cache = self._decode_req(
                self.server.params, self.cache, self.tok
            )
            key = (
                jax.random.fold_in(self._key0, self._steps)
                if self.scfg.temperature > 0 else self._key0
            )
            tok = self.server._sample(logits, key)
            self.tok = tok[:, None]
        tok_host = np.asarray(tok)
        self._steps += 1

        done: list[ServingRequest] = []
        now = time.perf_counter()
        for s in range(self.num_slots):
            r = self.active[s]
            if r is None:
                continue
            t = int(tok_host[s])
            r.generated.append(t)
            r.cached_tokens += 1
            self._generated_total += 1
            stopped = self.scfg.stop_token is not None and t == self.scfg.stop_token
            if stopped or len(r.generated) >= r.max_new:
                self.pool.release(s)
                r.state, r.slot = FINISHED, None
                r.finish_s = now
                self.active[s] = None
                self.finished.append(r)
                done.append(r)
                tool.pvar_count("engine:retire")
        return done

    def run(self) -> list[ServingRequest]:
        """Drain the queue: step until nothing is waiting or running."""

        while self.waiting or any(r is not None for r in self.active):
            self.step()
        return self.finished

    # -- bookkeeping ----------------------------------------------------------

    def stats(self) -> dict:
        # generated_tokens counts every sampled token exactly once: the
        # prefill-sampled first token at admission, one per row per decode step
        return {
            "steps": self._steps,
            "preemptions": self._preempt_count,
            "generated_tokens": self._generated_total,
            "finished": len(self.finished),
            "waiting": len(self.waiting),
            "running": sum(1 for r in self.active if r is not None),
            "pool_live_blocks": self.pool.live_blocks,
            "pool_budget_blocks": self.pool.budget_blocks,
        }


def make_engine(server: Server, ecfg: EngineConfig | None = None) -> Engine:
    """Factory: a continuous-batching engine over an existing Server."""

    return Engine(server, ecfg if ecfg is not None else EngineConfig())
