"""The Trainer: jit-compiled train step under the production sharding, with
checkpoint/restart, failure recovery, elastic rescale and straggler handling.

The train step itself is assembled from the substrate layers:

* model loss from ``repro.models.api`` (any assigned architecture);
* sharding from ``repro.sharding.rules`` (FSDP/TP/EP plans);
* AdamW from ``repro.optim`` (moments inherit the param shardings);
* data from ``repro.data`` (deterministic, stateless resume);
* checkpoints from ``repro.checkpoint`` (async, atomic, elastic).

Distribution is GSPMD-first: the step is a plain ``jax.jit`` with
``in_shardings``/``out_shardings`` derived from the rules, so the same step
function lowers for 8 CPU devices here and 512 TPU chips on the production
mesh (the dry-run proves the latter).  The explicit-collective path
(``shard_map`` + ``repro.core``) backs the overlap/compression features.

**Persistent execution engine** (default): the step is built *once* as a
:class:`~repro.core.futures.PersistentRequest` — AOT-lowered and compiled
with params/opt-state donated — and every step is an ``MPI_Start``-style
re-fire of the compiled executable.  The hot loop can never re-trace (the
``trace:train_step`` pvar counts traces; it stays at 1), argument
shape/sharding drift raises ``ERR_REQUEST`` instead of silently recompiling,
and donation makes steady-state steps allocation-free.  Because donated
buffers cannot be re-dispatched, the straggler policy runs with
``retry_safe=False``: a straggler goes straight to the failure path
(checkpoint restore), the production behaviour for donated step buffers.
``TrainerConfig(persistent=False)`` restores the plain-``jit`` path.

**Layout** comes from one :class:`~repro.configs.base.ParallelPlan`
(``TrainerConfig.plan``, or the deprecated ``pipeline_stages``/
``ring_attention`` int knobs shimmed through ``resolved_plan()``).

**Pipeline-parallel mode** (``plan.stage > 1``): the
trainer re-forms its process set as a ``(data, stage)`` Cartesian topology
(``cart_create`` — MPI 4.0 ch. 8) and the step streams microbatches through
the stages with :func:`repro.core.overlap.pipeline_spmd`; every stage
boundary is one ``cart_shift(+1)`` axis-local ``collective-permute``.  The
pipeline step rides the same persistent engine — still exactly one trace.

**Async checkpointing on the same engine** (default): ``ckpt.save`` gathers
device state synchronously (donation-safe) and runs the file writes as I/O
requests overlapping the next persistent step; the single manifest commit
is the durability point.  A failed save surfaces as ``ERR_IO`` at the next
join — the trainer counts it (``ckpt_failures`` in the result, the
``ckpt_save_failed`` pvar), logs it and keeps training from device state
(``latest`` stays at the previous complete step); it is never reported as
success.  The straggler/failure recovery path restores elastically through
the checkpoint's ``set_view`` storage representation.
"""

from __future__ import annotations

import dataclasses
import logging
import time
import warnings
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig, ParallelConfig, ParallelPlan
from repro.core import errors, tool
from repro.core.communicator import Communicator
from repro.core.epoch import ELASTIC, CommEpoch, TopologySpec
from repro.core.futures import PersistentRequest
from repro.data import TokenPipeline
from repro.models import api as model_api
from repro.optim import AdamW, clip_by_global_norm, cosine_warmup
from repro.runtime.faults import (
    FaultInjector,
    RankEvicted,
    StepGuard,
    StragglerPolicy,
    WorkerFailure,
)
from repro.sharding import rules

log = logging.getLogger("repro.trainer")

tool.pvar_register("trace:train_step", "train-step executables traced (want exactly 1 per epoch)")
tool.pvar_register(
    "elastic:recovery_steps",
    "steps replayed per eviction (restore point back to eviction point)",
)
tool.pvar_register(
    "config:deprecated_knob",
    "TrainerConfig layouts built through the deprecated "
    "pipeline_stages/ring_attention int knobs instead of a ParallelPlan",
)

_deprecated_knob_warned = False


def _warn_deprecated_knobs() -> None:
    """One DeprecationWarning per process for the legacy int knobs; the pvar
    still counts every shimmed construction so the lint sees the usage."""

    global _deprecated_knob_warned
    tool.pvar_count("config:deprecated_knob")
    if _deprecated_knob_warned:
        return
    _deprecated_knob_warned = True
    warnings.warn(
        "TrainerConfig.pipeline_stages/pipeline_microbatches/ring_attention "
        "are deprecated; pass plan=ParallelPlan(stage=..., ring=..., "
        "microbatches=...) instead",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    lr: float = 3e-4
    warmup_steps: int = 10
    clip_norm: float = 1.0
    weight_decay: float = 0.1
    seed: int = 0
    checkpoint_dir: str | None = None
    checkpoint_every: int = 50
    keep_checkpoints: int = 3
    log_every: int = 10
    max_restarts: int = 3
    # persistent execution engine: AOT-compile the step once, MPI_Start it
    # every iteration (zero re-traces); donate aliases params/opt-state.
    persistent: bool = True
    donate: bool = True
    # checkpoint writes ride the I/O request engine and overlap the next
    # step; False joins each save before the next step starts
    async_checkpoint: bool = True
    # the unified layout: one frozen ParallelPlan covers the cart fold
    # (data x stage x ring x tensor), microbatching, grad-sync buckets and
    # remat — what `python -m repro.tune` emits and `--plan` parses.
    # None = a pure data plan (adopt the communicator's own shape), unless
    # the deprecated knobs below ask for a fold.
    plan: ParallelPlan | None = None
    # DEPRECATED pipeline/ring int knobs — shims that construct the
    # equivalent ParallelPlan via resolved_plan() and warn once.  Kept so
    # pre-plan examples and configs run unchanged; pvar
    # `config:deprecated_knob` counts every shimmed construction.
    pipeline_stages: int = 0
    pipeline_microbatches: int = 2
    ring_attention: int = 0

    def resolved_plan(self) -> ParallelPlan:
        """The one layout truth: ``plan`` when set, else the deprecated int
        knobs shimmed through :meth:`ParallelPlan.from_legacy` (warning
        once), else the pure data plan."""

        legacy = self.pipeline_stages > 1 or self.ring_attention > 1
        if self.plan is not None:
            errors.check(
                not legacy,
                errors.ErrorClass.ERR_ARG,
                "TrainerConfig.plan and the deprecated pipeline_stages/"
                "ring_attention knobs are both set; the plan is the only "
                "layout input — drop the legacy knobs",
            )
            return self.plan
        if legacy:
            _warn_deprecated_knobs()
            return ParallelPlan.from_legacy(
                pipeline_stages=self.pipeline_stages,
                pipeline_microbatches=self.pipeline_microbatches,
                ring_attention=self.ring_attention,
            )
        return ParallelPlan()


def make_train_step(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    tcfg: TrainerConfig,
    opt: AdamW,
    mesh: Mesh | None = None,
):
    """Build the pure train-step function (params, opt_state, batch) ->
    (params, opt_state, metrics).  ``mesh`` is forwarded to the model loss
    for the explicitly sharded attention paths (ring attention)."""

    bundle = model_api.build(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = bundle.loss(p, batch, pcfg, mesh)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def _pipeline_param_specs(params, stages: int):
    """Pipeline placement: the stacked ``layers`` leading (unit) dim is
    sharded over the cart ``stage`` axis — each stage holds its slice of
    the layer stack; embedding/head/norms replicate."""

    for leaf in jax.tree.leaves(params["layers"]):
        errors.check(
            np.shape(leaf)[0] % stages == 0,
            errors.ErrorClass.ERR_DIMS,
            f"{np.shape(leaf)[0]} scanned units do not split over "
            f"{stages} pipeline stages",
        )
    specs = jax.tree.map(lambda _: P(), params)
    return {**specs, "layers": jax.tree.map(lambda _: P("stage"), params["layers"])}


def make_pipeline_train_step(
    cfg: ModelConfig,
    pcfg: ParallelConfig,
    tcfg: TrainerConfig,
    opt: AdamW,
    cart,
    plan: ParallelPlan | None = None,
):
    """Pipeline-parallel train step over a ``(data, stage)`` Cartesian
    topology (MPI 4.0 ch. 8 as the pipeline fabric).

    The loss runs under ``shard_map``: ``params['layers']`` is sharded over
    the ``stage`` axis, the batch over ``data``, and
    :func:`repro.core.overlap.pipeline_spmd` streams
    ``pipeline_microbatches`` through the stages — every stage boundary is
    one ``cart_shift(+1)`` axis-local ``collective-permute``, never a dense
    world collective.  AD differentiates through the schedule (the permute
    transposes to the reverse shift), so data-parallel gradient reduction
    over ``data`` and stage-local layer gradients emerge from the shard_map
    transpose without further plumbing.  The whole step still compiles once
    into the persistent engine: ``trace:train_step`` stays at 1.
    """

    from repro.core import _compat
    from repro.core import overlap as core_overlap
    from repro.models import transformer

    embed_mb, apply_units, loss_mb = transformer.pipeline_stage_fns(cfg, pcfg)
    plan = plan if plan is not None else tcfg.resolved_plan()
    m = max(1, plan.microbatches)
    mesh = cart.mesh

    def spmd_loss(params, batch):
        tokens = batch["tokens"]                     # local (b_loc, T)
        errors.check(
            tokens.shape[0] % m == 0,
            errors.ErrorClass.ERR_COUNT,
            f"local batch {tokens.shape[0]} does not split into {m} microbatches",
        )
        mb = tokens.shape[0] // m
        toks = tokens.reshape(m, mb, tokens.shape[1])
        losses = core_overlap.pipeline_spmd(
            cart,
            stage_dim=1,
            num_microbatches=m,
            inject=lambda i: embed_mb(params, toks[i]),
            stage_fn=lambda state, t: apply_units(params["layers"], state),
            extract=lambda i, state, is_last: jnp.where(
                is_last, loss_mb(params, state, toks[i]), 0.0
            ),
        )
        loss = sum(losses) / m
        # only the last stage contributed; the stage psum replicates it and
        # the data psum averages the per-shard token means
        return jax.lax.psum(loss, ("data", "stage")) / cart.dims[0]

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            pspecs = _pipeline_param_specs(p, cart.dims[1])
            bspecs = jax.tree.map(lambda _: P("data"), batch)
            mapped = _compat.shard_map(
                spmd_loss, mesh=mesh, in_specs=(pspecs, bspecs), out_specs=P()
            )
            loss = mapped(p, batch)
            return loss, {"loss": loss}

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        pcfg: ParallelConfig,
        tcfg: TrainerConfig,
        comm: Communicator | Mesh,
        *,
        seq_len: int = 512,
        global_batch: int = 8,
        injector: FaultInjector | None = None,
        straggler: StragglerPolicy | None = None,
        clock: Callable[[], float] | None = None,
    ):
        self.cfg, self.pcfg, self.tcfg = cfg, pcfg, tcfg
        self.injector = injector
        # Session-derived communicator is the canonical handle onto the
        # training process set; a bare Mesh is wrapped unmanaged.  All comm
        # state lives in the current CommEpoch — the rebuildable fabric the
        # elastic shrink/grow path advances — and `self.comm`/`self.mesh`
        # read through to it.
        comm = comm if isinstance(comm, Communicator) else Communicator(comm)
        self._epoch = self._reform_topology(comm)
        self.seq_len, self.global_batch = seq_len, global_batch
        self.bundle = model_api.build(cfg)
        self.opt = AdamW(
            lr=cosine_warmup(tcfg.lr, tcfg.warmup_steps, tcfg.steps),
            weight_decay=tcfg.weight_decay,
            moment_dtype=self.pcfg.moment_dtype,
        )
        self.guard = StepGuard(
            straggler or StragglerPolicy(), injector,
            clock if clock is not None else time.perf_counter,
        )
        self.ckpt = (
            CheckpointManager(
                tcfg.checkpoint_dir,
                keep=tcfg.keep_checkpoints,
                async_save=tcfg.async_checkpoint,
                injector=injector,
            )
            if tcfg.checkpoint_dir
            else None
        )
        self.ckpt_failures = 0
        self.pipeline = TokenPipeline(
            vocab_size=cfg.vocab_size,
            seq_len=seq_len,
            global_batch=global_batch,
            seed=tcfg.seed,
            modality={"encdec": "audio", "vlm": "vlm"}.get(cfg.family, "lm"),
            frame_dim=cfg.d_model,
            frame_len=max(8, seq_len // 8),
            image_tokens=cfg.num_image_tokens,
            image_dim=1152,
        )
        self._compiled = None
        self._bshard = None
        self.metrics_history: list[dict] = []
        self.restarts = 0
        self.evictions = 0
        self.joins = 0

    # -- the fabric: everything comm-shaped reads through the current epoch ---

    @property
    def epoch(self) -> CommEpoch:
        return self._epoch

    @property
    def comm(self) -> Communicator:
        return self._epoch.comm

    @property
    def mesh(self):
        return self._epoch.comm.mesh

    def _reform_topology(self, comm: Communicator) -> CommEpoch:
        """The one place the trainer shapes its fabric: resolve the
        :class:`~repro.configs.base.ParallelPlan` (pipeline and ring were
        two near-identical cart-reform special cases before the plan
        subsumed them), derive the epoch's :class:`TopologySpec` from it,
        and bundle it with the communicator's group into generation 0.  The
        data axis is the elastic dim — shrink/grow re-folds it; the plan's
        stage/ring/tensor dims are fixed."""

        self.plan = plan = self.tcfg.resolved_plan()
        size = comm.group().size()
        if plan.remat is not None:
            self.pcfg = dataclasses.replace(self.pcfg, remat=plan.remat)
        if plan.reforms_fabric:
            errors.check(
                size % plan.fixed_size == 0,
                errors.ErrorClass.ERR_DIMS,
                f"{size} devices do not fold onto plan {plan.slug()!r} "
                f"(fixed axes need a multiple of {plan.fixed_size})",
            )
            spec = TopologySpec.from_plan(plan)
            if plan.ring > 1:
                # the periodic ring dim rides the model axis: attention
                # shards the sequence over the ring and rotates KV via
                # cart_shift(+1) collective-permutes hidden behind compute
                self.pcfg = dataclasses.replace(self.pcfg, ring_attention=True)
        else:
            spec = None  # adopt the communicator's own shape
        return CommEpoch.create(comm, spec, name="train")

    # -- assembly -------------------------------------------------------------

    def init_state(self):
        with self.mesh:
            params = jax.jit(self.bundle.init)(jax.random.PRNGKey(self.tcfg.seed))
            pspecs = self._param_pspecs(params)
            params = jax.device_put(params, rules.shardings(pspecs, self.mesh))
            opt_state = jax.jit(self.opt.init)(params)
            # pin the optimiser state to its declared shardings up front: the
            # persistent executable is bound to them (ERR_REQUEST on drift)
            _, oshard = self._state_shardings(params, opt_state)
            opt_state = jax.device_put(opt_state, oshard)
        return params, opt_state

    def _param_pspecs(self, params):
        if self.plan.stage > 1:
            return _pipeline_param_specs(params, self.plan.stage)
        return rules.param_specs(params, self.mesh, self.pcfg)

    def _state_shardings(self, params, opt_state):
        pspecs = self._param_pspecs(params)
        pshard = rules.shardings(pspecs, self.mesh)
        oshard = jax.tree.map(
            lambda leaf: NamedSharding(self.mesh, P()),
            opt_state,
        )
        # moments inherit the matching parameter's sharding where shapes agree
        flat_p = jax.tree.leaves(pshard)
        shapes = [tuple(np.shape(x)) for x in jax.tree.leaves(params)]
        by_shape = {}
        for s, sh in zip(shapes, flat_p):
            by_shape.setdefault(s, sh)

        def moment_shard(leaf, cur):
            s = tuple(np.shape(leaf))
            return by_shape.get(s, cur)

        oshard = jax.tree.map(moment_shard, opt_state, oshard)
        return pshard, oshard

    def _shardings_for(self, params, opt_state, batch):
        pshard, oshard = self._state_shardings(params, opt_state)
        bshard = {
            k: NamedSharding(self.mesh, s)
            for k, s in zip(
                batch.keys(), jax.tree.leaves(rules.batch_spec(batch, self.mesh, self.pcfg))
            )
        }
        return pshard, oshard, bshard

    def compile(self, params, opt_state):
        """The epoch's persistent step executable, built lazily exactly once
        per epoch (``epoch.cached``).  A shrink/grow revokes the old epoch —
        and with it the request whose shardings the new mesh would reject
        with ``ERR_REQUEST`` — so the successor epoch rebuilds here on first
        use: ``trace:train_step`` is 1 per epoch by construction."""

        self._compiled, self._bshard = self._epoch.cached(
            "train_step", lambda _ep: self._build_step(params, opt_state)
        )
        return self._compiled

    def _build_step(self, params, opt_state):
        batch = self.pipeline.device_batch(0, self.mesh, self.pcfg)
        if self.plan.stage > 1:
            base_step = make_pipeline_train_step(
                self.cfg, self.pcfg, self.tcfg, self.opt, self.comm,
                plan=self.plan,
            )
        else:
            base_step = make_train_step(
                self.cfg, self.pcfg, self.tcfg, self.opt,
                mesh=self.mesh if self.pcfg.ring_attention else None,
            )

        def step_fn(params, opt_state, batch):
            # a python side effect at trace time: the pvar counts every trace
            # of the step, so tests can assert the hot loop never re-traces
            tool.pvar_count("trace:train_step")
            return base_step(params, opt_state, batch)

        pshard, oshard, bshard = self._shardings_for(params, opt_state, batch)
        with self.mesh:
            if self.tcfg.persistent:
                # persistent execution engine: AOT lower+compile once against
                # the canonical shardings; every step is an MPI_Start re-fire
                # of the executable (donated params/opt-state alias outputs).
                example = (
                    jax.device_put(params, pshard),
                    jax.device_put(opt_state, oshard),
                    jax.device_put(batch, bshard),
                )
                donate = (0, 1) if self.tcfg.donate else ()
                jitted = jax.jit(
                    step_fn,
                    in_shardings=(pshard, oshard, bshard),
                    out_shardings=(pshard, oshard, None),
                    donate_argnums=donate,
                )
                return (
                    PersistentRequest(jitted, example, donate_argnums=donate),
                    bshard,
                )
            else:
                # NOTE: no donation here — the straggler policy re-dispatches
                # the same step with the same inputs, which donated buffers
                # forbid.  The production lowering (launch/dryrun.py) donates
                # params and opt state; at scale the straggler retry path
                # instead restores from the last checkpoint (the failure
                # path below).
                return (
                    jax.jit(
                        step_fn,
                        in_shardings=(pshard, oshard, bshard),
                        out_shardings=(pshard, oshard, None),
                    ),
                    bshard,
                )

    # -- the loop --------------------------------------------------------------

    def run(self, steps: int | None = None) -> dict:
        steps = steps if steps is not None else self.tcfg.steps
        params, opt_state = self.init_state()
        start = 0
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            params, opt_state, start = self._restore(params, opt_state)
        self.compile(params, opt_state)

        step = start
        while step < steps:
            try:
                params, opt_state, step = self._run_span(
                    params, opt_state, step, steps
                )
            except RankEvicted as e:
                # ULFM path: no job restart — revoke, shrink to survivors,
                # rebuild the fabric, restore the last committed manifest
                self.evictions += 1
                if self.evictions + self.restarts > self.tcfg.max_restarts:
                    raise
                log.warning("rank %d evicted at step %d; shrinking", e.rank, e.step)
                params, opt_state, step = self._shrink(e)
            except WorkerFailure as e:
                self.restarts += 1
                if self.restarts > self.tcfg.max_restarts:
                    raise
                log.warning("worker failure at step %d (%s); restarting", step, e)
                params, opt_state, step = self._recover()
        if self.ckpt is not None:
            self._checkpoint(step, params, opt_state, join=True)
        return {
            "final_step": step,
            "restarts": self.restarts,
            "evictions": self.evictions,
            "joins": self.joins,
            "epoch": self._epoch.generation,
            "world_size": self.comm.size(),
            "ckpt_failures": self.ckpt_failures,
            "metrics": self.metrics_history,
        }

    def _checkpoint(self, step, params, opt_state, *, join: bool = False) -> None:
        """Issue the (async) checkpoint save; ``join=True`` additionally
        waits for durability.  A failed save — surfaced as ``ERR_IO`` from
        the request join, typically when the *previous* save's completion is
        collected — is counted and logged, never silently dropped: training
        continues from device state and ``latest`` stays at the last
        complete step (the production policy for checkpoint I/O errors)."""

        try:
            # collect the previous save's outcome first, so its failure is
            # reported without skipping this step's save
            self.ckpt.wait()
        except errors.IoError as e:
            self._note_ckpt_failure(step, e)
        try:
            self.ckpt.save(
                step,
                {"params": params, "opt": opt_state},
                extra={"step": step},
                # manifests carry the fabric they were written under, so an
                # elastic restore knows it is resharding across world sizes
                meta={
                    "epoch": self._epoch.generation,
                    "world_size": self.comm.size(),
                },
            )
            if join:
                self.ckpt.wait()
        except errors.IoError as e:
            self._note_ckpt_failure(step, e)

    def _note_ckpt_failure(self, step: int, e: Exception) -> None:
        self.ckpt_failures += 1
        tool.pvar_count("ckpt_save_failed")
        log.warning("checkpoint save failed at step %d: %s", step, e)

    def _run_span(self, params, opt_state, step, steps):
        # donated buffers cannot be re-dispatched: stragglers under the
        # persistent engine take the failure path (checkpoint restore)
        retry_safe = not (self.tcfg.persistent and self.tcfg.donate)
        while step < steps:
            if self.injector is not None:
                joiners = self.injector.take_admissions(step)
                if joiners:
                    params, opt_state = self._grow(joiners, params, opt_state)
            step_fn = self._compiled
            batch = self.pipeline.device_batch(step, self.mesh, self.pcfg)
            if self.tcfg.persistent:
                # no-op when device_batch already matches the bound sharding
                batch = jax.device_put(batch, self._bshard)

            def do_step():
                new_p, new_o, metrics = step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                return new_p, new_o, metrics

            (params, opt_state, metrics), info = self.guard.run(
                step,
                do_step,
                retry_safe=retry_safe,
                # a step sharing the host with an in-flight checkpoint save
                # is slow from known interference, not worker sickness
                exempt=self.ckpt is not None and self.ckpt.pending(),
            )
            step += 1
            if step % self.tcfg.log_every == 0 or step == steps:
                pvars = tool.pvar_read()
                rec = {
                    "step": step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    **{k: float(v) for k, v in info.items() if k != "straggled"},
                    "persistent_start": pvars.get("persistent_start", 0),
                    "partition_ready": pvars.get("partition_ready", 0),
                }
                self.metrics_history.append(rec)
                log.info(
                    "step %(step)d loss %(loss).4f "
                    "persistent_start %(persistent_start)d "
                    "partition_ready %(partition_ready)d", rec,
                )
            if (
                self.ckpt is not None
                and self.tcfg.checkpoint_every
                and step % self.tcfg.checkpoint_every == 0
            ):
                # the save's file I/O overlaps the following steps; the next
                # save (or run-end/exit) joins it and surfaces any failure
                self._checkpoint(step, params, opt_state)
        return params, opt_state, step

    # -- recovery ---------------------------------------------------------------

    def _shrink(self, evt: RankEvicted):
        """The ULFM recovery loop, one method: revoke → ``Group.difference``
        shrink → ``Communicator.from_group`` / cart re-fold rebuild →
        restore from the last committed manifest → continue on the
        survivors.  The old epoch's persistent request dies with it (its
        shardings would raise ``ERR_REQUEST`` on the shrunken mesh); the
        successor epoch rebuilds it lazily in :meth:`compile`."""

        self._epoch = self._epoch.shrink([evt.rank])
        log.warning(
            "epoch %d: %s survivors fold onto %s",
            self._epoch.generation, self._epoch.pool.size(), self._epoch.dims,
        )
        params, opt_state = self.init_state()
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            params, opt_state, step = self._restore(params, opt_state)
        else:
            step = 0
        tool.pvar_add("elastic:recovery_steps", max(0, evt.step - step))
        self.compile(params, opt_state)
        return params, opt_state, step

    def _grow(self, count: int, params, opt_state):
        """The reverse path: hot-join up to ``count`` spare ranks (world
        minus the epoch's pool), re-fold the elastic data axis, and reshard
        the *live* state onto the grown mesh — growing loses no steps, so
        there is nothing to restore."""

        spares = (
            self._epoch.session.group("repro://world")
            .difference(self._epoch.pool)
            .devices[:count]
        )
        if not spares:
            log.warning("admission requested but no spare ranks; continuing")
            return params, opt_state
        self._epoch = self._epoch.grow(spares)
        self.joins += len(spares)
        tool.pvar_count("elastic:joins")
        log.warning(
            "epoch %d: %d rank(s) joined, folding onto %s",
            self._epoch.generation, len(spares), self._epoch.dims,
        )
        with self.mesh:
            pshard, oshard = self._state_shardings(params, opt_state)
            params = jax.device_put(params, pshard)
            opt_state = jax.device_put(opt_state, oshard)
        self.compile(params, opt_state)
        return params, opt_state

    def _recover(self):
        """Restart protocol: re-form mesh (elastic), restore newest complete
        checkpoint, resume from its step (data is stateless)."""

        if self.ckpt is not None:
            # join the in-flight save first (tolerantly): without this,
            # latest_step() cannot see a save that is mid-commit and
            # recovery would reinitialise, discarding the steps that save
            # was about to preserve
            try:
                self.ckpt.wait()
            except errors.IoError as e:
                self._note_ckpt_failure(-1, e)
        if self.ckpt is None or self.ckpt.latest_step() is None:
            params, opt_state = self.init_state()
            return params, opt_state, 0
        params, opt_state = self.init_state()
        return self._restore(params, opt_state)

    def _restore(self, params, opt_state):
        # collect any in-flight save first, tolerantly: recovery must
        # proceed from the newest COMPLETE checkpoint even if the save that
        # was pending when the worker failed has itself failed
        try:
            self.ckpt.wait()
        except errors.IoError as e:
            self._note_ckpt_failure(-1, e)
        pshard, oshard, _ = self._shardings_for(
            params, opt_state, self.pipeline.device_batch(0, self.mesh, self.pcfg)
        )
        tree, step = self.ckpt.restore(
            {"params": params, "opt": opt_state},
            shardings={"params": pshard, "opt": oshard},
        )
        extra_step = self.ckpt.extra(step).get("step", step)
        return tree["params"], tree["opt"], int(extra_step)
