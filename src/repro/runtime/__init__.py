"""Training/serving runtime: step loops, fault tolerance (checkpoint/restart
with failure injection), straggler mitigation, elastic rescale, metrics."""

from repro.runtime.trainer import Trainer, TrainerConfig  # noqa: F401
from repro.runtime.server import Server, ServerConfig  # noqa: F401
from repro.runtime.faults import FaultInjector, StragglerPolicy  # noqa: F401
