"""Attention: GQA/MQA (+ RoPE, sliding window, softcap), MLA (deepseek-v2),
KV caches (bf16 / int8, linear or ring-buffer), and the distributed decode
paths (sequence-sharded cache with flash-decoding merge via repro.core).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.core import _compat, overlap
from repro.core.communicator import Communicator
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.models import common
from repro.models.common import dense_init, key_iter


# ---------------------------------------------------------------------------
# KV cache
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class KVCache:
    """Per-model stacked KV cache.  ``k``/``v``: (L, B, S, Hk, Dh) in
    ``dtype`` (int8 with per-(token, head) ``*_scale`` when quantised).
    ``length``: ring-buffer capacity == S; ``pos``: global position count.
    Sliding-window layers use S == window with ring addressing."""

    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None
    v_scale: jax.Array | None
    pos: jax.Array  # () int32 — number of tokens already cached

    @staticmethod
    def init(
        num_layers: int,
        batch: int,
        length: int,
        kv_heads: int,
        head_dim: int,
        *,
        dtype=jnp.bfloat16,
        quantized: bool = False,
    ) -> "KVCache":
        shape = (num_layers, batch, length, kv_heads, head_dim)
        if quantized:
            return KVCache(
                k=jnp.zeros(shape, jnp.int8),
                v=jnp.zeros(shape, jnp.int8),
                k_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32),
                v_scale=jnp.zeros(shape[:-1] + (1,), jnp.float32),
                pos=jnp.zeros((), jnp.int32),
            )
        return KVCache(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            k_scale=None,
            v_scale=None,
            pos=jnp.zeros((), jnp.int32),
        )


def _quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-(token, head) symmetric int8: x (..., Dh) → (int8, fp32 scale)."""

    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _row_update(layer: jax.Array, new: jax.Array, write_pos: jax.Array) -> jax.Array:
    """Per-row cache write: ``layer`` (B, S, ...), ``new`` (B, T, ...),
    ``write_pos`` (B,) — each batch row writes at its own position (the
    continuous-batching slot table, where rows decode at ragged depths)."""

    return jax.vmap(
        lambda l, n, w: jax.lax.dynamic_update_slice_in_dim(l, n, w, axis=0)
    )(layer, new.astype(layer.dtype), write_pos)


def cache_layer_update(
    k_layer: jax.Array,
    v_layer: jax.Array,
    k_scale_l: jax.Array | None,
    v_scale_l: jax.Array | None,
    k_new: jax.Array,
    v_new: jax.Array,
    pos: jax.Array,
    *,
    ring: bool,
) -> tuple[jax.Array, jax.Array, jax.Array | None, jax.Array | None]:
    """Write k_new/v_new (B, T, Hk, Dh) at ``pos`` (ring: pos % capacity).

    ``pos`` is the shared scalar in the fixed-batch serving path, or a
    per-row ``(B,)`` vector when rows live at different depths (the
    continuous-batching engine); vector positions write through a vmapped
    per-row update."""

    capacity = k_layer.shape[1]
    write_pos = (pos % capacity) if ring else pos
    per_row = jnp.ndim(pos) == 1
    if k_layer.dtype == jnp.int8:
        kq, ks = _quantize_kv(k_new)
        vq, vs = _quantize_kv(v_new)
        if per_row:
            k_layer = _row_update(k_layer, kq, write_pos)
            v_layer = _row_update(v_layer, vq, write_pos)
            k_scale_l = _row_update(k_scale_l, ks, write_pos)
            v_scale_l = _row_update(v_scale_l, vs, write_pos)
        else:
            k_layer = jax.lax.dynamic_update_slice_in_dim(k_layer, kq, write_pos, axis=1)
            v_layer = jax.lax.dynamic_update_slice_in_dim(v_layer, vq, write_pos, axis=1)
            k_scale_l = jax.lax.dynamic_update_slice_in_dim(k_scale_l, ks, write_pos, axis=1)
            v_scale_l = jax.lax.dynamic_update_slice_in_dim(v_scale_l, vs, write_pos, axis=1)
    elif per_row:
        k_layer = _row_update(k_layer, k_new, write_pos)
        v_layer = _row_update(v_layer, v_new, write_pos)
    else:
        k_layer = jax.lax.dynamic_update_slice_in_dim(
            k_layer, k_new.astype(k_layer.dtype), write_pos, axis=1
        )
        v_layer = jax.lax.dynamic_update_slice_in_dim(
            v_layer, v_new.astype(v_layer.dtype), write_pos, axis=1
        )
    return k_layer, v_layer, k_scale_l, v_scale_l


def cache_layer_read(k_layer, v_layer, k_scale_l, v_scale_l, dtype):
    if k_layer.dtype == jnp.int8:
        return (
            _dequantize_kv(k_layer, k_scale_l, dtype),
            _dequantize_kv(v_layer, v_scale_l, dtype),
        )
    return k_layer.astype(dtype), v_layer.astype(dtype)


# ---------------------------------------------------------------------------
# GQA parameters
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype) -> common.Params:
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = key_iter(key)
    p = {
        "wq": dense_init(next(ks), d, (d, h, dh), dtype),
        "wk": dense_init(next(ks), d, (d, hk, dh), dtype),
        "wv": dense_init(next(ks), d, (d, hk, dh), dtype),
        "wo": dense_init(next(ks), h * dh, (h, dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((hk, dh), dtype)
        p["bv"] = jnp.zeros((hk, dh), dtype)
    return p


def _project_qkv(p, x, cfg, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = common.rope(q, positions, theta=cfg.rope_theta)
    k = common.rope(k, positions, theta=cfg.rope_theta)
    return q, k, v


def _scale(cfg) -> float:
    return cfg.query_scale if cfg.query_scale is not None else 1.0 / math.sqrt(cfg.head_dim)


# ---------------------------------------------------------------------------
# full-sequence attention (train / prefill)
# ---------------------------------------------------------------------------


def attention_full(
    p: common.Params,
    x: jax.Array,            # (B, S, D)
    cfg,
    pcfg,
    *,
    positions: jax.Array,    # (S,) or (B, S)
    sliding_window: int | None,
    prefix_len: int | None = None,
    mesh=None,
) -> jax.Array:
    q, k, v = _project_qkv(p, x, cfg, positions)

    if pcfg.ring_attention and mesh is not None and not cfg.attn_logit_softcap and \
            sliding_window is None and prefix_len is None:
        out = _ring_attention_sharded(q, k, v, pcfg, mesh, scale=_scale(cfg))
    else:
        out = fa_ops.flash_attention(
            q,
            k,
            v,
            causal=True,
            sliding_window=sliding_window,
            prefix_len=prefix_len,
            logit_softcap=cfg.attn_logit_softcap,
            scale=_scale(cfg),
            impl=getattr(pcfg, "attn_impl", "ref"),
            q_block_axis=pcfg.model_axis if pcfg.attn_plan == "sp" else None,
        )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _ring_attention_sharded(q, k, v, pcfg, mesh, *, scale, causal=True):
    """Sequence parallelism for training and long prefill: shard the
    sequence over the model axis, fold it onto a 1-D periodic cart ring and
    run the fused blockwise ring kernel (``kernels/ring_attention``) — the
    stacked KV buffer rotates via ``cart_shift(+1)`` collective-permutes
    hidden behind each step's compute.  Global lengths that do not divide
    the ring are padded here (the kernel masks the tail) and sliced back."""

    from jax.sharding import PartitionSpec as P

    from repro.core import topology
    from repro.kernels.ring_attention import ops as ring_ops

    axis = pcfg.model_axis
    n = mesh.shape[axis]
    cart = topology.CartComm(
        mesh, (axis,), dims=(n,), periods=(True,), managed=False, tag="ring-attn"
    )
    s = q.shape[1]
    pad = (-s) % n
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, widths), jnp.pad(k, widths), jnp.pad(v, widths)
    spec = P(pcfg.data_axes, axis, None, None)
    impl = {"chunked": "ref"}.get(
        getattr(pcfg, "attn_impl", "ref"), getattr(pcfg, "attn_impl", "ref")
    )

    def body(ql, kl, vl):
        return ring_ops.ring_attention(
            cart, ql, kl, vl, causal=causal, scale=scale, global_len=s, impl=impl
        )

    out = _compat.shard_map(
        body, mesh=mesh, in_specs=(spec, spec, spec), out_specs=spec
    )(q, k, v)
    return out[:, :s] if pad else out


# ---------------------------------------------------------------------------
# prefill / decode with cache
# ---------------------------------------------------------------------------


def attention_prefill(
    p, x, cfg, pcfg, *, positions, sliding_window, prefix_len=None, mesh=None
):
    """Full-sequence attention that also returns the layer's new KV entries
    (B, S_cache, Hk, Dh) — S_cache is min(S, window) for windowed layers."""

    q, k, v = _project_qkv(p, x, cfg, positions)
    if pcfg.ring_attention and mesh is not None and not cfg.attn_logit_softcap and \
            sliding_window is None and prefix_len is None:
        # long-prompt prefill: the ring kernel admits prompts whose KV does
        # not fit one device — same sharded-sequence path as training
        out = _ring_attention_sharded(q, k, v, pcfg, mesh, scale=_scale(cfg))
    else:
        out = fa_ops.flash_attention(
            q,
            k,
            v,
            causal=True,
            sliding_window=sliding_window,
            prefix_len=prefix_len,
            logit_softcap=cfg.attn_logit_softcap,
            scale=_scale(cfg),
            impl=getattr(pcfg, "attn_impl", "ref"),
            q_block_axis=pcfg.model_axis if pcfg.attn_plan == "sp" else None,
        )
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if sliding_window is not None and k.shape[1] > sliding_window:
        # ring-buffer layout: slot i holds the latest token with pos%win == i
        s = k.shape[1]
        start = s - sliding_window
        # ring layout: global position p lives in slot p % window
        roll = s % sliding_window
        k_keep = jnp.roll(k[:, start:], roll, axis=1)
        v_keep = jnp.roll(v[:, start:], roll, axis=1)
        return y, (k_keep, v_keep)
    return y, (k, v)


def attention_decode(
    p,
    x1: jax.Array,           # (B, 1, D)
    k_layer,
    v_layer,
    k_scale_l,
    v_scale_l,
    pos: jax.Array,          # () int32 tokens already cached — or (B,) per-row
    cfg,
    pcfg,
    *,
    sliding_window: int | None,
    mesh=None,
):
    """Single-token attention against a cached layer.  Returns
    (y (B,1,D), updated cache slices).  Scalar ``pos`` is the fixed-batch
    path (all rows at one depth); a ``(B,)`` vector gives each row its own
    depth — the per-slot position of the continuous-batching engine — with
    a per-row validity mask replacing the shared one."""

    dtype = x1.dtype
    per_row = jnp.ndim(pos) == 1
    positions = pos[:, None] if per_row else pos[None]
    q, k_new, v_new = _project_qkv(p, x1, cfg, positions)
    ring = sliding_window is not None and k_layer.shape[1] == sliding_window
    k_layer, v_layer, k_scale_l, v_scale_l = cache_layer_update(
        k_layer, v_layer, k_scale_l, v_scale_l, k_new, v_new, pos, ring=ring
    )
    capacity = k_layer.shape[1]

    # pos broadcasts against the slot index: () keeps the shared (capacity,)
    # mask, (B, 1) makes it per-row (B, capacity)
    pos_b = pos[:, None] if per_row else pos
    if ring:
        # slot i holds global position p_i = pos - ((pos - i) mod capacity)
        slots = jnp.arange(capacity)
        slot_pos = pos_b - ((pos_b - slots) % capacity)
        valid = slot_pos >= jnp.maximum(0, pos_b - capacity + 1)
        valid = jnp.logical_and(valid, slot_pos <= pos_b)
    else:
        slot_pos = jnp.arange(capacity)
        valid = slot_pos <= pos_b
    if sliding_window is not None:
        valid = jnp.logical_and(valid, pos_b - slot_pos < sliding_window)

    if (
        pcfg.seq_shard_cache
        and pcfg.flash_decode_merge
        and mesh is not None
        and not ring
    ):
        y = _flash_decode_sharded(
            q, k_layer, v_layer, k_scale_l, v_scale_l, valid, cfg, pcfg, mesh, dtype
        )
    else:
        kc, vc = cache_layer_read(k_layer, v_layer, k_scale_l, v_scale_l, dtype)
        y = _decode_attend(q, kc, vc, valid, cfg)
    y = jnp.einsum("bshk,hkd->bsd", y, p["wo"])
    return y, (k_layer, v_layer, k_scale_l, v_scale_l)


def _decode_attend(q, kc, vc, valid, cfg):
    h, hk = q.shape[2], kc.shape[2]
    if hk != h:
        kc = jnp.repeat(kc, h // hk, axis=2)
        vc = jnp.repeat(vc, h // hk, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kc.astype(jnp.float32))
    s = s * _scale(cfg)
    s = common.softcap(s, cfg.attn_logit_softcap)
    # valid is (capacity,) shared across the batch, or (B, capacity) per-row
    mask = valid[None, None, None, :] if valid.ndim == 1 else valid[:, None, None, :]
    s = jnp.where(mask, s, fa_ref.NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", pattn, vc.astype(jnp.float32)).astype(q.dtype)


def _flash_decode_sharded(q, k_layer, v_layer, k_scale_l, v_scale_l, valid, cfg, pcfg,
                          mesh, dtype):
    """Sequence-sharded KV cache decode: each model-axis shard attends over
    its slice, then the exact softmax merge combines (O(B·H) payload instead
    of all-gathering the cache)."""

    from jax.sharding import PartitionSpec as P

    axis = pcfg.model_axis
    comm = Communicator(mesh, (axis,))
    b_axes = pcfg.data_axes
    q_spec = P(b_axes, None, None, None)
    kv_spec = P(b_axes, axis, None, None)
    sc_spec = None if k_scale_l is None else P(b_axes, axis, None, None)
    valid_spec = P(axis) if valid.ndim == 1 else P(b_axes, axis)

    def body(ql, kl, vl, ksl, vsl, validl):
        kc, vc = cache_layer_read(kl, vl, ksl, vsl, dtype)
        h, hk = ql.shape[2], kc.shape[2]
        if hk != h:
            kc = jnp.repeat(kc, h // hk, axis=2)
            vc = jnp.repeat(vc, h // hk, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", ql.astype(jnp.float32), kc.astype(jnp.float32))
        s = s * _scale(cfg)
        s = common.softcap(s, cfg.attn_logit_softcap)
        maskl = (
            validl[None, None, None, :] if validl.ndim == 1
            else validl[:, None, None, :]
        )
        s = jnp.where(maskl, s, fa_ref.NEG_INF)
        m = jnp.max(s, axis=-1)
        p_ = jnp.exp(s - m[..., None])
        l = jnp.sum(p_, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p_, vc.astype(jnp.float32))
        o = o / jnp.maximum(jnp.swapaxes(l, 1, 2), 1e-30)[..., None]
        return overlap.merge_partial_attention(o, m, l, comm).astype(ql.dtype)

    args = [q, k_layer, v_layer]
    specs = [q_spec, kv_spec, kv_spec]
    if k_scale_l is not None:
        body_fn = body
        args += [k_scale_l, v_scale_l, valid]
        specs += [sc_spec, sc_spec, valid_spec]
    else:
        def body_fn(ql, kl, vl, validl):  # type: ignore[misc]
            return body(ql, kl, vl, None, None, validl)

        args += [valid]
        specs += [valid_spec]
    return _compat.shard_map(
        body_fn,
        mesh=mesh,
        in_specs=tuple(specs),
        out_specs=q_spec,
    )(*args)


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank Q/KV with compressed cache + absorbed decode
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class MLACache:
    """Compressed latent cache: ``ckv`` (L, B, S, kv_lora), ``k_rope``
    (L, B, S, rope_dim), ``pos`` ()."""

    ckv: jax.Array
    k_rope: jax.Array
    pos: jax.Array

    @staticmethod
    def init(num_layers, batch, length, kv_lora, rope_dim, dtype=jnp.bfloat16):
        return MLACache(
            ckv=jnp.zeros((num_layers, batch, length, kv_lora), dtype),
            k_rope=jnp.zeros((num_layers, batch, length, rope_dim), dtype),
            pos=jnp.zeros((), jnp.int32),
        )


def init_mla(key, cfg, dtype) -> common.Params:
    d, h = cfg.d_model, cfg.num_heads
    dn, dr, dv = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = key_iter(key)
    return {
        "wq_a": dense_init(next(ks), d, (d, cfg.q_lora), dtype),
        "q_norm": common.init_rmsnorm(cfg.q_lora, dtype),
        "wq_b": dense_init(next(ks), cfg.q_lora, (cfg.q_lora, h, dn + dr), dtype),
        "wkv_a": dense_init(next(ks), d, (d, cfg.kv_lora + dr), dtype),
        "kv_norm": common.init_rmsnorm(cfg.kv_lora, dtype),
        "wk_b": dense_init(next(ks), cfg.kv_lora, (cfg.kv_lora, h, dn), dtype),
        "wv_b": dense_init(next(ks), cfg.kv_lora, (cfg.kv_lora, h, dv), dtype),
        "wo": dense_init(next(ks), h * dv, (h, dv, d), dtype),
    }


def _mla_scale(cfg) -> float:
    return 1.0 / math.sqrt(cfg.nope_head_dim + cfg.rope_head_dim)


def _mla_latents(p, x, cfg, positions):
    """Shared q/kv latent computation.  Returns (q_nope, q_rope, ckv, k_rope)."""

    cq = common.rms_norm(jnp.einsum("bsd,dq->bsq", x, p["wq_a"]), p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsq,qhk->bshk", cq, p["wq_b"])
    q_nope, q_rope = q[..., : cfg.nope_head_dim], q[..., cfg.nope_head_dim :]
    q_rope = common.rope(q_rope, positions, theta=cfg.rope_theta)

    kv = jnp.einsum("bsd,dk->bsk", x, p["wkv_a"])
    ckv, k_rope = kv[..., : cfg.kv_lora], kv[..., cfg.kv_lora :]
    ckv = common.rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = common.rope(k_rope[:, :, None, :], positions, theta=cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, ckv, k_rope


def mla_attention_full(p, x, cfg, pcfg, *, positions, mesh=None, return_cache=False):
    """Training/prefill MLA: expand the latents and run standard attention."""

    q_nope, q_rope, ckv, k_rope = _mla_latents(p, x, cfg, positions)
    k_nope = jnp.einsum("bsk,khn->bshn", ckv, p["wk_b"])
    v = jnp.einsum("bsk,khv->bshv", ckv, p["wv_b"])
    h = cfg.num_heads
    k_rope_h = jnp.broadcast_to(
        k_rope[:, :, None, :], k_rope.shape[:2] + (h, cfg.rope_head_dim)
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope_h], axis=-1)
    out = fa_ops.flash_attention(
        q_full,
        k_full,
        v,
        causal=True,
        scale=_mla_scale(cfg),
        impl=getattr(pcfg, "attn_impl", "ref"),
    )
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    if return_cache:
        return y, (ckv, k_rope)
    return y


def mla_attention_decode(p, x1, ckv_layer, krope_layer, pos, cfg, pcfg, *, mesh=None):
    """Absorbed decode: attend in the compressed latent space — the W^UK
    absorption that makes the MLA cache pay off (no per-step expansion)."""

    per_row = jnp.ndim(pos) == 1
    positions = pos[:, None] if per_row else pos[None]
    q_nope, q_rope, ckv_new, krope_new = _mla_latents(p, x1, cfg, positions)
    if per_row:
        ckv_layer = _row_update(ckv_layer, ckv_new, pos)
        krope_layer = _row_update(krope_layer, krope_new, pos)
    else:
        ckv_layer = jax.lax.dynamic_update_slice_in_dim(
            ckv_layer, ckv_new.astype(ckv_layer.dtype), pos, axis=1
        )
        krope_layer = jax.lax.dynamic_update_slice_in_dim(
            krope_layer, krope_new.astype(krope_layer.dtype), pos, axis=1
        )
    capacity = ckv_layer.shape[1]
    pos_b = pos[:, None] if per_row else pos
    valid = jnp.arange(capacity) <= pos_b

    # absorb: q_latent = q_nope @ W^UK  → (B, 1, H, kv_lora)
    q_latent = jnp.einsum("bshn,khn->bshk", q_nope, p["wk_b"])
    s = jnp.einsum(
        "bshk,btk->bhst", q_latent.astype(jnp.float32), ckv_layer.astype(jnp.float32)
    )
    s = s + jnp.einsum(
        "bshr,btr->bhst", q_rope.astype(jnp.float32), krope_layer.astype(jnp.float32)
    )
    s = s * _mla_scale(cfg)
    mask = valid[None, None, None, :] if valid.ndim == 1 else valid[:, None, None, :]
    s = jnp.where(mask, s, fa_ref.NEG_INF)
    pattn = jax.nn.softmax(s, axis=-1)
    o_latent = jnp.einsum("bhst,btk->bshk", pattn, ckv_layer.astype(jnp.float32))
    out = jnp.einsum("bshk,khv->bshv", o_latent.astype(x1.dtype), p["wv_b"])
    y = jnp.einsum("bshv,hvd->bsd", out, p["wo"])
    return y, (ckv_layer, krope_layer)
