"""Shared model components: norms, RoPE, initialisers, activation helpers."""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def dtype_of(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


# -- init ----------------------------------------------------------------------


def trunc_normal(key, shape, scale: float, dtype) -> jax.Array:
    stddev = scale / math.sqrt(max(1, shape[0] if len(shape) else 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev).astype(
        dtype
    )


def dense_init(key, in_dim: int, shape: tuple[int, ...], dtype) -> jax.Array:
    stddev = 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * stddev).astype(
        dtype
    )


def key_iter(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# -- norms -----------------------------------------------------------------------


def init_rmsnorm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype)  # stored as (scale - 1), gemma-style


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    return (normed * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# -- activations --------------------------------------------------------------------


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# -- rotary embeddings -----------------------------------------------------------------


def rope(
    x: jax.Array, positions: jax.Array, *, theta: float, rope_dim: int | None = None
) -> jax.Array:
    """Apply rotary embedding.  x: (..., seq, heads, head_dim); positions:
    broadcastable to (..., seq).  ``rope_dim`` rotates only the first
    ``rope_dim`` features (partial RoPE)."""

    d = x.shape[-1]
    rd = rope_dim or d
    half = rd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, half)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    xr, rest = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., :half], xr[..., half:]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    out = jnp.concatenate([out1, out2], axis=-1).astype(x.dtype)
    if rest.shape[-1]:
        out = jnp.concatenate([out, rest], axis=-1)
    return out


# -- activation sharding constraints ------------------------------------------------------
#
# GSPMD's propagation, left alone, may re-shard ACTIVATIONS instead of
# gathering FSDP-sharded weights (observed: the whole layer stack running at
# full global batch per device because the (model, fsdp)-sharded embedding
# poisoned propagation).  Production frameworks pin activation shardings
# explicitly (MaxText's logical constraints); these helpers do that under
# the ambient mesh and degrade to no-ops on meshless CPU tests.


def _ambient_mesh_shape() -> dict[str, int]:
    """Axis-name → size of the ambient mesh, from either the new abstract
    mesh (``jax.sharding.use_mesh``) or the legacy ``with mesh:`` context."""

    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return dict(zip(m.axis_names, m.axis_sizes))
    except AttributeError:
        pass  # jax without get_abstract_mesh / axis_sizes (pre-0.4.35 API)
    try:  # legacy resource env
        from jax._src import mesh as _mesh_mod

        pm = _mesh_mod.thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return {a: int(s) for a, s in pm.shape.items()}
    except (ImportError, AttributeError):
        pass  # private module moved/renamed across jax versions
    return {}


def _fit(axes, dim: int, mesh_shape) -> tuple[str, ...] | None:
    """Keep the axis group only if the dim divides its total size."""

    if not axes:
        return None
    total = 1
    for a in axes:
        total *= mesh_shape[a]
    return tuple(axes) if (total > 1 and dim % total == 0) else None


def constrain(x: jax.Array, pcfg, *, logits: bool = False) -> jax.Array:
    """Pin activation sharding: batch over the data axes, last dim over
    'model' for logits; everything else replicated.  No-op without a mesh
    or when a dim does not divide."""

    from jax.sharding import PartitionSpec as P

    shape = _ambient_mesh_shape()
    if not shape:
        return x
    data_axes = tuple(a for a in pcfg.data_axes if a in shape)
    if not data_axes:
        return x
    batch_axes = _fit(data_axes, x.shape[0], shape)
    dims: list = [batch_axes] + [None] * (x.ndim - 1)
    if logits and pcfg.model_axis in shape and x.shape[-1] % shape[pcfg.model_axis] == 0:
        dims[-1] = pcfg.model_axis
    if all(d is None for d in dims):
        return x
    return jax.lax.with_sharding_constraint(x, P(*dims))


# -- losses -------------------------------------------------------------------------------


def cross_entropy(logits: jax.Array, labels: jax.Array, *, softcap_val=None) -> jax.Array:
    """Token-mean CE in fp32; logits (..., V), labels (...)."""

    logits = logits.astype(jnp.float32)
    if softcap_val is not None:
        logits = softcap(logits, softcap_val)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
