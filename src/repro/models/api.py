"""Unified model API: ``build(cfg)`` returns a :class:`ModelBundle` with
init / loss / prefill / decode entry points, plus shape-only variants
(``jax.eval_shape``) used by the dry-run to build caches and param stand-ins
without allocation."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, ssm_lm, transformer


@dataclasses.dataclass
class ModelBundle:
    cfg: Any
    init: Callable                  # (key) -> params
    loss: Callable                  # (params, batch, pcfg, mesh) -> (loss, metrics)
    prefill: Callable               # (params, batch, pcfg, mesh) -> (logits, cache)
    decode: Callable                # (params, cache, token, pcfg, mesh) -> (logits, cache)
    init_cache: Callable | None     # (pcfg, batch, length) -> cache


def build(cfg) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        return ModelBundle(
            cfg=cfg,
            init=lambda key: transformer.init_lm(key, cfg),
            loss=lambda p, b, pc, mesh=None: transformer.lm_loss(p, b, cfg, pc, mesh),
            prefill=lambda p, b, pc, mesh=None, extra_capacity=0: transformer.lm_prefill(
                p, b, cfg, pc, mesh, extra_capacity=extra_capacity
            ),
            decode=lambda p, c, t, pc, mesh=None: transformer.lm_decode(p, c, t, cfg, pc, mesh),
            init_cache=lambda pc, batch, length: transformer.init_cache(cfg, pc, batch, length),
        )
    if fam == "ssm":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: ssm_lm.init_ssm_lm(key, cfg),
            loss=lambda p, b, pc, mesh=None: ssm_lm.ssm_lm_loss(p, b, cfg, pc, mesh),
            prefill=lambda p, b, pc, mesh=None, extra_capacity=0: ssm_lm.ssm_lm_prefill(
                p, b, cfg, pc, mesh, extra_capacity=extra_capacity
            ),
            decode=lambda p, c, t, pc, mesh=None: ssm_lm.ssm_lm_decode(p, c, t, cfg, pc, mesh),
            init_cache=lambda pc, batch, length: ssm_lm.SSMCache.init(
                cfg.num_layers, batch, cfg
            ),
        )
    if fam == "hybrid":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: ssm_lm.init_hybrid_lm(key, cfg),
            loss=lambda p, b, pc, mesh=None: ssm_lm.hybrid_lm_loss(p, b, cfg, pc, mesh),
            prefill=lambda p, b, pc, mesh=None, extra_capacity=0: ssm_lm.hybrid_lm_prefill(
                p, b, cfg, pc, mesh, extra_capacity=extra_capacity
            ),
            decode=lambda p, c, t, pc, mesh=None: ssm_lm.hybrid_lm_decode(
                p, c, t, cfg, pc, mesh
            ),
            init_cache=lambda pc, batch, length: ssm_lm.init_hybrid_cache(
                cfg, pc, batch, length
            ),
        )
    if fam == "encdec":
        return ModelBundle(
            cfg=cfg,
            init=lambda key: encdec.init_encdec(key, cfg),
            loss=lambda p, b, pc, mesh=None: encdec.encdec_loss(p, b, cfg, pc, mesh),
            prefill=lambda p, b, pc, mesh=None, extra_capacity=0: encdec.encdec_prefill(
                p, b, cfg, pc, mesh, extra_capacity=extra_capacity
            ),
            decode=lambda p, c, t, pc, mesh=None: encdec.encdec_decode(p, c, t, cfg, pc, mesh),
            init_cache=None,  # built by prefill shape (cross-attn needs enc length)
        )
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# shape-only helpers (dry-run substrate: no allocation, ShapeDtypeStructs)
# ---------------------------------------------------------------------------


def param_structs(bundle: ModelBundle) -> Any:
    """Parameter ShapeDtypeStructs via ``eval_shape`` (never materialised)."""

    return jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))


def cache_structs(bundle: ModelBundle, pcfg, batch: int, length: int, enc_len: int | None = None):
    """Cache ShapeDtypeStructs for decode dry-runs."""

    if bundle.cfg.family == "encdec":
        def mk():
            params = bundle.init(jax.random.PRNGKey(0))
            b = {
                "frames": jnp.zeros((batch, enc_len or length, bundle.cfg.d_model),
                                    jnp.bfloat16),
                "tokens": jnp.zeros((batch, length), jnp.int32),
            }
            _, cache = bundle.prefill(params, b, pcfg)
            return cache

        return jax.eval_shape(mk)
    return jax.eval_shape(lambda: bundle.init_cache(pcfg, batch, length))
