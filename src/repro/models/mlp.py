"""MLPs: gated (SwiGLU/GeGLU) dense blocks and the mixture-of-experts block
(top-k routing, shared experts, capacity-bounded sort-based dispatch) — plus
the expert-parallel dispatch (:func:`moe_neighbor`) that moves tokens
between expert-owning ranks over an MPI ch. 8 distributed-graph
communicator's ``neighbor_alltoallv``."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import errors
from repro.models import common
from repro.models.common import dense_init, key_iter


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype) -> common.Params:
    ks = key_iter(key)
    return {
        "w_gate": dense_init(next(ks), d, (d, f), dtype),
        "w_up": dense_init(next(ks), d, (d, f), dtype),
        "w_down": dense_init(next(ks), f, (f, d), dtype),
    }


def mlp(p: common.Params, x: jax.Array, act: str) -> jax.Array:
    a = common.activation(act)
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", a(g) * u, p["w_down"])


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------


def _sort_dispatch(rows: jax.Array, bucket: jax.Array, e: int, c: int):
    """Capacity-bounded sort-based dispatch: scatter ``rows`` (n, d) into
    ``(e, c, d)`` slots keyed by ``bucket`` (n,) ids — O(n log n) argsort +
    ``searchsorted`` position-in-bucket instead of the O(n·e) one-hot
    cumsum.  Returns ``(slots, slot)`` where ``slot`` (n,) is each row's
    flat destination (``e*c`` = overflowed/dropped).  Shared by the global
    and per-row MoE paths and both sides of the expert-parallel exchange.
    """

    n = bucket.shape[0]
    order = jnp.argsort(bucket)
    sorted_b = bucket[order]
    first = jnp.searchsorted(sorted_b, sorted_b, side="left")
    pos_in_b = jnp.arange(n) - first
    slot_sorted = sorted_b * c + pos_in_b
    slot_sorted = jnp.where(pos_in_b < c, slot_sorted, e * c)
    slot = jnp.zeros((n,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    slots = (
        jnp.zeros((e * c, rows.shape[-1]), rows.dtype)
        .at[slot]
        .add(rows, mode="drop")
        .reshape(e, c, rows.shape[-1])
    )
    return slots, slot


def init_moe(key, cfg, dtype) -> common.Params:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = key_iter(key)
    p = {
        "router": dense_init(next(ks), d, (d, e), jnp.float32),
        "w_gate": dense_init(next(ks), d, (e, d, f), dtype),
        "w_up": dense_init(next(ks), d, (e, d, f), dtype),
        "w_down": dense_init(next(ks), f, (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(next(ks), d, cfg.num_shared_experts * f, dtype)
    return p


def _pin(x: jax.Array, dims: tuple, pcfg) -> jax.Array:
    """Constrain a MoE-internal tensor under the ambient mesh (no-op without
    one or when a mapped dim does not divide).  ``dims`` entries: 'data'
    (the ParallelConfig data axes), 'model', 'experts' (model axis iff
    shard_experts), or None."""

    if pcfg is None:
        return x
    from jax.sharding import PartitionSpec as P

    shape = common._ambient_mesh_shape()
    if not shape:
        return x
    table = {
        "data": tuple(a for a in pcfg.data_axes if a in shape) or None,
        "model": pcfg.model_axis if pcfg.model_axis in shape else None,
        "experts": (
            pcfg.model_axis
            if pcfg.shard_experts and pcfg.model_axis in shape
            else None
        ),
    }
    out = []
    used: set = set()
    for dim, name in zip(x.shape, dims):
        axes = table.get(name) if name else None
        if axes is not None:
            group = axes if isinstance(axes, tuple) else (axes,)
            if used & set(group):   # a mesh axis may appear once per spec
                axes = None
            else:
                n = 1
                for a in group:
                    n *= shape[a]
                if n <= 1 or dim % n != 0:
                    axes = None
                else:
                    used |= set(group)
        out.append(axes)
    if all(a is None for a in out):
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))


def moe_per_row(
    p: common.Params, x: jax.Array, cfg, pcfg=None
) -> tuple[jax.Array, dict]:
    """Data-local MoE dispatch (§Perf B2): routing, sort and scatter run
    independently per batch row, so the whole dispatch shards cleanly along
    the batch/data axis — no global scatter semantics for GSPMD to resolve
    with giant all-reduces.  Capacity is bounded per row (the per-device
    capacity convention of production MoE systems) instead of globally.
    """

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    xt = x  # (b, s, d)

    logits = jnp.einsum("bsd,de->bse", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (b, s, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    c = min(_round_up(int(cfg.capacity_factor * s * k / e) or 1, 8), s * k)
    token_idx = jnp.repeat(jnp.arange(s), k)

    def dispatch_row(x_row, flat_e):
        return _sort_dispatch(x_row[token_idx], flat_e, e, c)

    slots, slot = jax.vmap(dispatch_row)(xt, top_e.reshape(b, s * k))
    slots = _pin(slots, ("data", "experts", None, None), pcfg)   # (b, e, c, d)

    a = common.activation(cfg.act)
    g = jnp.einsum("becd,edf->becf", slots, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", slots, p["w_up"])
    g = _pin(g, ("data", "experts", None, "model"), pcfg)
    u = _pin(u, ("data", "experts", None, "model"), pcfg)
    hidden = a(g) * u
    out_slots = jnp.einsum("becf,efd->becd", hidden, p["w_down"])
    out_slots = _pin(out_slots, ("data", "experts", None, None), pcfg)
    out_flat = out_slots.reshape(b, e * c, d)

    def combine_row(out_row, slot_row, gate_row):
        gathered = jnp.take(out_row, jnp.minimum(slot_row, e * c - 1), axis=0)
        gathered = jnp.where((slot_row < e * c)[:, None], gathered, 0.0)
        weighted = gathered * gate_row[:, None].astype(gathered.dtype)
        return jnp.zeros((s, d), out_row.dtype).at[token_idx].add(weighted)

    y = jax.vmap(combine_row)(out_flat, slot, top_p.reshape(b, s * k))
    y = _pin(y, ("data", None, None), pcfg)

    if cfg.num_shared_experts:
        y = y + mlp(p["shared"], xt, cfg.act)

    me = jnp.mean(probs, axis=(0, 1))                          # (e,)
    ce_frac = jnp.zeros((e,)).at[top_e.reshape(-1)].add(1.0) / (b * s * k)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce_frac),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_fraction": jnp.mean((slot == e * c).astype(jnp.float32)),
    }
    return y, aux


# ---------------------------------------------------------------------------
# expert-parallel dispatch over a distributed-graph topology (MPI 4.0 ch. 8)
# ---------------------------------------------------------------------------


def expert_dispatch_graph(
    world: int, num_experts: int, *, radius: int | None = None
) -> tuple[list[list[int]], list[list[int]]]:
    """The router's expert map as a ``dist_graph_create_adjacent`` adjacency.

    Rank ``r`` owns experts ``[r·E/W, (r+1)·E/W)`` and its router may select
    experts owned by ranks within ring distance ``radius`` (device-limited
    routing, the production trick that keeps expert dispatch neighbor-local
    instead of world-dense; ``radius=None`` → the full graph, vanilla top-k
    over every expert).  The returned ``(sources, destinations)`` lists are
    symmetric and order-aligned per rank — the property
    :func:`moe_neighbor` needs so expert outputs ride the reverse edges
    home — and include the self-edge (local experts dispatch through the
    same path, keeping the program uniform).
    """

    errors.check(
        num_experts % world == 0,
        errors.ErrorClass.ERR_DIMS,
        f"{num_experts} experts do not shard over {world} ranks",
    )
    r_eff = world if radius is None else int(radius)
    errors.check(
        r_eff >= 0,
        errors.ErrorClass.ERR_ARG,
        f"expert graph radius must be >= 0, got {radius}",
    )
    neighbors = []
    for r in range(world):
        nb = {(r + off) % world for off in range(-r_eff, r_eff + 1)}
        neighbors.append(sorted(nb))
    return [list(n) for n in neighbors], [list(n) for n in neighbors]


def moe_neighbor(
    p: common.Params, x: jax.Array, cfg, graph, *, capacity: int | None = None
) -> tuple[jax.Array, dict]:
    """Expert-parallel MoE dispatch riding ``neighbor_alltoallv`` over a
    :class:`~repro.core.topology.DistGraphComm` built from the router's
    expert map (:func:`expert_dispatch_graph`).

    Runs *inside* ``graph.spmd``: ``x`` (t, d) is this rank's token shard,
    ``p['router']`` is replicated, and the expert tensors hold only the
    **local** expert slice (E/W, ...).  Routing is masked to experts the
    graph can reach; token blocks (capacity-padded) and expert ids travel to
    the owning ranks over the graph's sparse exchange, experts run locally
    through the same sort-based dispatch as the dense path, and outputs ride
    the reverse edges home (the adjacency must be symmetric and
    order-aligned, which :func:`expert_dispatch_graph` guarantees) — two
    ``neighbor_alltoallv`` rounds total (the expert ids travel as a trailing
    payload column of the token exchange), each lowering to per-edge
    ``collective-permute`` matchings, never a world-dense ``all-to-all``.
    """

    t, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    el = p["w_gate"].shape[0]
    n = graph.size()
    errors.check(
        el * n == e,
        errors.ErrorClass.ERR_DIMS,
        f"local expert slice {el} x {n} ranks != {e} experts",
    )
    adj = [graph.dist_graph_neighbors(r) for r in range(n)]
    for r, (srcs, _, dsts, _) in enumerate(adj):
        errors.check(
            tuple(srcs) == tuple(dsts),
            errors.ErrorClass.ERR_TOPOLOGY,
            f"moe_neighbor needs a symmetric, order-aligned expert graph "
            f"(rank {r}: sources {srcs} != destinations {dsts}) — expert "
            f"outputs return over the reverse edges",
        )
    d_out = graph.outdegree()
    c = capacity if capacity is not None else t * k

    # static router map: which experts each rank may select, and the out
    # slot of each owning rank
    slot_tab = np.full((n, n), -1, np.int32)
    mask_tab = np.zeros((n, e), bool)
    owner = np.arange(e) // el
    for r, (_, _, dsts, _) in enumerate(adj):
        for j, dst in enumerate(dsts):
            slot_tab[r, dst] = j
            mask_tab[r, owner == dst] = True
    # every rank's router must be able to fill its top-k from reachable
    # experts; otherwise top_k is forced onto masked (prob-0) experts whose
    # owner is not a neighbor and the dispatch has nowhere to send them
    reachable = mask_tab.sum(axis=1)
    errors.check(
        int(reachable.min()) >= k,
        errors.ErrorClass.ERR_TOPOLOGY,
        f"expert graph reaches only {int(reachable.min())} experts from "
        f"some rank but the router selects top-{k}; widen the graph radius",
    )
    rank = graph.rank()
    mask = jnp.asarray(mask_tab)[rank]                          # (e,)

    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    logits = jnp.where(mask[None, :], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                      # (t, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)                                  # (t*k,)
    token_idx = jnp.repeat(jnp.arange(t), k)
    dest_rank = flat_e // el
    flat_slot = jnp.asarray(slot_tab)[rank][dest_rank]          # out slot, >= 0
    # defence in depth: a -1 slot (unreachable owner) must land in the
    # dropped bucket, never wrap into the last neighbor's block
    flat_slot = jnp.where(flat_slot < 0, d_out, flat_slot)

    # pack token rows with the local expert id as a trailing payload column
    # (one exchange moves both; ids stay exact as long as the mantissa
    # covers the local expert range)
    errors.check(
        el <= 2 ** jnp.finfo(jnp.dtype(x.dtype)).nmant,
        errors.ErrorClass.ERR_TYPE,
        f"{el} local experts are not exactly representable in the id "
        f"column's {jnp.dtype(x.dtype)} payload",
    )
    local_ids = (flat_e % el).astype(x.dtype)[:, None]
    payload = jnp.concatenate([x[token_idx], local_ids], axis=-1)   # (t*k, d+1)
    send_x, pos = _sort_dispatch(payload, flat_slot, d_out, c)

    counts = np.zeros((n, d_out), np.int64)
    for r, (_, _, dsts, _) in enumerate(adj):
        counts[r, : len(dsts)] = c
    recv, _ = graph.neighbor_alltoallv(send_x, counts).get()       # (d_in, c, d+1)
    recv_x, recv_ids = recv[..., :d], recv[..., d]

    # owner side: group arrivals by local expert (capacity = all arrivals:
    # the sender-side capacity already bounded the traffic, so nothing drops
    # here) and run the expert FFNs
    rows_in = recv_x.reshape(-1, d)
    ids_in = jnp.round(recv_ids.reshape(-1)).astype(jnp.int32)
    ci = rows_in.shape[0]
    slots, pos_in = _sort_dispatch(rows_in, ids_in, el, ci)
    a = common.activation(cfg.act)
    g = jnp.einsum("ecd,edf->ecf", slots, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", slots, p["w_up"])
    out_slots = jnp.einsum("ecf,efd->ecd", a(g) * u, p["w_down"]).reshape(-1, d)

    # un-dispatch to arrival order and ride the reverse edges home
    back_rows = jnp.take(out_slots, jnp.minimum(pos_in, el * ci - 1), axis=0)
    back_rows = jnp.where((pos_in < el * ci)[:, None], back_rows, 0.0)
    reply = back_rows.reshape(recv_x.shape)
    home, _ = graph.neighbor_alltoallv(reply, counts).get()        # (d_out, c, d)

    # combine at the origin: gather each dispatch's packed position, weight
    # by the gate, scatter-add per token
    home_flat = home.reshape(-1, d)
    gathered = jnp.take(home_flat, jnp.minimum(pos, d_out * c - 1), axis=0)
    gathered = jnp.where((pos < d_out * c)[:, None], gathered, 0.0)
    weighted = gathered * top_p.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.zeros((t, d), x.dtype).at[token_idx].add(weighted)

    if cfg.num_shared_experts:
        y = y + mlp(p["shared"], x, cfg.act)

    me = jnp.mean(probs, axis=0)
    ce_frac = jnp.zeros((e,)).at[flat_e].add(1.0) / (t * k)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce_frac),
        "router_z_loss": jnp.mean(
            jax.nn.logsumexp(jnp.where(mask[None, :], logits, -1e30), axis=-1) ** 2
        ),
        "dropped_fraction": jnp.mean((pos == d_out * c).astype(jnp.float32)),
    }
    return y, aux


def moe(
    p: common.Params, x: jax.Array, cfg, *, capacity: int | None = None, pcfg=None
) -> tuple[jax.Array, dict]:
    if pcfg is not None and getattr(pcfg, "moe_dispatch", "global") == "per_row":
        return moe_per_row(p, x, cfg, pcfg)
    """Capacity-bounded top-k MoE.

    Dispatch is sort-based (argsort by expert, position-in-expert via
    ``searchsorted`` on the sorted ids — O(T·k log) instead of the O(T·E)
    one-hot cumsum), then a scatter into ``(E, C, D)`` slots, one grouped
    einsum per projection, and a gather-combine.  Overflowing tokens drop
    (capacity factor bounds them); aux losses follow the standard
    load-balance + z-loss recipe.
    """

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (t, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    if capacity is None:
        capacity = _round_up(int(cfg.capacity_factor * t * k / e) or 1, 8)
    c = min(capacity, t * k)

    flat_e = top_e.reshape(-1)                                # (t*k,)
    token_idx = jnp.repeat(jnp.arange(t), k)
    slots, slot = _sort_dispatch(xt[token_idx], flat_e, e, c)
    # NOTE: pinning the dispatched layout here was tried and REFUTED
    # (§Perf B1: global scatter semantics fight the constraints, collective
    # bytes INCREASED 1.6x).  The productive fix is the data-local per-row
    # dispatch above (§Perf B2) — this global path stays paper-plain.

    a = common.activation(cfg.act)
    g = jnp.einsum("ecd,edf->ecf", slots, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", slots, p["w_up"])
    hidden = a(g) * u
    out_slots = jnp.einsum("ecf,efd->ecd", hidden, p["w_down"]).reshape(e * c, d)

    gathered = jnp.take(out_slots, jnp.minimum(slot, e * c - 1), axis=0)
    gathered = jnp.where((slot < e * c)[:, None], gathered, 0.0)
    weighted = gathered * top_p.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.zeros((t, d), xt.dtype).at[token_idx].add(weighted)

    if cfg.num_shared_experts:
        y = y + mlp(p["shared"], xt, cfg.act)

    # aux losses (returned as metrics; weighted by the trainer)
    me = jnp.mean(probs, axis=0)                               # (e,)
    ce_frac = jnp.zeros((e,)).at[flat_e].add(1.0) / (t * k)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce_frac),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_fraction": jnp.mean((slot == e * c).astype(jnp.float32)),
    }
    return y.reshape(b, s, d), aux
