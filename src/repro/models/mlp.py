"""MLPs: gated (SwiGLU/GeGLU) dense blocks and the mixture-of-experts block
(top-k routing, shared experts, capacity-bounded sort-based dispatch)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import dense_init, key_iter


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# ---------------------------------------------------------------------------
# dense gated MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, dtype) -> common.Params:
    ks = key_iter(key)
    return {
        "w_gate": dense_init(next(ks), d, (d, f), dtype),
        "w_up": dense_init(next(ks), d, (d, f), dtype),
        "w_down": dense_init(next(ks), f, (f, d), dtype),
    }


def mlp(p: common.Params, x: jax.Array, act: str) -> jax.Array:
    a = common.activation(act)
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", a(g) * u, p["w_down"])


# ---------------------------------------------------------------------------
# mixture of experts
# ---------------------------------------------------------------------------


def init_moe(key, cfg, dtype) -> common.Params:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = key_iter(key)
    p = {
        "router": dense_init(next(ks), d, (d, e), jnp.float32),
        "w_gate": dense_init(next(ks), d, (e, d, f), dtype),
        "w_up": dense_init(next(ks), d, (e, d, f), dtype),
        "w_down": dense_init(next(ks), f, (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(next(ks), d, cfg.num_shared_experts * f, dtype)
    return p


def _pin(x: jax.Array, dims: tuple, pcfg) -> jax.Array:
    """Constrain a MoE-internal tensor under the ambient mesh (no-op without
    one or when a mapped dim does not divide).  ``dims`` entries: 'data'
    (the ParallelConfig data axes), 'model', 'experts' (model axis iff
    shard_experts), or None."""

    if pcfg is None:
        return x
    from jax.sharding import PartitionSpec as P

    shape = common._ambient_mesh_shape()
    if not shape:
        return x
    table = {
        "data": tuple(a for a in pcfg.data_axes if a in shape) or None,
        "model": pcfg.model_axis if pcfg.model_axis in shape else None,
        "experts": (
            pcfg.model_axis
            if pcfg.shard_experts and pcfg.model_axis in shape
            else None
        ),
    }
    out = []
    used: set = set()
    for dim, name in zip(x.shape, dims):
        axes = table.get(name) if name else None
        if axes is not None:
            group = axes if isinstance(axes, tuple) else (axes,)
            if used & set(group):   # a mesh axis may appear once per spec
                axes = None
            else:
                n = 1
                for a in group:
                    n *= shape[a]
                if n <= 1 or dim % n != 0:
                    axes = None
                else:
                    used |= set(group)
        out.append(axes)
    if all(a is None for a in out):
        return x
    return jax.lax.with_sharding_constraint(x, P(*out))


def moe_per_row(
    p: common.Params, x: jax.Array, cfg, pcfg=None
) -> tuple[jax.Array, dict]:
    """Data-local MoE dispatch (§Perf B2): routing, sort and scatter run
    independently per batch row, so the whole dispatch shards cleanly along
    the batch/data axis — no global scatter semantics for GSPMD to resolve
    with giant all-reduces.  Capacity is bounded per row (the per-device
    capacity convention of production MoE systems) instead of globally.
    """

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    xt = x  # (b, s, d)

    logits = jnp.einsum("bsd,de->bse", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (b, s, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    c = min(_round_up(int(cfg.capacity_factor * s * k / e) or 1, 8), s * k)
    token_idx = jnp.repeat(jnp.arange(s), k)

    def dispatch_row(x_row, flat_e):
        order = jnp.argsort(flat_e)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, sorted_e, side="left")
        pos_in_e = jnp.arange(s * k) - first
        slot_sorted = sorted_e * c + pos_in_e
        slot_sorted = jnp.where(pos_in_e < c, slot_sorted, e * c)
        slot = jnp.zeros((s * k,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
        slots = (
            jnp.zeros((e * c, d), x_row.dtype)
            .at[slot]
            .add(x_row[token_idx], mode="drop")
            .reshape(e, c, d)
        )
        return slots, slot

    slots, slot = jax.vmap(dispatch_row)(xt, top_e.reshape(b, s * k))
    slots = _pin(slots, ("data", "experts", None, None), pcfg)   # (b, e, c, d)

    a = common.activation(cfg.act)
    g = jnp.einsum("becd,edf->becf", slots, p["w_gate"])
    u = jnp.einsum("becd,edf->becf", slots, p["w_up"])
    g = _pin(g, ("data", "experts", None, "model"), pcfg)
    u = _pin(u, ("data", "experts", None, "model"), pcfg)
    hidden = a(g) * u
    out_slots = jnp.einsum("becf,efd->becd", hidden, p["w_down"])
    out_slots = _pin(out_slots, ("data", "experts", None, None), pcfg)
    out_flat = out_slots.reshape(b, e * c, d)

    def combine_row(out_row, slot_row, gate_row):
        gathered = jnp.take(out_row, jnp.minimum(slot_row, e * c - 1), axis=0)
        gathered = jnp.where((slot_row < e * c)[:, None], gathered, 0.0)
        weighted = gathered * gate_row[:, None].astype(gathered.dtype)
        return jnp.zeros((s, d), out_row.dtype).at[token_idx].add(weighted)

    y = jax.vmap(combine_row)(out_flat, slot, top_p.reshape(b, s * k))
    y = _pin(y, ("data", None, None), pcfg)

    if cfg.num_shared_experts:
        y = y + mlp(p["shared"], xt, cfg.act)

    me = jnp.mean(probs, axis=(0, 1))                          # (e,)
    ce_frac = jnp.zeros((e,)).at[top_e.reshape(-1)].add(1.0) / (b * s * k)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce_frac),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_fraction": jnp.mean((slot == e * c).astype(jnp.float32)),
    }
    return y, aux


def moe(
    p: common.Params, x: jax.Array, cfg, *, capacity: int | None = None, pcfg=None
) -> tuple[jax.Array, dict]:
    if pcfg is not None and getattr(pcfg, "moe_dispatch", "global") == "per_row":
        return moe_per_row(p, x, cfg, pcfg)
    """Capacity-bounded top-k MoE.

    Dispatch is sort-based (argsort by expert, position-in-expert via
    ``searchsorted`` on the sorted ids — O(T·k log) instead of the O(T·E)
    one-hot cumsum), then a scatter into ``(E, C, D)`` slots, one grouped
    einsum per projection, and a gather-combine.  Overflowing tokens drop
    (capacity factor bounds them); aux losses follow the standard
    load-balance + z-loss recipe.
    """

    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.moe_top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                    # (t, k)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    if capacity is None:
        capacity = _round_up(int(cfg.capacity_factor * t * k / e) or 1, 8)
    c = min(capacity, t * k)

    flat_e = top_e.reshape(-1)                                # (t*k,)
    order = jnp.argsort(flat_e)                               # stable
    sorted_e = flat_e[order]
    # position of each dispatched token within its expert's slot block
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos_in_e = jnp.arange(t * k) - first
    slot_sorted = sorted_e * c + pos_in_e
    slot_sorted = jnp.where(pos_in_e < c, slot_sorted, e * c)  # overflow → dropped
    # slot for the j-th dispatch of token i, in original order
    slot = jnp.zeros((t * k,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))

    token_idx = jnp.repeat(jnp.arange(t), k)
    slots = (
        jnp.zeros((e * c, d), xt.dtype)
        .at[slot]
        .add(xt[token_idx], mode="drop")
        .reshape(e, c, d)
    )
    # NOTE: pinning the dispatched layout here was tried and REFUTED
    # (§Perf B1: global scatter semantics fight the constraints, collective
    # bytes INCREASED 1.6x).  The productive fix is the data-local per-row
    # dispatch above (§Perf B2) — this global path stays paper-plain.

    a = common.activation(cfg.act)
    g = jnp.einsum("ecd,edf->ecf", slots, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", slots, p["w_up"])
    hidden = a(g) * u
    out_slots = jnp.einsum("ecf,efd->ecd", hidden, p["w_down"]).reshape(e * c, d)

    gathered = jnp.take(out_slots, jnp.minimum(slot, e * c - 1), axis=0)
    gathered = jnp.where((slot < e * c)[:, None], gathered, 0.0)
    weighted = gathered * top_p.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.zeros((t, d), xt.dtype).at[token_idx].add(weighted)

    if cfg.num_shared_experts:
        y = y + mlp(p["shared"], xt, cfg.act)

    # aux losses (returned as metrics; weighted by the trainer)
    me = jnp.mean(probs, axis=0)                               # (e,)
    ce_frac = jnp.zeros((e,)).at[flat_e].add(1.0) / (t * k)
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce_frac),
        "router_z_loss": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
        "dropped_fraction": jnp.mean((slot == e * c).astype(jnp.float32)),
    }
    return y.reshape(b, s, d), aux
