"""Encoder-decoder transformer (seamless-m4t backbone).  The speech frontend
is a stub per the task statement: ``input_specs`` provides precomputed frame
embeddings (B, S_enc, D); the model is the transformer backbone with
bidirectional encoder, causal decoder and cross-attention."""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, mlp
from repro.models.attention import KVCache
from repro.models.common import key_iter
from repro.kernels.flash_attention import ops as fa_ops


def _init_enc_layer(key, cfg, dtype):
    ks = key_iter(key)
    return {
        "ln_attn": common.init_rmsnorm(cfg.d_model, dtype),
        "attn": attn.init_attention(next(ks), cfg, dtype),
        "ln_mlp": common.init_rmsnorm(cfg.d_model, dtype),
        "mlp": mlp.init_mlp(next(ks), cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg, dtype):
    ks = key_iter(key)
    p = _init_enc_layer(next(ks), cfg, dtype)
    p["ln_cross"] = common.init_rmsnorm(cfg.d_model, dtype)
    p["cross"] = attn.init_attention(next(ks), cfg, dtype)
    return p


def init_encdec(key, cfg) -> common.Params:
    dtype = common.dtype_of(cfg)
    ks = key_iter(key)
    ekeys = jax.random.split(next(ks), cfg.encoder_layers)
    dkeys = jax.random.split(next(ks), cfg.num_layers)
    return {
        "embed": common.trunc_normal(next(ks), (cfg.padded_vocab, cfg.d_model), 1.0, dtype),
        "enc_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "final_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "encoder": jax.vmap(lambda k: _init_enc_layer(k, cfg, dtype))(ekeys),
        "decoder": jax.vmap(lambda k: _init_dec_layer(k, cfg, dtype))(dkeys),
    }


def _maybe_remat(fn, pcfg):
    if pcfg.remat == "none":
        return fn
    return jax.checkpoint(fn)


def _self_attention(p, h, cfg, pcfg, positions, *, causal):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    q = common.rope(q, positions, theta=cfg.rope_theta)
    k = common.rope(k, positions, theta=cfg.rope_theta)
    out = fa_ops.flash_attention(
        q, k, v, causal=causal, scale=1.0 / math.sqrt(cfg.head_dim),
        impl=getattr(pcfg, "attn_impl", "ref"),
    )
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def _cross_attention(p, h, enc_k, enc_v, cfg):
    """Decoder → encoder attention against precomputed encoder K/V."""

    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), enc_k.astype(jnp.float32))
    s = s / math.sqrt(cfg.head_dim)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, enc_v.astype(jnp.float32)).astype(h.dtype)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def encode(params, frames: jax.Array, cfg, pcfg, mesh=None) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings → encoder states."""

    x = frames.astype(common.dtype_of(cfg))
    positions = jnp.arange(x.shape[1])

    def unit(x, lp):
        x = common.constrain(x, pcfg)
        h = common.rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        x = x + _self_attention(lp["attn"], h, cfg, pcfg, positions, causal=False)
        h = common.rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        return x + mlp.mlp(lp["mlp"], h, cfg.act), ()

    x = common.constrain(x, pcfg)
    x, _ = jax.lax.scan(_maybe_remat(unit, pcfg), x, params["encoder"])
    return common.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _decoder_full(params, enc_out, tokens, cfg, pcfg, *, collect_cache, mesh=None):
    x = params["embed"][tokens]
    x = common.constrain(x, pcfg)
    positions = jnp.arange(tokens.shape[1])

    def unit(x, lp):
        x = common.constrain(x, pcfg)
        # encoder K/V for this layer (recomputed per layer from enc_out)
        ek = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"])
        ev = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"])
        h = common.rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        if collect_cache:
            a, entry = attn.attention_prefill(
                lp["attn"], h, cfg, pcfg, positions=positions, sliding_window=None, mesh=mesh
            )
        else:
            a = attn.attention_full(
                lp["attn"], h, cfg, pcfg, positions=positions, sliding_window=None, mesh=mesh
            )
            entry = None
        x = x + a
        h = common.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        x = x + _cross_attention(lp["cross"], h, ek, ev, cfg)
        h = common.rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        x = x + mlp.mlp(lp["mlp"], h, cfg.act)
        ys = (entry, (ek, ev)) if collect_cache else ()
        return x, ys

    x, ys = jax.lax.scan(_maybe_remat(unit, pcfg), x, params["decoder"])
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, ys


def encdec_loss(params, batch, cfg, pcfg, mesh=None):
    enc_out = encode(params, batch["frames"], cfg, pcfg, mesh)
    tokens = batch["tokens"]
    x, _ = _decoder_full(params, enc_out, tokens, cfg, pcfg, collect_cache=False, mesh=mesh)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    logits = common.constrain(logits, pcfg, logits=True)
    loss = common.cross_entropy(logits[:, :-1], tokens[:, 1:])
    return loss, {"loss": loss}


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EncDecCache:
    self_kv: KVCache       # decoder self-attention (L, B, S_dec, Hk, Dh)
    cross_k: jax.Array     # (L, B, S_enc, Hk, Dh)
    cross_v: jax.Array

    @property
    def pos(self):
        return self.self_kv.pos


def encdec_prefill(params, batch, cfg, pcfg, mesh=None, extra_capacity: int = 0):
    """Encode + teacher-forced decoder prefill over the target prefix."""

    enc_out = encode(params, batch["frames"], cfg, pcfg, mesh)
    tokens = batch["tokens"]
    x, (entries, cross) = _decoder_full(
        params, enc_out, tokens, cfg, pcfg, collect_cache=True, mesh=mesh
    )
    k, v = entries
    if extra_capacity:
        pad = [(0, 0)] * k.ndim
        pad[2] = (0, extra_capacity)
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    dtype = common.dtype_of(cfg)
    pos = jnp.asarray(tokens.shape[1], jnp.int32)
    cache = EncDecCache(
        self_kv=KVCache(
            k=k.astype(dtype), v=v.astype(dtype), k_scale=None, v_scale=None, pos=pos
        ),
        cross_k=cross[0].astype(dtype),
        cross_v=cross[1].astype(dtype),
    )
    logits = jnp.einsum("bsd,vd->bsv", x[:, -1:], params["embed"])
    return logits, cache


def encdec_decode(params, cache: EncDecCache, token, cfg, pcfg, mesh=None):
    x = common.constrain(params["embed"][token], pcfg)
    pos = cache.pos

    def unit(x, xs):
        lp, k_l, v_l, ck, cv = xs
        h = common.rms_norm(x, lp["ln_attn"], cfg.norm_eps)
        a, (k_l, v_l, _, _) = attn.attention_decode(
            lp["attn"], h, k_l, v_l, None, None, pos, cfg, pcfg,
            sliding_window=None, mesh=mesh,
        )
        x = x + a
        h = common.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        x = x + _cross_attention(lp["cross"], h, ck, cv, cfg)
        h = common.rms_norm(x, lp["ln_mlp"], cfg.norm_eps)
        x = x + mlp.mlp(lp["mlp"], h, cfg.act)
        return x, (k_l, v_l)

    xs = (params["decoder"], cache.self_kv.k, cache.self_kv.v, cache.cross_k, cache.cross_v)
    x, (k, v) = jax.lax.scan(unit, x, xs)
    cache = EncDecCache(
        self_kv=KVCache(k=k, v=v, k_scale=None, v_scale=None, pos=pos + 1),
        cross_k=cache.cross_k,
        cross_v=cache.cross_v,
    )
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, cache
