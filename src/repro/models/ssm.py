"""Mamba-2 block (SSD): projections, causal depthwise conv, SSD scan (Pallas
kernel or chunked jnp), gated RMS norm, plus the O(1)-state decode step and
its cache."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import ops as ssd_ops
from repro.models import common
from repro.models.common import dense_init, key_iter


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SSMCache:
    """Per-model stacked SSM cache: ``conv`` (L, B, K-1, conv_dim) rolling
    conv window, ``state`` (L, B, H, P, N) fp32 SSD state, ``pos`` ()."""

    conv: jax.Array
    state: jax.Array
    pos: jax.Array

    @staticmethod
    def init(num_layers, batch, cfg, dtype=jnp.bfloat16):
        conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        return SSMCache(
            conv=jnp.zeros((num_layers, batch, cfg.ssm_conv - 1, conv_dim), dtype),
            state=jnp.zeros(
                (num_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32,
            ),
            pos=jnp.zeros((), jnp.int32),
        )


def init_mamba2(key, cfg, dtype) -> common.Params:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    g, n, nh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * n
    ks = key_iter(key)
    return {
        # in_proj → [z (di), xBC (conv_dim), dt (nh)]
        "w_in": dense_init(next(ks), d, (d, 2 * di + 2 * g * n + nh), dtype),
        "conv_w": dense_init(next(ks), cfg.ssm_conv, (cfg.ssm_conv, conv_dim), dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(
            jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)
        ),  # A = -exp(a_log)
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "out_norm": common.init_rmsnorm(di, dtype),
        "w_out": dense_init(next(ks), di, (di, d), dtype),
    }


def _split_proj(zxbcdt, cfg):
    di = cfg.ssm_d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + di + 2 * gn]
    dt = zxbcdt[..., di + di + 2 * gn :]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, *, history=None):
    """Depthwise causal conv over the sequence.  ``history``: (B, K-1, C)
    left context (decode); returns (out, new_history)."""

    k = conv_w.shape[0]
    b, s, c = xbc.shape
    if history is None:
        history = jnp.zeros((b, k - 1, c), xbc.dtype)
    full = jnp.concatenate([history, xbc], axis=1)             # (B, K-1+S, C)
    out = jnp.zeros((b, s, c), jnp.float32)
    for i in range(k):
        out = out + full[:, i : i + s].astype(jnp.float32) * conv_w[i].astype(jnp.float32)
    out = jax.nn.silu(out + conv_b.astype(jnp.float32)).astype(xbc.dtype)
    new_hist = full[:, -(k - 1) :] if k > 1 else history
    return out, new_hist


def mamba2_full(p, x, cfg, pcfg, *, conv_history=None, return_cache=False):
    """Full-sequence Mamba-2 block.  x: (B, S, D) → (B, S, D)."""

    di = cfg.ssm_d_inner
    g, n, nh, hp = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, new_hist = _causal_conv(xbc, p["conv_w"], p["conv_b"], history=conv_history)
    xs = xbc[..., :di]
    B = xbc[..., di : di + g * n].reshape(*x.shape[:2], g, n)
    C = xbc[..., di + g * n :].reshape(*x.shape[:2], g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, S, nh)
    A = -jnp.exp(p["a_log"])

    xh = xs.reshape(*x.shape[:2], nh, hp)
    chunk = min(128, xh.shape[1])
    y = ssd_ops.ssd_scan(
        xh, dt, A, B, C, chunk=chunk, impl=getattr(pcfg, "ssd_impl", "ref")
    )
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(*x.shape[:2], di).astype(x.dtype)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                        p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    if return_cache:
        # final SSD state for decode continuation
        _, final_state = _final_state(xh, dt, A, B, C)
        return out, (new_hist, final_state)
    return out


def _final_state(xh, dt, A, B, C):
    from repro.kernels.ssd_scan import ref as ssd_ref

    chunk = min(128, xh.shape[1])
    return ssd_ref.ssd_chunked(xh, dt, A, B, C, chunk=chunk)


def mamba2_decode(p, x1, conv_hist, state, cfg, pcfg):
    """Single-token step.  x1 (B, 1, D); conv_hist (B, K-1, C); state
    (B, H, P, N).  Returns (y (B,1,D), (conv_hist, state))."""

    di = cfg.ssm_d_inner
    g, n, nh, hp = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,dk->bsk", x1, p["w_in"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    xbc, conv_hist = _causal_conv(xbc, p["conv_w"], p["conv_b"], history=conv_hist)
    xs = xbc[:, 0, :di]
    B = xbc[:, 0, di : di + g * n].reshape(-1, g, n)
    C = xbc[:, 0, di + g * n :].reshape(-1, g, n)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["a_log"])

    xh = xs.reshape(-1, nh, hp)
    y, state = ssd_ops.ssd_decode_step(state, xh, dt, A, B, C)
    y = y.astype(jnp.float32) + xh.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(-1, 1, di).astype(x1.dtype)
    y = common.rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x1.dtype),
                        p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return out, (conv_hist, state)
