"""Decoder-only LM trunk: dense (qwen/phi4/granite), local-global alternating
with softcaps (gemma-2), MoE (grok-1), MLA+MoE (deepseek-v2) and prefix-LM
VLM (paligemma) — all as scanned layer stacks with train / prefill / decode
entry points."""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import errors
from repro.models import attention as attn
from repro.models import common, mlp
from repro.models.attention import KVCache, MLACache
from repro.models.common import dense_init, key_iter


# ---------------------------------------------------------------------------
# layer units
# ---------------------------------------------------------------------------


def _init_block(key, cfg, dtype, *, kind: str) -> common.Params:
    """One residual block: attention + (dense|moe) MLP with pre-norms
    (+ gemma-2 post-norms)."""

    ks = key_iter(key)
    p: common.Params = {
        "ln_attn": common.init_rmsnorm(cfg.d_model, dtype),
        "ln_mlp": common.init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.post_norms:
        p["ln_attn_post"] = common.init_rmsnorm(cfg.d_model, dtype)
        p["ln_mlp_post"] = common.init_rmsnorm(cfg.d_model, dtype)
    p["attn"] = (
        attn.init_mla(next(ks), cfg, dtype) if cfg.mla else attn.init_attention(next(ks), cfg, dtype)
    )
    if kind == "moe":
        p["mlp"] = mlp.init_moe(next(ks), cfg, dtype)
    else:
        p["mlp"] = mlp.init_mlp(next(ks), cfg.d_model, cfg.d_ff, dtype)
    return p


def _block_full(
    p, x, cfg, pcfg, *, kind, sliding_window, positions, prefix_len, mesh, collect_cache
):
    """Full-sequence block.  Returns (x, cache_entry, aux)."""

    h = common.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    cache_entry = None
    if cfg.mla:
        if collect_cache:
            a, cache_entry = attn.mla_attention_full(
                p["attn"], h, cfg, pcfg, positions=positions, mesh=mesh, return_cache=True
            )
        else:
            a = attn.mla_attention_full(p["attn"], h, cfg, pcfg, positions=positions, mesh=mesh)
    elif collect_cache:
        a, cache_entry = attn.attention_prefill(
            p["attn"], h, cfg, pcfg, positions=positions,
            sliding_window=sliding_window, prefix_len=prefix_len, mesh=mesh,
        )
    else:
        a = attn.attention_full(
            p["attn"], h, cfg, pcfg, positions=positions,
            sliding_window=sliding_window, prefix_len=prefix_len, mesh=mesh,
        )
    if cfg.post_norms:
        a = common.rms_norm(a, p["ln_attn_post"], cfg.norm_eps)
    x = x + a

    h = common.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    aux = {}
    if kind == "moe":
        m, aux = mlp.moe(p["mlp"], h, cfg, pcfg=pcfg)
    else:
        m = mlp.mlp(p["mlp"], h, cfg.act)
    if cfg.post_norms:
        m = common.rms_norm(m, p["ln_mlp_post"], cfg.norm_eps)
    return x + m, cache_entry, aux


def _block_decode(p, x1, cache_slices, pos, cfg, pcfg, *, kind, sliding_window, mesh):
    """Single-token block.  ``cache_slices``: layer slices of the cache
    arrays.  Returns (x1, new_cache_slices)."""

    h = common.rms_norm(x1, p["ln_attn"], cfg.norm_eps)
    if cfg.mla:
        ckv_l, krope_l = cache_slices
        a, new_slices = attn.mla_attention_decode(
            p["attn"], h, ckv_l, krope_l, pos, cfg, pcfg, mesh=mesh
        )
    else:
        k_l, v_l, ks_l, vs_l = cache_slices
        a, new_slices = attn.attention_decode(
            p["attn"], h, k_l, v_l, ks_l, vs_l, pos, cfg, pcfg,
            sliding_window=sliding_window, mesh=mesh,
        )
    if cfg.post_norms:
        a = common.rms_norm(a, p["ln_attn_post"], cfg.norm_eps)
    x1 = x1 + a

    h = common.rms_norm(x1, p["ln_mlp"], cfg.norm_eps)
    if kind == "moe":
        m, _ = mlp.moe(p["mlp"], h, cfg, pcfg=pcfg)
    else:
        m = mlp.mlp(p["mlp"], h, cfg.act)
    if cfg.post_norms:
        m = common.rms_norm(m, p["ln_mlp_post"], cfg.norm_eps)
    return x1 + m, new_slices


# ---------------------------------------------------------------------------
# layer-stack layout
# ---------------------------------------------------------------------------


def _unit_plan(cfg) -> list[tuple[str, str, int | None]]:
    """The sub-layers of one scan unit: list of (name, kind, window)."""

    if cfg.layer_pattern == "local_global":
        return [
            ("local", _mlp_kind(cfg), cfg.sliding_window),
            ("global", _mlp_kind(cfg), None),
        ]
    return [("layer", _mlp_kind(cfg), cfg.sliding_window)]


def _mlp_kind(cfg) -> str:
    return "moe" if cfg.num_experts else "dense"


def _num_units(cfg) -> int:
    n_scanned = cfg.num_layers - cfg.first_dense_layers
    per_unit = len(_unit_plan(cfg))
    assert n_scanned % per_unit == 0, (cfg.num_layers, per_unit)
    return n_scanned // per_unit


def _stacked_init(key, cfg, dtype, n: int, kind: str):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(k, cfg, dtype, kind=kind))(keys)


def init_lm(key, cfg) -> common.Params:
    dtype = common.dtype_of(cfg)
    ks = key_iter(key)
    params: common.Params = {
        "embed": common.trunc_normal(next(ks), (cfg.padded_vocab, cfg.d_model), 1.0, dtype),
        "final_norm": common.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            next(ks), cfg.d_model, (cfg.d_model, cfg.padded_vocab), dtype
        )
    n_units = _num_units(cfg)
    units: common.Params = {}
    for name, kind, _ in _unit_plan(cfg):
        units[name] = _stacked_init(next(ks), cfg, dtype, n_units, kind)
    params["layers"] = units
    for i in range(cfg.first_dense_layers):
        params[f"dense_{i}"] = _init_block(next(ks), cfg, dtype, kind="dense")
    if cfg.family == "vlm":
        # multimodal projector (SigLIP stub dim 1152 → d_model)
        params["mm_proj"] = dense_init(next(ks), 1152, (1152, cfg.d_model), dtype)
    return params


def _maybe_remat(fn, pcfg):
    if pcfg.remat == "none":
        return fn
    if pcfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed(params, tokens, cfg):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def _head(params, x, cfg, pcfg=None):
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    sub = "bsd,vd->bsv" if cfg.tie_embeddings else "bsd,dv->bsv"
    logits = jnp.einsum(sub, x, w)
    if pcfg is not None:
        logits = common.constrain(logits, pcfg, logits=True)
    return logits


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def _prepare_inputs(params, batch: dict, cfg):
    """tokens (+ image embeds for VLM) → (x, positions, prefix_len)."""

    tokens = batch["tokens"]
    x = _embed(params, tokens, cfg)
    prefix_len = None
    if cfg.family == "vlm":
        img = jnp.einsum("bnf,fd->bnd", batch["image_embeds"].astype(x.dtype),
                         params["mm_proj"])
        x = jnp.concatenate([img, x], axis=1)
        prefix_len = cfg.num_image_tokens if cfg.prefix_lm else None
    positions = jnp.arange(x.shape[1])
    return x, positions, prefix_len


def lm_forward(params, batch: dict, cfg, pcfg, mesh=None) -> tuple[jax.Array, dict]:
    """Full-sequence forward → (logits, aux metrics)."""

    x, positions, prefix_len = _prepare_inputs(params, batch, cfg)
    x = common.constrain(x, pcfg)
    aux_acc = {"load_balance_loss": 0.0, "router_z_loss": 0.0, "dropped_fraction": 0.0}

    for i in range(cfg.first_dense_layers):
        x, _, _ = _block_full(
            params[f"dense_{i}"], x, cfg, pcfg, kind="dense", sliding_window=None,
            positions=positions, prefix_len=prefix_len, mesh=mesh, collect_cache=False,
        )

    plan = _unit_plan(cfg)

    def unit(x, unit_params):
        aux_l = {}
        x = common.constrain(x, pcfg)
        for name, kind, window in plan:
            x, _, aux = _block_full(
                unit_params[name], x, cfg, pcfg, kind=kind, sliding_window=window,
                positions=positions, prefix_len=prefix_len, mesh=mesh, collect_cache=False,
            )
            x = common.constrain(x, pcfg)
            for k_, v_ in aux.items():
                aux_l[k_] = aux_l.get(k_, 0.0) + v_
        return x, aux_l

    x, aux_layers = jax.lax.scan(_maybe_remat(unit, pcfg), x, params["layers"])
    if aux_layers:
        for k_ in aux_acc:
            if k_ in aux_layers:
                aux_acc[k_] = jnp.sum(aux_layers[k_])
    logits = _head(params, x, cfg, pcfg)
    return logits, aux_acc


def lm_loss(params, batch: dict, cfg, pcfg, mesh=None) -> tuple[jax.Array, dict]:
    logits, aux = lm_forward(params, batch, cfg, pcfg, mesh)
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        # labels cover only the text region (image prefix contributes no loss)
        logits = logits[:, cfg.num_image_tokens :]
    loss = common.cross_entropy(
        logits[:, :-1], tokens[:, 1:], softcap_val=cfg.final_logit_softcap
    )
    if cfg.num_experts:
        loss = loss + 1e-2 * aux["load_balance_loss"] + 1e-3 * aux["router_z_loss"]
    metrics = {"loss": loss, **{k: jnp.asarray(v) for k, v in aux.items()}}
    return loss, metrics


# -- pipeline-parallel stage decomposition (MPI 4.0 ch. 8 fabric) -------------


def pipeline_stage_fns(cfg, pcfg):
    """Decompose the LM into the three pieces the pipeline schedule
    (:func:`repro.core.overlap.pipeline_spmd`) streams microbatches through:
    ``embed_mb`` (stage-0 injection), ``apply_units`` (each stage's local
    slice of the scanned layer stack), ``loss_mb`` (last-stage head + CE).

    Meant to run *inside* a ``shard_map`` region whose mesh carries the
    pipeline ``stage`` axis, so the model-internal sharding constraints are
    neutralised (``data_axes=()`` — constraints are the ambient-mesh GSPMD
    idiom; inside shard_map the partitioning is explicit).  Requires the
    fully-scanned layout (``first_dense_layers == 0``) — the stage split is
    a slice of the stacked ``params['layers']`` leading dim.
    """

    errors.check(
        cfg.first_dense_layers == 0,
        errors.ErrorClass.ERR_TOPOLOGY,
        "pipeline stages require a fully-scanned layer stack "
        f"(first_dense_layers={cfg.first_dense_layers})",
    )
    errors.check(
        cfg.family in ("dense", "moe"),
        errors.ErrorClass.ERR_TOPOLOGY,
        f"pipeline stage decomposition supports dense/moe LMs, not {cfg.family!r}",
    )
    local_pcfg = dataclasses.replace(pcfg, data_axes=())
    plan = _unit_plan(cfg)

    def embed_mb(params, tokens_mb):
        """(mb, T) tokens → (mb, T, D) stage-0 activations."""

        return _embed(params, tokens_mb, cfg)

    def apply_units(layers_local, x):
        """Apply this stage's local scanned units to the in-flight
        activation (positions are full-sequence — microbatches split the
        batch dim, never the sequence)."""

        positions = jnp.arange(x.shape[1])

        def unit(x, unit_params):
            for name, kind, window in plan:
                x, _, _ = _block_full(
                    unit_params[name], x, cfg, local_pcfg, kind=kind,
                    sliding_window=window, positions=positions, prefix_len=None,
                    mesh=None, collect_cache=False,
                )
            return x, {}

        x, _ = jax.lax.scan(_maybe_remat(unit, local_pcfg), x, layers_local)
        return x

    def loss_mb(params, x, tokens_mb):
        """Last-stage head + token-mean CE for one microbatch."""

        logits = _head(params, x, cfg, None)
        return common.cross_entropy(
            logits[:, :-1], tokens_mb[:, 1:], softcap_val=cfg.final_logit_softcap
        )

    return embed_mb, apply_units, loss_mb


# -- caches -------------------------------------------------------------------


def init_cache(cfg, pcfg, batch: int, length: int) -> dict[str, Any]:
    """Cache pytree for decode: one entry per unit sub-layer name."""

    n_units = _num_units(cfg)
    dtype = common.dtype_of(cfg)
    quant = pcfg.kv_cache_dtype == "int8"
    caches: dict[str, Any] = {}
    if cfg.mla:
        caches["layer"] = MLACache.init(
            n_units, batch, length, cfg.kv_lora, cfg.rope_head_dim, dtype
        )
        for i in range(cfg.first_dense_layers):
            caches[f"dense_{i}"] = MLACache.init(
                1, batch, length, cfg.kv_lora, cfg.rope_head_dim, dtype
            )
        return caches
    for name, _, window in _unit_plan(cfg):
        cap = min(length, window) if window else length
        caches[name] = KVCache.init(
            n_units, batch, cap, cfg.num_kv_heads, cfg.head_dim, dtype=dtype, quantized=quant
        )
    for i in range(cfg.first_dense_layers):
        caches[f"dense_{i}"] = KVCache.init(
            1, batch, length, cfg.num_kv_heads, cfg.head_dim, dtype=dtype, quantized=quant
        )
    return caches


def _cache_xs(cache):
    if isinstance(cache, MLACache):
        return (cache.ckv, cache.k_rope)
    return (cache.k, cache.v, cache.k_scale, cache.v_scale)


def _cache_rebuild(cache, new_xs, pos):
    if isinstance(cache, MLACache):
        return MLACache(ckv=new_xs[0], k_rope=new_xs[1], pos=pos)
    return KVCache(k=new_xs[0], v=new_xs[1], k_scale=new_xs[2], v_scale=new_xs[3], pos=pos)


def lm_prefill(params, batch: dict, cfg, pcfg, mesh=None, extra_capacity: int = 0):
    """Prefill: full forward that also builds the cache.  Returns
    (last-token logits, cache dict)."""

    x, positions, prefix_len = _prepare_inputs(params, batch, cfg)
    x = common.constrain(x, pcfg)
    seq = x.shape[1]
    caches: dict[str, Any] = {}

    for i in range(cfg.first_dense_layers):
        x, entry, _ = _block_full(
            params[f"dense_{i}"], x, cfg, pcfg, kind="dense", sliding_window=None,
            positions=positions, prefix_len=prefix_len, mesh=mesh, collect_cache=True,
        )
        caches[f"dense_{i}"] = _entry_to_cache(
            entry, cfg, pcfg, stack=True, extra=extra_capacity
        )

    plan = _unit_plan(cfg)

    def unit(x, unit_params):
        entries = {}
        x = common.constrain(x, pcfg)
        for name, kind, window in plan:
            x, entry, _ = _block_full(
                unit_params[name], x, cfg, pcfg, kind=kind, sliding_window=window,
                positions=positions, prefix_len=prefix_len, mesh=mesh, collect_cache=True,
            )
            x = common.constrain(x, pcfg)
            entries[name] = entry
        return x, entries

    x, entries = jax.lax.scan(_maybe_remat(unit, pcfg), x, params["layers"])
    for name, _, window in plan:
        # windowed layers use a fixed ring buffer — no headroom needed
        extra = 0 if (window is not None and seq > window) else extra_capacity
        caches[name] = _entry_to_cache(entries[name], cfg, pcfg, stack=False, extra=extra)
    pos = jnp.asarray(seq, jnp.int32)
    caches = {k_: dataclasses.replace(v, pos=pos) for k_, v in caches.items()}
    logits = _head(params, x[:, -1:], cfg, pcfg)
    if cfg.final_logit_softcap:
        logits = common.softcap(logits, cfg.final_logit_softcap)
    return logits, caches


def _pad_seq(arr, extra: int):
    """Decode headroom: grow the cache's sequence axis (axis 2 of the
    stacked layout) by ``extra`` zero slots so decode never writes past
    capacity (dynamic_update_slice clamps silently otherwise)."""

    if not extra:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[2] = (0, extra)
    return jnp.pad(arr, widths)


def _entry_to_cache(entry, cfg, pcfg, *, stack: bool, extra: int = 0):
    quant = pcfg.kv_cache_dtype == "int8"
    dtype = common.dtype_of(cfg)
    pos = jnp.zeros((), jnp.int32)
    if cfg.mla:
        ckv, krope = entry
        if stack:
            ckv, krope = ckv[None], krope[None]
        ckv, krope = _pad_seq(ckv, extra), _pad_seq(krope, extra)
        return MLACache(ckv=ckv.astype(dtype), k_rope=krope.astype(dtype), pos=pos)
    k, v = entry
    if stack:
        k, v = k[None], v[None]
    k, v = _pad_seq(k, extra), _pad_seq(v, extra)
    if quant:
        kq, ksc = attn._quantize_kv(k)
        vq, vsc = attn._quantize_kv(v)
        return KVCache(k=kq, v=vq, k_scale=ksc, v_scale=vsc, pos=pos)
    return KVCache(k=k.astype(dtype), v=v.astype(dtype), k_scale=None, v_scale=None, pos=pos)


def lm_decode(params, caches: dict, token: jax.Array, cfg, pcfg, mesh=None):
    """One decode step.  token: (B, 1) int32.  Returns (logits, caches)."""

    pos = next(iter(caches.values())).pos
    x = _embed(params, token, cfg)
    x = common.constrain(x, pcfg)
    if cfg.family == "vlm":
        pass  # image prefix already lives in the cache

    for i in range(cfg.first_dense_layers):
        c = caches[f"dense_{i}"]
        slices = tuple(None if a is None else a[0] for a in _cache_xs(c))
        x, new_slices = _block_decode(
            params[f"dense_{i}"], x, slices, pos, cfg, pcfg,
            kind="dense", sliding_window=None, mesh=mesh,
        )
        new_xs = tuple(
            None if old is None else new[None]
            for old, new in zip(_cache_xs(c), _pad_none(new_slices, _cache_xs(c)))
        )
        caches[f"dense_{i}"] = _cache_rebuild(c, new_xs, pos + 1)

    plan = _unit_plan(cfg)

    def unit(x, xs):
        unit_params = xs["params"]
        new_entries = {}
        x = common.constrain(x, pcfg)
        for name, kind, window in plan:
            slices = xs[name]
            x, new_slices = _block_decode(
                unit_params[name], x, slices, pos, cfg, pcfg,
                kind=kind, sliding_window=window, mesh=mesh,
            )
            new_entries[name] = new_slices
        return x, new_entries

    xs = {"params": params["layers"]}
    for name, _, _w in plan:
        xs[name] = _cache_xs(caches[name])
    x, new_entries = jax.lax.scan(unit, x, xs)
    for name, _, _w in plan:
        c = caches[name]
        new_xs = _pad_none(new_entries[name], _cache_xs(c))
        caches[name] = _cache_rebuild(c, new_xs, pos + 1)
    logits = _head(params, x, cfg, pcfg)
    if cfg.final_logit_softcap:
        logits = common.softcap(logits, cfg.final_logit_softcap)
    return logits, caches


def _pad_none(new_slices, template):
    out = []
    it = iter(new_slices)
    for t in template:
        if t is None:
            out.append(None)
            # consume the matching None from new_slices
            n = next(it)
            assert n is None
        else:
            out.append(next(it))
    return tuple(out)
