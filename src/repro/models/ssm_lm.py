"""Attention-free Mamba-2 LM (mamba2-2.7b) and the Mamba-2 + shared-attention
hybrid (zamba2-7b)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import common, mlp, ssm
from repro.models.attention import KVCache
from repro.models.common import key_iter
from repro.models.ssm import SSMCache


# ---------------------------------------------------------------------------
# pure SSM LM
# ---------------------------------------------------------------------------


def _init_ssm_layer(key, cfg, dtype):
    return {
        "ln": common.init_rmsnorm(cfg.d_model, dtype),
        "mixer": ssm.init_mamba2(key, cfg, dtype),
    }


def init_ssm_lm(key, cfg) -> common.Params:
    dtype = common.dtype_of(cfg)
    ks = key_iter(key)
    keys = jax.random.split(next(ks), cfg.num_layers)
    return {
        "embed": common.trunc_normal(next(ks), (cfg.padded_vocab, cfg.d_model), 1.0, dtype),
        "final_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "layers": jax.vmap(lambda k: _init_ssm_layer(k, cfg, dtype))(keys),
    }


def _ssm_layer_full(lp, x, cfg, pcfg, *, collect_cache=False):
    h = common.rms_norm(x, lp["ln"], cfg.norm_eps)
    if collect_cache:
        y, cache = ssm.mamba2_full(lp["mixer"], h, cfg, pcfg, return_cache=True)
        return x + y, cache
    return x + ssm.mamba2_full(lp["mixer"], h, cfg, pcfg), None


def _maybe_remat(fn, pcfg):
    if pcfg.remat == "none":
        return fn
    if pcfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def ssm_lm_loss(params, batch, cfg, pcfg, mesh=None):
    tokens = batch["tokens"]
    x = params["embed"][tokens]

    def unit(x, lp):
        x = common.constrain(x, pcfg)
        x, _ = _ssm_layer_full(lp, x, cfg, pcfg)
        return x, ()

    x = common.constrain(x, pcfg)
    x, _ = jax.lax.scan(_maybe_remat(unit, pcfg), x, params["layers"])
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    logits = common.constrain(logits, pcfg, logits=True)
    loss = common.cross_entropy(logits[:, :-1], tokens[:, 1:])
    return loss, {"loss": loss}


def ssm_lm_prefill(params, batch, cfg, pcfg, mesh=None, extra_capacity: int = 0):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = common.constrain(params["embed"][tokens], pcfg)

    def unit(x, lp):
        x = common.constrain(x, pcfg)
        x, cache = _ssm_layer_full(lp, x, cfg, pcfg, collect_cache=True)
        return x, cache

    x = common.constrain(x, pcfg)
    x, caches = jax.lax.scan(_maybe_remat(unit, pcfg), x, params["layers"])
    conv_hist, state = caches
    cache = SSMCache(
        conv=conv_hist.astype(common.dtype_of(cfg)),
        state=state,
        pos=jnp.asarray(s, jnp.int32),
    )
    x = common.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, cache


def ssm_lm_decode(params, cache: SSMCache, token, cfg, pcfg, mesh=None):
    x = common.constrain(params["embed"][token], pcfg)

    def unit(x, xs):
        lp, conv_l, state_l = xs
        h = common.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, (conv_l, state_l) = ssm.mamba2_decode(lp["mixer"], h, conv_l, state_l, cfg, pcfg)
        return x + y, (conv_l, state_l)

    x, (conv, state) = jax.lax.scan(unit, x, (params["layers"], cache.conv, cache.state))
    cache = SSMCache(conv=conv, state=state, pos=cache.pos + 1)
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, cache


# ---------------------------------------------------------------------------
# hybrid (zamba2): Mamba-2 backbone + one shared attention block applied
# every `attn_every` layers (weights shared across applications)
# ---------------------------------------------------------------------------


def _hybrid_split(cfg) -> tuple[int, int]:
    groups = cfg.num_layers // cfg.attn_every
    rest = cfg.num_layers - groups * cfg.attn_every
    return groups, rest


def init_hybrid_lm(key, cfg) -> common.Params:
    dtype = common.dtype_of(cfg)
    ks = key_iter(key)
    groups, rest = _hybrid_split(cfg)
    params: common.Params = {
        "embed": common.trunc_normal(next(ks), (cfg.padded_vocab, cfg.d_model), 1.0, dtype),
        "final_norm": common.init_rmsnorm(cfg.d_model, dtype),
        "ssm_layers": jax.vmap(lambda k: _init_ssm_layer(k, cfg, dtype))(
            jax.random.split(next(ks), groups * cfg.attn_every)
        ),
        "shared_attn": {
            "ln_attn": common.init_rmsnorm(cfg.d_model, dtype),
            "attn": attn.init_attention(next(ks), cfg, dtype),
            "ln_mlp": common.init_rmsnorm(cfg.d_model, dtype),
            "mlp": mlp.init_mlp(next(ks), cfg.d_model, cfg.d_ff, dtype),
        },
    }
    # reshape stacked ssm layers into (groups, per_group) scan-of-scan layout
    params["ssm_layers"] = jax.tree.map(
        lambda a: a.reshape((groups, cfg.attn_every) + a.shape[1:]), params["ssm_layers"]
    )
    if rest:
        rkeys = jax.random.split(next(ks), rest)
        params["ssm_tail"] = jax.vmap(lambda k: _init_ssm_layer(k, cfg, dtype))(rkeys)
    return params


def _shared_attn_full(sp, x, cfg, pcfg, *, positions, mesh, collect_cache):
    h = common.rms_norm(x, sp["ln_attn"], cfg.norm_eps)
    if collect_cache:
        a, entry = attn.attention_prefill(
            sp["attn"], h, cfg, pcfg, positions=positions, sliding_window=None, mesh=mesh
        )
    else:
        a = attn.attention_full(
            sp["attn"], h, cfg, pcfg, positions=positions, sliding_window=None, mesh=mesh
        )
        entry = None
    x = x + a
    h = common.rms_norm(x, sp["ln_mlp"], cfg.norm_eps)
    return x + mlp.mlp(sp["mlp"], h, cfg.act), entry


def hybrid_lm_loss(params, batch, cfg, pcfg, mesh=None):
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])
    groups, rest = _hybrid_split(cfg)

    def group_unit(x, glp):
        x = common.constrain(x, pcfg)
        x, _ = _shared_attn_full(
            params["shared_attn"], x, cfg, pcfg, positions=positions, mesh=mesh,
            collect_cache=False,
        )

        def inner(x, lp):
            x, _ = _ssm_layer_full(lp, x, cfg, pcfg)
            return x, ()

        x, _ = jax.lax.scan(inner, x, glp)
        return x, ()

    x, _ = jax.lax.scan(_maybe_remat(group_unit, pcfg), x, params["ssm_layers"])
    if rest:
        def inner_tail(x, lp):
            x, _ = _ssm_layer_full(lp, x, cfg, pcfg)
            return x, ()

        x, _ = jax.lax.scan(_maybe_remat(inner_tail, pcfg), x, params["ssm_tail"])
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    logits = common.constrain(logits, pcfg, logits=True)
    loss = common.cross_entropy(logits[:, :-1], tokens[:, 1:])
    return loss, {"loss": loss}


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class HybridCache:
    attn: KVCache          # (groups, B, S, Hk, Dh)
    ssm: SSMCache          # (groups*per + rest, ...)

    @property
    def pos(self):
        return self.ssm.pos


def init_hybrid_cache(cfg, pcfg, batch: int, length: int) -> HybridCache:
    groups, rest = _hybrid_split(cfg)
    return HybridCache(
        attn=KVCache.init(
            groups, batch, length, cfg.num_kv_heads, cfg.head_dim,
            dtype=common.dtype_of(cfg), quantized=pcfg.kv_cache_dtype == "int8",
        ),
        ssm=SSMCache.init(cfg.num_layers, batch, cfg, common.dtype_of(cfg)),
    )


def hybrid_lm_prefill(params, batch, cfg, pcfg, mesh=None, extra_capacity: int = 0):
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = common.constrain(params["embed"][tokens], pcfg)
    positions = jnp.arange(s)
    groups, rest = _hybrid_split(cfg)

    def group_unit(x, glp):
        x = common.constrain(x, pcfg)
        x, entry = _shared_attn_full(
            params["shared_attn"], x, cfg, pcfg, positions=positions, mesh=mesh,
            collect_cache=True,
        )

        def inner(x, lp):
            x, cache = _ssm_layer_full(lp, x, cfg, pcfg, collect_cache=True)
            return x, cache

        x, ssm_caches = jax.lax.scan(inner, x, glp)
        return x, (entry, ssm_caches)

    x, (attn_entries, ssm_caches) = jax.lax.scan(
        _maybe_remat(group_unit, pcfg), x, params["ssm_layers"]
    )
    conv_hist, state = ssm_caches  # (groups, per, B, ...) — flatten groups
    conv_hist = conv_hist.reshape((-1,) + conv_hist.shape[2:])
    state = state.reshape((-1,) + state.shape[2:])
    if rest:
        def inner_tail(x, lp):
            x, cache = _ssm_layer_full(lp, x, cfg, pcfg, collect_cache=True)
            return x, cache

        x, tail_caches = jax.lax.scan(inner_tail, x, params["ssm_tail"])
        conv_hist = jnp.concatenate([conv_hist, tail_caches[0]], axis=0)
        state = jnp.concatenate([state, tail_caches[1]], axis=0)

    pos = jnp.asarray(s, jnp.int32)
    quant = pcfg.kv_cache_dtype == "int8"
    k, v = attn_entries
    if extra_capacity:
        pad = [(0, 0)] * k.ndim
        pad[2] = (0, extra_capacity)
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    if quant:
        kq, ksc = attn._quantize_kv(k)
        vq, vsc = attn._quantize_kv(v)
        kv = KVCache(k=kq, v=vq, k_scale=ksc, v_scale=vsc, pos=pos)
    else:
        dtype = common.dtype_of(cfg)
        kv = KVCache(k=k.astype(dtype), v=v.astype(dtype), k_scale=None, v_scale=None, pos=pos)
    cache = HybridCache(
        attn=kv,
        ssm=SSMCache(conv=conv_hist.astype(common.dtype_of(cfg)), state=state, pos=pos),
    )
    x = common.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, cache


def hybrid_lm_decode(params, cache: HybridCache, token, cfg, pcfg, mesh=None):
    x = common.constrain(params["embed"][token], pcfg)
    pos = cache.pos
    groups, rest = _hybrid_split(cfg)
    per = cfg.attn_every

    ssm_conv_g = cache.ssm.conv[: groups * per].reshape((groups, per) + cache.ssm.conv.shape[1:])
    ssm_state_g = cache.ssm.state[: groups * per].reshape(
        (groups, per) + cache.ssm.state.shape[1:]
    )

    def group_unit(x, xs):
        glp, k_l, v_l, ks_l, vs_l, conv_g, state_g = xs
        h = common.rms_norm(x, params["shared_attn"]["ln_attn"], cfg.norm_eps)
        a, (k_l, v_l, ks_l, vs_l) = attn.attention_decode(
            params["shared_attn"]["attn"], h, k_l, v_l, ks_l, vs_l, pos, cfg, pcfg,
            sliding_window=None, mesh=mesh,
        )
        x = x + a
        h = common.rms_norm(x, params["shared_attn"]["ln_mlp"], cfg.norm_eps)
        x = x + mlp.mlp(params["shared_attn"]["mlp"], h, cfg.act)

        def inner(x, ixs):
            lp, conv_l, state_l = ixs
            h = common.rms_norm(x, lp["ln"], cfg.norm_eps)
            y, (conv_l, state_l) = ssm.mamba2_decode(lp["mixer"], h, conv_l, state_l, cfg, pcfg)
            return x + y, (conv_l, state_l)

        x, (conv_g, state_g) = jax.lax.scan(inner, x, (glp, conv_g, state_g))
        return x, (k_l, v_l, ks_l, vs_l, conv_g, state_g)

    xs = (
        params["ssm_layers"],
        cache.attn.k,
        cache.attn.v,
        cache.attn.k_scale,
        cache.attn.v_scale,
        ssm_conv_g,
        ssm_state_g,
    )
    x, (k, v, ksc, vsc, conv_g, state_g) = jax.lax.scan(group_unit, x, xs)
    conv = conv_g.reshape((-1,) + conv_g.shape[2:])
    state = state_g.reshape((-1,) + state_g.shape[2:])
    if rest:
        def inner_tail(x, ixs):
            lp, conv_l, state_l = ixs
            h = common.rms_norm(x, lp["ln"], cfg.norm_eps)
            y, (conv_l, state_l) = ssm.mamba2_decode(lp["mixer"], h, conv_l, state_l, cfg, pcfg)
            return x + y, (conv_l, state_l)

        x, (conv_t, state_t) = jax.lax.scan(
            inner_tail, x, (params["ssm_tail"], cache.ssm.conv[groups * per :],
                            cache.ssm.state[groups * per :])
        )
        conv = jnp.concatenate([conv, conv_t], axis=0)
        state = jnp.concatenate([state, state_t], axis=0)

    new_cache = HybridCache(
        attn=KVCache(k=k, v=v, k_scale=ksc, v_scale=vsc, pos=pos + 1),
        ssm=SSMCache(conv=conv, state=state, pos=pos + 1),
    )
    x = common.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, new_cache
