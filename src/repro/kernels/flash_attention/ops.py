"""Jit'd public wrapper for flash attention.

``flash_attention`` dispatches between the Pallas kernel (TPU target;
``interpret=True`` validation on CPU) and the jnp reference, and installs a
``custom_vjp`` whose backward pass recomputes through the reference — the
standard recompute-backward for memory-bound attention (no O(S²) residuals).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import ref as _ref


@functools.partial(
    jax.custom_vjp,
    nondiff_argnums=(3, 4, 5, 6, 7, 8),
)
def _flash(q, k, v, causal, sliding_window, prefix_len, logit_softcap, scale, impl):
    return _forward(q, k, v, causal, sliding_window, prefix_len, logit_softcap, scale, impl)


def _forward(q, k, v, causal, sliding_window, prefix_len, logit_softcap, scale, impl):
    if impl == "pallas":
        return _kernel.flash_attention_fwd(
            q,
            k,
            v,
            causal=causal,
            sliding_window=sliding_window,
            prefix_len=prefix_len,
            logit_softcap=logit_softcap,
            scale=scale,
            interpret=True,
        )
    if impl == "pallas_tpu":
        return _kernel.flash_attention_fwd(
            q,
            k,
            v,
            causal=causal,
            sliding_window=sliding_window,
            prefix_len=prefix_len,
            logit_softcap=logit_softcap,
            scale=scale,
            interpret=False,
        )
    return _ref.mha(
        q,
        k,
        v,
        causal=causal,
        sliding_window=sliding_window,
        prefix_len=prefix_len,
        logit_softcap=logit_softcap,
        scale=scale,
    )


def _fwd(q, k, v, causal, sliding_window, prefix_len, logit_softcap, scale, impl):
    out = _forward(q, k, v, causal, sliding_window, prefix_len, logit_softcap, scale, impl)
    return out, (q, k, v)


def _bwd(causal, sliding_window, prefix_len, logit_softcap, scale, impl, res, g):
    q, k, v = res

    def recompute(q, k, v):
        return _ref.mha(
            q,
            k,
            v,
            causal=causal,
            sliding_window=sliding_window,
            prefix_len=prefix_len,
            logit_softcap=logit_softcap,
            scale=scale,
        )

    _, vjp = jax.vjp(recompute, q, k, v)
    return vjp(g)


_flash.defvjp(_fwd, _bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int | None = None,
    prefix_len: int | None = None,
    logit_softcap: float | None = None,
    scale: float | None = None,
    impl: str = "ref",
    q_block_axis: str | None = None,
) -> jax.Array:
    """Public API.  ``impl``:
    'ref'      — O(S²) pure jnp (small shapes, oracle);
    'chunked'  — online-softmax jnp, O(S·block) memory (production XLA path,
                 differentiated directly: the scan already avoids S² residuals);
    'pallas'   — interpret-mode kernel (CPU validation);
    'pallas_tpu' — the TPU kernel."""

    if impl == "chunked":
        return _ref.chunked_mha(
            q, k, v, causal=causal, sliding_window=sliding_window,
            prefix_len=prefix_len, logit_softcap=logit_softcap, scale=scale,
            q_block_axis=q_block_axis,
        )
    return _flash(q, k, v, causal, sliding_window, prefix_len, logit_softcap, scale, impl)
