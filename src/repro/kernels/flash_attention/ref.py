"""Pure-jnp oracle for the flash-attention kernel.

Numerically exact (fp32 softmax) reference used by the kernel's allclose
tests and as the recompute target of the custom-VJP backward pass.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # finite mask value: keeps fully-masked rows NaN-free


def attention_mask(
    q_len: int,
    k_len: int,
    *,
    causal: bool = True,
    sliding_window: int | None = None,
    prefix_len: int | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """(q_len, k_len) boolean mask. ``q_offset`` positions queries globally
    (used for chunked decodes and ring steps)."""

    q_pos = q_offset + jnp.arange(q_len)[:, None]
    k_pos = jnp.arange(k_len)[None, :]
    mask = jnp.ones((q_len, k_len), bool)
    if causal:
        mask = q_pos >= k_pos
    if sliding_window is not None:
        mask = mask & (q_pos - k_pos < sliding_window)
    if prefix_len is not None:
        mask = mask | (k_pos < prefix_len)
    return mask


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int | None = None,
    prefix_len: int | None = None,
    logit_softcap: float | None = None,
    scale: float | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Reference attention.  q: (b, sq, h, d); k/v: (b, sk, hk, d) with
    ``h % hk == 0`` (GQA).  Returns (b, sq, h, d) in q's dtype."""

    b, sq, h, d = q.shape
    hk = k.shape[2]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if hk != h:
        k = jnp.repeat(k, h // hk, axis=2)
        v = jnp.repeat(v, h // hk, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    mask = attention_mask(
        sq,
        k.shape[1],
        causal=causal,
        sliding_window=sliding_window,
        prefix_len=prefix_len,
        q_offset=q_offset,
    )
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def chunked_mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int | None = None,
    prefix_len: int | None = None,
    logit_softcap: float | None = None,
    scale: float | None = None,
    q_block: int = 1024,
    k_block: int = 1024,
    q_block_axis: str | None = None,
) -> jax.Array:
    """Memory-efficient (online-softmax) attention on the XLA path — the
    jnp twin of the Pallas kernel's schedule: never materialises the
    (S, S) score matrix, O(S·block) live memory instead of O(S²).

    Query blocks are vmapped (parallel); the KV walk is a scan.  With
    ``q_block_axis`` set to a mesh axis name, the query-block dim is
    sharding-constrained onto that axis — sequence parallelism for
    attention, the lever when heads do not divide the model axis (§Perf A4).

    This is what the production prefill cells compile (the §Perf memory-term
    lever); the Pallas kernel remains the TPU-target implementation and this
    the shape-compatible oracle-consistent fallback.
    """

    b, sq, h, d = q.shape
    sk = k.shape[1]
    hk = k.shape[2]
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    if sq % q_block or sk % k_block:
        # fall back for ragged shapes (tests, smoke models)
        return mha(q, k, v, causal=causal, sliding_window=sliding_window,
                   prefix_len=prefix_len, logit_softcap=logit_softcap, scale=scale)
    group = h // hk

    qf = q.astype(jnp.float32).reshape(b, sq // q_block, q_block, h, d)
    kf = k.astype(jnp.float32).reshape(b, sk // k_block, k_block, hk, d)
    vf = v.astype(jnp.float32).reshape(b, sk // k_block, k_block, hk, d)

    def one_q_block(qb, qi):
        # qb: (b, q_block, h, d)

        def kv_step(carry, kv):
            o_acc, m, l = carry
            kb, vb, ki = kv                               # (b, k_block, hk, d)
            kbh = jnp.repeat(kb, group, axis=2) if group > 1 else kb
            vbh = jnp.repeat(vb, group, axis=2) if group > 1 else vb
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kbh) * scale
            if logit_softcap is not None:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            # global offsets for this (q, k) block pair
            q_pos = qi * q_block + jnp.arange(q_block)[:, None]
            k_pos = ki * k_block + jnp.arange(k_block)[None, :]
            mask = jnp.ones((q_block, k_block), bool)
            if causal:
                mask = q_pos >= k_pos
            if sliding_window is not None:
                mask = mask & (q_pos - k_pos < sliding_window)
            if prefix_len is not None:
                mask = mask | (k_pos < prefix_len)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + jnp.sum(p, axis=-1)
            o_new = o_acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vbh)
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((b, h, q_block, d), jnp.float32)
        m0 = jnp.full((b, h, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_block), jnp.float32)
        ks = jnp.arange(sk // k_block)
        (o, m, l), _ = jax.lax.scan(
            kv_step, (o0, m0, l0),
            (kf.transpose(1, 0, 2, 3, 4), vf.transpose(1, 0, 2, 3, 4), ks),
        )
        o = o / jnp.maximum(l, 1e-30)[..., None]
        return o.transpose(0, 2, 1, 3)                    # (b, q_block, h, d)

    qi = jnp.arange(sq // q_block)
    q_blocks = qf.transpose(1, 0, 2, 3, 4)               # (nq, b, q_block, h, d)
    if q_block_axis is not None:
        from jax.sharding import PartitionSpec as P
        from repro.models.common import _ambient_mesh_shape

        mesh_shape = _ambient_mesh_shape()
        n = mesh_shape.get(q_block_axis, 1)
        if n > 1 and q_blocks.shape[0] % n == 0:
            q_blocks = jax.lax.with_sharding_constraint(
                q_blocks, P(q_block_axis, None, None, None, None)
            )
    o_blocks = jax.vmap(one_q_block)(q_blocks, qi)
    if q_block_axis is not None:
        mesh_shape = _ambient_mesh_shape()
        n = mesh_shape.get(q_block_axis, 1)
        if n > 1 and o_blocks.shape[0] % n == 0:
            from jax.sharding import PartitionSpec as P

            o_blocks = jax.lax.with_sharding_constraint(
                o_blocks, P(q_block_axis, None, None, None, None)
            )
    o = o_blocks.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    return o.astype(q.dtype)
