"""Blockwise flash-attention forward kernel (Pallas, TPU target).

TPU adaptation of the blockwise online-softmax algorithm:

* grid ``(batch, q_heads, num_q_blocks, num_k_blocks)`` — the K axis is the
  minor (sequential) grid dimension, so the VMEM scratch accumulators carry
  across K steps of one (b, h, qi) tile;
* ``BlockSpec`` tiles: Q/O ``(block_q, head_dim)``, K/V ``(block_k,
  head_dim)`` — VMEM working set is ``(2·block_q + 2·block_k) · d`` floats,
  sized well under the ~16 MB VMEM budget for the default 512/512 blocks;
* matmul dims are MXU-aligned: ``block_q``/``block_k`` multiples of 128 and
  ``head_dim`` ∈ {64, 128, 224, 256} pad to lane width internally;
* GQA is free: the K/V ``index_map`` divides the query-head grid index by
  the group size instead of materialising repeated heads;
* causal tiles above the diagonal are skipped with ``pl.when`` (no FLOPs,
  no VMEM traffic), halving causal work;
* optional sliding-window masking and tanh logit soft-capping (gemma-2)
  happen on the fp32 logits tile in registers.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _fwd_kernel(
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scratch,
    l_scratch,
    acc_scratch,
    *,
    scale: float,
    causal: bool,
    sliding_window: int | None,
    prefix_len: int | None,
    logit_softcap: float | None,
    block_q: int,
    block_k: int,
    num_k_blocks: int,
    kv_len: int | None,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q_start = qi * block_q
    k_start = ki * block_k

    # causal skip: tile strictly above the diagonal contributes nothing;
    # with a ragged K length the padded tail tiles are skipped the same way
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if kv_len is not None:
        tail_ok = k_start < kv_len
        needed = tail_ok if needed is True else jnp.logical_and(needed, tail_ok)

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (block_q, block_k)
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones(s.shape, jnp.bool_)
        if causal:
            mask = q_pos >= k_pos
        if sliding_window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < sliding_window)
        if prefix_len is not None:
            mask = jnp.logical_or(mask, k_pos < prefix_len)
        if kv_len is not None:
            # ragged tail: padded K columns are masked out of the online
            # softmax (applied last so prefix_len cannot re-admit them)
            mask = jnp.logical_and(mask, k_pos < kv_len)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scratch[...]                         # (block_q, 1)
        l_prev = l_scratch[...]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (block_q, block_k)
        corr = jnp.exp(m_prev - m_new)                  # (block_q, 1)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        vv = v_ref[0, 0].astype(jnp.float32)            # (block_k, d)
        pv = jax.lax.dot_general(
            p, vv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_scratch[...] = acc_scratch[...] * corr + pv
        m_scratch[...] = m_new
        l_scratch[...] = l_new

    @pl.when(ki == num_k_blocks - 1)
    def _finalize():
        l = l_scratch[...]
        o_ref[0, 0] = (acc_scratch[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int | None = None,
    prefix_len: int | None = None,
    logit_softcap: float | None = None,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """q: (b, sq, h, d); k/v: (b, sk, hk, d), h % hk == 0.  → (b, sq, h, d).

    ``interpret=True`` executes the kernel body in Python (CPU validation);
    on TPU pass ``interpret=False``.
    """

    b, sq, h, d = q.shape
    _, sk, hk, _ = k.shape
    assert h % hk == 0, (h, hk)
    group = h // hk
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # ragged sequence lengths: pad up to block multiples.  Padded Q rows are
    # sliced off the output; padded K columns are masked out of the online
    # softmax inside the kernel (kv_len), never averaged in.
    pad_q = (-sq) % block_q
    pad_k = (-sk) % block_k
    kv_len = sk if pad_k else None
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    sqp, skp = sq + pad_q, sk + pad_k
    nq, nk = sqp // block_q, skp // block_k

    # layout: (b, h, s, d) blocks — heads are a pure grid dimension
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _fwd_kernel,
        scale=scale,
        causal=causal,
        sliding_window=sliding_window,
        prefix_len=prefix_len,
        logit_softcap=logit_softcap,
        block_q=block_q,
        block_k=block_k,
        num_k_blocks=nk,
        kv_len=kv_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    return out[:, :sq] if pad_q else out
