"""Pallas kernel for per-block symmetric int8 quantisation (TPU target).

Used on the gradient-compression path (cross-pod reduction payloads) and for
int8 KV caches.  Layout: the flat payload is reshaped to ``(rows, BLOCK)``;
the grid tiles rows, each tile computing VPU absmax→scale→round entirely in
VMEM.  ``BLOCK = 256`` (two 128-lane vregs) keeps reductions lane-aligned.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.compress import BLOCK

ROW_TILE = 64


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (rows, BLOCK)
    absmax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def quantize_int8_rows(
    x: jax.Array, *, row_tile: int = ROW_TILE, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """x: (rows, BLOCK) fp — returns (int8 (rows, BLOCK), fp32 scales (rows, 1))."""

    rows, width = x.shape
    assert width == BLOCK, (width, BLOCK)
    row_tile = min(row_tile, rows)
    assert rows % row_tile == 0, (rows, row_tile)
    grid = (rows // row_tile,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((row_tile, BLOCK), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((row_tile, BLOCK), lambda r: (r, 0)),
            pl.BlockSpec((row_tile, 1), lambda r: (r, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(x_ref.dtype)


def dequantize_int8_rows(
    q: jax.Array, s: jax.Array, *, out_dtype=jnp.float32, row_tile: int = ROW_TILE,
    interpret: bool = True,
) -> jax.Array:
    rows, width = q.shape
    assert width == BLOCK
    row_tile = min(row_tile, rows)
    assert rows % row_tile == 0
    return pl.pallas_call(
        _dequant_kernel,
        grid=(rows // row_tile,),
        in_specs=[
            pl.BlockSpec((row_tile, BLOCK), lambda r: (r, 0)),
            pl.BlockSpec((row_tile, 1), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, BLOCK), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, BLOCK), out_dtype),
        interpret=interpret,
    )(q, s)
