"""Pure-jnp oracle for block int8 quantisation (re-exports the core
reference so the kernel and the communication layer share one definition)."""

from repro.core.compress import (  # noqa: F401
    BLOCK,
    compression_error,
    dequantize_int8,
    quantize_int8,
)
