"""Jit'd wrapper for block int8 quantisation: flat-payload API matching
:mod:`repro.core.compress`, dispatching to the Pallas kernel or the jnp
reference."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compress import BLOCK
from repro.kernels.quant import kernel as _kernel
from repro.kernels.quant import ref as _ref


def quantize_int8(x: jax.Array, *, impl: str = "ref"):
    """Flat tensor → (q int8 flat, scales fp32 per block, pad)."""

    if impl == "ref":
        return _ref.quantize_int8(x)
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    rows = flat.shape[0] // BLOCK
    q, s = _kernel.quantize_int8_rows(
        flat.reshape(rows, BLOCK), interpret=(impl == "pallas")
    )
    return q.reshape(-1), s[:, 0], pad


def dequantize_int8(q, scale, pad, shape, dtype, *, impl: str = "ref"):
    if impl == "ref":
        return _ref.dequantize_int8(q, scale, pad, shape, dtype)
    rows = q.shape[0] // BLOCK
    x = _kernel.dequantize_int8_rows(
        q.reshape(rows, BLOCK), scale[:, None], out_dtype=dtype,
        interpret=(impl == "pallas"),
    ).reshape(-1)
    if pad:
        x = x[:-pad]
    return x.reshape(shape)
