"""Pallas kernel for the Mamba-2 SSD chunked scan (TPU target).

TPU adaptation of the SSD algorithm:

* grid ``(batch, heads, num_chunks)`` — chunks are the minor (sequential)
  grid dimension, so the ``(p, n)`` fp32 state lives in VMEM scratch and
  carries across chunk steps (the inter-chunk recurrence), re-initialised
  at ``chunk == 0``;
* each chunk step is three MXU matmuls (``C Bᵀ``, ``(CB ⊙ L) X``,
  ``Xᵀ_w B``) plus VPU elementwise decay math — the "duality" that makes
  SSM training MXU-bound instead of scan-bound;
* ``BlockSpec`` tiles: x/y ``(chunk, p)``, B/C ``(chunk, n)`` with the
  group index derived from the head grid index (grouped B/C need no
  materialised repeat);
* default ``chunk=128`` keeps every matmul MXU-aligned and the working set
  (≈ 4·chunk·max(p,n) fp32) far below VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    a_ref,      # (1,)        A for this head
    x_ref,      # (1, q, 1, p)
    dt_ref,     # (1, q, 1)
    b_ref,      # (1, q, 1, n)
    c_ref,      # (1, q, 1, n)
    y_ref,      # (1, q, 1, p)
    state_ref,  # VMEM (p, n) fp32 carry
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0].astype(jnp.float32)
    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (q, p)
    dt = dt_ref[0, :, 0].astype(jnp.float32)       # (q,)
    B = b_ref[0, :, 0, :].astype(jnp.float32)      # (q, n)
    C = c_ref[0, :, 0, :].astype(jnp.float32)      # (q, n)

    dA = dt * a
    cum = jnp.cumsum(dA)                            # (q,) inclusive
    total = cum[-1]

    # intra-chunk: (C Bᵀ ⊙ L) X
    cb = jax.lax.dot_general(
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (q, q)
    # mask the exponent (not the exp result): above the diagonal cum_i-cum_j
    # is positive and exp() overflows, which would poison autodiff through
    # the interpret-mode kernel with inf·0 (same fix as ref.ssd_chunked).
    qi = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    seg = jnp.where(qi >= kj, cum[:, None] - cum[None, :], -jnp.inf)
    L = jnp.exp(seg) * dt[None, :]
    y_intra = jax.lax.dot_general(
        cb * L, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (q, p)

    # inter-chunk: exp(cum_i) * (H_in C_i)
    h_in = state_ref[...]                           # (p, n)
    y_inter = jnp.exp(cum)[:, None] * jax.lax.dot_general(
        C, h_in, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (q, p)

    # state update: H = exp(total) H_in + Xᵀ_w B
    w = jnp.exp(total - cum) * dt                   # (q,)
    xw = x * w[:, None]                             # (q, p)
    s_local = jax.lax.dot_general(
        xw, B, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                               # (p, n)
    state_ref[...] = jnp.exp(total) * h_in + s_local

    y_ref[0, :, 0, :] = (y_intra + y_inter).astype(y_ref.dtype)


def ssd_scan_fwd(
    x: jax.Array,    # (b, l, h, p)
    dt: jax.Array,   # (b, l, h)
    A: jax.Array,    # (h,)
    B: jax.Array,    # (b, l, g, n)
    C: jax.Array,    # (b, l, g, n)
    *,
    chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Chunked SSD scan; returns y (b, l, h, p).  Zero initial state (the
    training/prefill case; decoding uses the explicit-state step in ref)."""

    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    group = h // g

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec(
                (1, chunk, 1, n), lambda bi, hi, ci, gg=group: (bi, ci, hi // gg, 0)
            ),
            pl.BlockSpec(
                (1, chunk, 1, n), lambda bi, hi, ci, gg=group: (bi, ci, hi // gg, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(A, x, dt, B, C)
