"""Pure-jnp oracles for the Mamba-2 SSD (state-space duality) scan.

Two references:

* :func:`ssd_sequential` — the literal recurrence (``lax.scan`` over time),
  the ground truth;
* :func:`ssd_chunked` — the chunked matrix form (intra-chunk dense matmuls +
  inter-chunk state recurrence).  This is the form the Pallas kernel
  implements and the form models compile on CPU; it is validated against the
  sequential oracle and the kernel is validated against both.

Conventions (Mamba-2 §6): per head, state ``H`` is ``(p, n)``;
``H_t = exp(dt_t A) H_{t-1} + dt_t x_t ⊗ B_t``; ``y_t = H_t C_t``.
``A < 0`` (decay), ``dt > 0``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(B: jax.Array, h: int) -> jax.Array:
    """(b, l, g, n) → (b, l, h, n) by repeating groups over their heads."""

    g = B.shape[2]
    if g == h:
        return B
    return jnp.repeat(B, h // g, axis=2)


def ssd_sequential(
    x: jax.Array,       # (b, l, h, p)
    dt: jax.Array,      # (b, l, h)
    A: jax.Array,       # (h,)
    B: jax.Array,       # (b, l, g, n)
    C: jax.Array,       # (b, l, g, n)
    initial_state: jax.Array | None = None,  # (b, h, p, n)
) -> tuple[jax.Array, jax.Array]:
    """Ground-truth recurrence.  Returns (y (b,l,h,p), final_state)."""

    b, l, h, p = x.shape
    n = B.shape[-1]
    Bh = _expand_groups(B, h).astype(jnp.float32)
    Ch = _expand_groups(C, h).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    state0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def step(state, inputs):
        xt, dtt, Bt, Ct = inputs  # (b,h,p), (b,h), (b,h,n), (b,h,n)
        decay = jnp.exp(dtt * Af[None])[:, :, None, None]          # (b,h,1,1)
        outer = (dtt[..., None, None] * xt[..., None]) * Bt[:, :, None, :]
        state = decay * state + outer                              # (b,h,p,n)
        y = jnp.einsum("bhpn,bhn->bhp", state, Ct)
        return state, y

    inputs = (
        xf.transpose(1, 0, 2, 3),
        dtf.transpose(1, 0, 2),
        Bh.transpose(1, 0, 2, 3),
        Ch.transpose(1, 0, 2, 3),
    )
    final, ys = jax.lax.scan(step, state0, inputs)
    y = ys.transpose(1, 0, 2, 3).astype(x.dtype)  # (b, l, h, p)
    return y, final


def ssd_chunked(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 128,
    initial_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD (matrix form).  Same signature/returns as sequential."""

    b, l, h, p = x.shape
    assert l % chunk == 0, (l, chunk)
    nc, q = l // chunk, chunk
    n = B.shape[-1]
    Bh = _expand_groups(B, h).astype(jnp.float32).reshape(b, nc, q, h, n)
    Ch = _expand_groups(C, h).astype(jnp.float32).reshape(b, nc, q, h, n)
    xf = x.astype(jnp.float32).reshape(b, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(b, nc, q, h)
    Af = A.astype(jnp.float32)

    dA = dtf * Af[None, None, None]                     # (b,nc,q,h)
    cum = jnp.cumsum(dA, axis=2)                        # inclusive
    total = cum[:, :, -1]                               # (b,nc,h)

    # intra-chunk: y_i += sum_{j<=i} (C_i·B_j) exp(cum_i-cum_j) dt_j x_j
    # NOTE: mask the EXPONENT (j>i → -inf), not the exp result: cum_i-cum_j
    # is positive above the diagonal and exp() overflows there, which poisons
    # the backward of where() with inf·0 = NaN (Mamba-2's segsum does the
    # same masking for the same reason).
    cb = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)       # (b,nc,h,q,q)
    seg = (
        cum.transpose(0, 1, 3, 2)[:, :, :, :, None]
        - cum.transpose(0, 1, 3, 2)[:, :, :, None, :]
    )                                                   # (b,nc,h,q,q): cum_i - cum_j
    mask = jnp.tril(jnp.ones((q, q), bool))
    L = jnp.exp(jnp.where(mask[None, None, None], seg, -jnp.inf))
    L = L * dtf.transpose(0, 1, 3, 2)[:, :, :, None, :]  # × dt_j
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", cb * L, xf)

    # chunk-local state contribution: S_c = sum_j exp(total-cum_j) dt_j x_j ⊗ B_j
    w = jnp.exp(total[:, :, None] - cum) * dtf          # (b,nc,q,h)
    S = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn", w, xf, Bh)

    # inter-chunk recurrence over c: H_{c} = exp(total_c) H_{c-1} + S_c
    state0 = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )

    def chunk_step(Hprev, inputs):
        S_c, total_c = inputs                            # (b,h,p,n), (b,h)
        Hnew = jnp.exp(total_c)[:, :, None, None] * Hprev + S_c
        return Hnew, Hprev                               # emit the *incoming* state

    final, H_in = jax.lax.scan(
        chunk_step, state0, (S.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2))
    )
    H_in = H_in.transpose(1, 0, 2, 3, 4)                 # (b,nc,h,p,n) state at chunk start

    # inter-chunk output: y_i += exp(cum_i) * (H_in C_i)
    y_inter = jnp.exp(cum)[..., None] * jnp.einsum("bchpn,bcqhn->bcqhp", H_in, Ch)

    y = (y_intra + y_inter).reshape(b, l, h, p).astype(x.dtype)
    return y, final


def ssd_decode_step(
    state: jax.Array,   # (b, h, p, n)
    x: jax.Array,       # (b, h, p)
    dt: jax.Array,      # (b, h)
    A: jax.Array,       # (h,)
    B: jax.Array,       # (b, g, n)
    C: jax.Array,       # (b, g, n)
) -> tuple[jax.Array, jax.Array]:
    """Single-token recurrent step (the serving path).  Returns (y, state)."""

    h = x.shape[1]
    g = B.shape[1]
    if g != h:
        B = jnp.repeat(B, h // g, axis=1)
        C = jnp.repeat(C, h // g, axis=1)
    decay = jnp.exp(dt.astype(jnp.float32) * A[None])[:, :, None, None]
    outer = (dt[..., None, None] * x[..., None]).astype(jnp.float32) * B[:, :, None, :]
    state = decay * state + outer
    y = jnp.einsum("bhpn,bhn->bhp", state, C.astype(jnp.float32))
    return y.astype(x.dtype), state
