"""Jit'd public wrapper for the SSD scan.

Dispatches between the Pallas kernel (TPU target / interpret validation) and
the chunked jnp form (CPU compile path for full models).  Backward pass:
``custom_vjp`` recomputing through the chunked reference — SSD residuals are
O(L·state), recompute keeps memory at activations-only.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan import kernel as _kernel
from repro.kernels.ssd_scan import ref as _ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd(x, dt, A, B, C, chunk, impl):
    return _forward(x, dt, A, B, C, chunk, impl)


def _forward(x, dt, A, B, C, chunk, impl):
    if impl == "pallas":
        return _kernel.ssd_scan_fwd(x, dt, A, B, C, chunk=chunk, interpret=True)
    if impl == "pallas_tpu":
        return _kernel.ssd_scan_fwd(x, dt, A, B, C, chunk=chunk, interpret=False)
    y, _ = _ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    return y


def _fwd(x, dt, A, B, C, chunk, impl):
    return _forward(x, dt, A, B, C, chunk, impl), (x, dt, A, B, C)


def _bwd(chunk, impl, res, g):
    x, dt, A, B, C = res

    def recompute(x, dt, A, B, C):
        y, _ = _ref.ssd_chunked(x, dt, A, B, C, chunk=chunk)
        return y

    _, vjp = jax.vjp(recompute, x, dt, A, B, C)
    return vjp(g)


_ssd.defvjp(_fwd, _bwd)


def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, impl: str = "ref"):
    """y = SSD(x, dt, A, B, C); shapes as in :mod:`.ref`."""

    return _ssd(x, dt, A, B, C, chunk, impl)


ssd_decode_step = _ref.ssd_decode_step
