"""Ring attention over a 1-D periodic :class:`~repro.core.topology.CartComm`.

The fusion of two existing layers: the flash-attention Pallas kernel (blockwise
online softmax) and the ch. 8 cart/halo fabric (``cart_shift(+1)`` lowering to
an axis-local ``collective-permute``).  Called per-shard inside ``shard_map``:
every rank holds its Q shard for the whole schedule while the stacked KV
buffer rotates around the ring — ``n`` steps, ``n - 1`` collective-permutes,
each issued as a :class:`~repro.core.futures.TraceFuture` *before* the step it
overlaps with and joined via ``when_all``
(:func:`repro.core.overlap.ring_rotate_compute`).  Per-step wire volume is the
local KV shard: ``1/n`` of the global KV, the ring-attention wire contract
``benchmarks/hlo_parity.py`` proves on the compiled artifact.

Uneven global lengths: the caller pads the global sequence to ``n × shard``
(padding at the tail, so shard ``r`` owns global rows ``[r·shard, (r+1)·shard)``
and only trailing shards hold padding); ``global_len`` sizes the per-source
valid-row table that masks padded columns out of the online softmax inside the
kernel.  Padded Q rows produce the reference oracle's uniform-softmax value and
are sliced off by the caller.

Gradients: ``custom_vjp`` with backward recompute through the differentiable
jnp ring (same schedule, ``impl='ref'``) — the recompute-backward convention of
``flash_attention/ops.py``, with the ring loop itself as the VJP boundary so
no per-step O(S²) residuals survive the forward pass.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import errors, overlap
from repro.core.futures import TraceFuture
from repro.kernels.ring_attention import kernel as _kernel

NEG_INF = _kernel.NEG_INF


@dataclasses.dataclass(frozen=True)
class RingSpec:
    """Static (hashable) description of one ring-attention schedule — the
    ``nondiff`` payload of the custom-VJP boundary.  ``axis_name`` and
    ``axis_perm`` come from ``cart.cart_shift(dim, +1)``; ``shard`` is the
    per-rank sequence length *before* block padding; ``global_len`` the
    unpadded global sequence length."""

    axis_name: str
    axis_perm: tuple[tuple[int, int], ...]
    n: int
    shard: int
    global_len: int
    causal: bool
    scale: float
    impl: str
    block_q: int
    block_k: int

    def kv_lens(self) -> tuple[int, ...]:
        """Valid KV rows per source shard (the ragged tail lives on the
        trailing shards)."""

        return tuple(
            max(0, min(self.shard, self.global_len - r * self.shard))
            for r in range(self.n)
        )


def _pad_seq(x: jax.Array, block: int, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % block
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _forward(q, k, v, spec: RingSpec):
    """The fused ring loop (per-shard, inside ``shard_map``).

    q: (b, sq, h, d); k/v: (b, sk, hk, d) — the local shards.  Returns the
    local output shard (b, sq, h, d) in q's dtype.
    """

    b, sq, h, d = q.shape
    sk, hk = k.shape[1], k.shape[2]
    # a ring of one has zero permutes and needs no bound axis (usable
    # outside shard_map); larger rings read their coordinate off the axis
    idx = lax.axis_index(spec.axis_name) if spec.n > 1 else jnp.int32(0)

    # head-major layout once, outside the loop; block padding once (the
    # kv_len table masks padded K columns, padded Q rows are sliced off)
    block_q = min(spec.block_q, sq)
    block_k = min(spec.block_k, sk)
    qt = _pad_seq(q.transpose(0, 2, 1, 3), block_q, 2)          # (b, h, sqp, d)
    kt = _pad_seq(k.transpose(0, 2, 1, 3), block_k, 2)          # (b, hk, skp, d)
    vt = _pad_seq(v.transpose(0, 2, 1, 3), block_k, 2)
    sqp = qt.shape[2]

    m = jnp.full((b, h, sqp, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((b, h, sqp, 1), jnp.float32)
    acc = jnp.zeros((b, h, sqp, d), jnp.float32)

    # per-source valid-row table; when every shard is full (the common even
    # case) kv_len is a CONSTANT and XLA folds the tail mask away entirely —
    # the fused path must not pay a masking tax the hand-written schedule
    # would not
    lens = spec.kv_lens()
    even = all(n_valid == spec.shard for n_valid in lens)
    lens_arr = None if even else jnp.asarray(lens, jnp.int32)
    q_off = (idx * spec.shard).astype(jnp.int32)

    def rotate(kv):
        # the cart_shift(+1) permute of the *stacked* KV buffer: one
        # collective-permute per ring step, issued before the step's compute
        return TraceFuture(
            lambda: lax.ppermute(kv, spec.axis_name, list(spec.axis_perm))
        )

    def step_fn(carry, kv, step):
        m, l, acc = carry
        src = jnp.mod(idx - step, spec.n)
        k_off = (src * spec.shard).astype(jnp.int32)
        kv_len = jnp.int32(spec.shard) if even else lens_arr[src]
        if spec.impl in ("pallas", "pallas_tpu"):
            return _kernel.ring_step_fwd(
                qt, kv[0], kv[1], m, l, acc,
                q_offset=q_off, k_offset=k_off, kv_len=kv_len,
                scale=spec.scale, causal=spec.causal,
                block_q=block_q, block_k=block_k,
                interpret=(spec.impl == "pallas"),
            )
        return _kernel.ring_step_ref(
            qt, kv[0], kv[1], m, l, acc,
            q_offset=q_off, k_offset=k_off, kv_len=kv_len,
            scale=spec.scale, causal=spec.causal,
        )

    m, l, acc = overlap.ring_rotate_compute(
        rotate, jnp.stack([kt, vt]), spec.n, step_fn, (m, l, acc)
    )
    out = acc / jnp.maximum(l, 1e-30)                           # (b, h, sqp, d)
    out = out.transpose(0, 2, 1, 3).astype(q.dtype)
    return out[:, :sq] if sqp != sq else out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _ring(q, k, v, spec):
    return _forward(q, k, v, spec)


def _fwd(q, k, v, spec):
    return _forward(q, k, v, spec), (q, k, v)


def _bwd(spec, res, g):
    q, k, v = res
    ref_spec = dataclasses.replace(spec, impl="ref")

    def recompute(q, k, v):
        return _forward(q, k, v, ref_spec)

    _, vjp = jax.vjp(recompute, q, k, v)
    return vjp(g)


_ring.defvjp(_fwd, _bwd)


def ring_attention(
    cart,
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    dim: int = 0,
    causal: bool = True,
    scale: float | None = None,
    global_len: int | None = None,
    impl: str = "pallas",
    block_q: int = _kernel.DEFAULT_BLOCK_Q,
    block_k: int = _kernel.DEFAULT_BLOCK_K,
) -> jax.Array:
    """Blockwise ring attention over cart dimension ``dim`` (periodic).

    Per-shard entry point (call inside ``shard_map`` over the ring axis):
    ``q`` (b, sq, h, d), ``k``/``v`` (b, sk, hk, d) are this rank's shards of
    a sequence padded to ``n × shard``; ``global_len`` (default ``n × sq``)
    is the unpadded length.  ``impl``: 'ref' (jnp online blocks, the XLA
    path), 'pallas' (interpret-mode kernel, CPU validation), 'pallas_tpu'.
    Exact (fp32 state) vs the dense flash reference; differentiable, with
    backward recompute through the jnp ring.
    """

    errors.check(
        0 <= dim < len(cart.dims),
        errors.ErrorClass.ERR_DIMS,
        f"ring dim {dim} out of range for cart dims {cart.dims}",
    )
    errors.check(
        cart.periods[dim],
        errors.ErrorClass.ERR_TOPOLOGY,
        "ring attention needs a periodic ring dimension (the KV rotation "
        "must wrap; create the cart with periods=True on the ring dim)",
    )
    errors.check(
        q.shape[1] == k.shape[1],
        errors.ErrorClass.ERR_COUNT,
        f"ring attention shards Q and KV identically, got q seq {q.shape[1]} "
        f"vs kv seq {k.shape[1]}",
    )
    n = cart.dims[dim]
    shard = q.shape[1]
    if global_len is None:
        global_len = n * shard
    errors.check(
        0 < global_len <= n * shard,
        errors.ErrorClass.ERR_COUNT,
        f"global_len {global_len} inconsistent with {n} shards of {shard}",
    )
    if scale is None:
        scale = 1.0 / math.sqrt(q.shape[-1])
    shift = cart.cart_shift(dim, 1)
    spec = RingSpec(
        axis_name=shift.axis_name,
        axis_perm=tuple(shift.axis_perm),
        n=n,
        shard=shard,
        global_len=int(global_len),
        causal=bool(causal),
        scale=float(scale),
        impl=impl,
        block_q=int(block_q),
        block_k=int(block_k),
    )
    return _ring(q, k, v, spec)
