"""Blockwise ring-attention step kernel (Pallas, TPU target).

One *ring step* of blockwise ring attention: the local Q shard attends over
the KV shard currently in flight on the ring, folding the result into the
online-softmax carry ``(m, l, acc)`` that travels across ring steps.  The
surrounding rotate-while-compute schedule (``kernels/ring_attention/ops.py``
over :func:`repro.core.overlap.ring_rotate_compute`) issues the next
``cart_shift(+1)`` collective-permute while this kernel runs.

Differences from the single-device flash kernel (``flash_attention/kernel``):

* the carry is a kernel *input and output* instead of scratch — VMEM scratch
  dies with the ``pallas_call``, but ring state must survive N invocations
  interleaved with permutes;
* Q and K global positions are **traced scalars** (SMEM block): inside
  ``shard_map`` the step's source rank is ``(idx - step) mod n`` with
  ``idx = lax.axis_index``, so block offsets for causal masking cannot be
  Python ints — they ride in through a tiny ``(3,)`` int32 SMEM buffer
  (q_offset, k_offset, kv_len);
* ``kv_len`` masks the ragged tail of an uneven shard (global sequence
  padded to ``n × shard``; padding lives at the tail of the last shards) —
  masked columns never enter the online softmax;
* no finalize: normalisation by ``l`` happens once, after the last ring
  step, in the ops layer.

The carry uses the flash state convention throughout: ``m``/``l``
``(b, h, sq, 1)`` fp32, ``acc`` ``(b, h, sq, d)`` fp32 *unnormalised*.
Masking uses the finite ``NEG_INF`` convention of the flash kernel: a tile
that is entirely masked adds ``exp(0)`` rows that the next real tile's
correction factor ``exp(m_prev - m_new)`` zeroes out, and rows that stay
fully masked across every step resolve to the same uniform softmax as the
reference oracle.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_K = 512


def _step_kernel(
    info_ref,      # SMEM (3,) int32: q_offset, k_offset, kv_len
    q_ref,
    k_ref,
    v_ref,
    m_in_ref,
    l_in_ref,
    acc_in_ref,
    m_out_ref,
    l_out_ref,
    acc_out_ref,
    *,
    scale: float,
    causal: bool,
    block_q: int,
    block_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    q_off = info_ref[0]
    k_off = info_ref[1]
    kv_len = info_ref[2]

    # the carry enters through the output refs: loaded once at ki == 0, then
    # accumulated in place across the sequential K walk (out blocks persist
    # while their index map ignores ki)
    @pl.when(ki == 0)
    def _load_carry():
        m_out_ref[...] = m_in_ref[...]
        l_out_ref[...] = l_in_ref[...]
        acc_out_ref[...] = acc_in_ref[...]

    q_start = qi * block_q
    k_start = ki * block_k

    # skip tiles with no unmasked column: the ragged tail beyond kv_len,
    # and (causal) tiles strictly in this Q block's future
    needed = k_start < kv_len
    if causal:
        needed = jnp.logical_and(
            needed, k_off + k_start <= q_off + q_start + block_q - 1
        )

    @pl.when(needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_k, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (block_q, block_k)

        k_local = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = k_local < kv_len
        if causal:
            q_pos = q_off + q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            k_pos = k_off + k_local
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_out_ref[0, 0]                        # (block_q, 1)
        l_prev = l_out_ref[0, 0]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                          # (block_q, block_k)
        corr = jnp.exp(m_prev - m_new)                  # (block_q, 1)
        l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
        vv = v_ref[0, 0].astype(jnp.float32)            # (block_k, d)
        pv = jax.lax.dot_general(
            p, vv, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_out_ref[0, 0] = acc_out_ref[0, 0] * corr + pv
        m_out_ref[0, 0] = m_new
        l_out_ref[0, 0] = l_new


def ring_step_fwd(
    q: jax.Array,        # (b, h, sq, d)  — local Q shard, head-major layout
    k: jax.Array,        # (b, hk, sk, d) — KV shard in flight
    v: jax.Array,        # (b, hk, sk, d)
    m: jax.Array,        # (b, h, sq, 1) fp32 carry
    l: jax.Array,        # (b, h, sq, 1) fp32 carry
    acc: jax.Array,      # (b, h, sq, d) fp32 carry (unnormalised)
    *,
    q_offset: jax.Array,  # () int32, traced — global start of the Q shard
    k_offset: jax.Array,  # () int32, traced — global start of the KV shard
    kv_len: jax.Array,    # () int32, traced — valid rows of the KV shard
    scale: float | None = None,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One ring step: fold ``softmax(q @ k.T) @ v`` of this KV block into
    the carry.  Returns the updated ``(m, l, acc)``.

    Sequence lengths must already be block multiples (the ops layer pads
    once, outside the ring loop; ``kv_len`` masks the padded tail).
    ``interpret=True`` runs the kernel body in Python (CPU validation).
    """

    b, h, sq, d = q.shape
    _, hk, sk, _ = k.shape
    assert h % hk == 0, (h, hk)
    group = h // hk
    if scale is None:
        scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nq, nk = sq // block_q, sk // block_k

    info = jnp.stack(
        [
            jnp.asarray(q_offset, jnp.int32),
            jnp.asarray(k_offset, jnp.int32),
            jnp.asarray(kv_len, jnp.int32),
        ]
    )

    kernel = functools.partial(
        _step_kernel,
        scale=scale,
        causal=causal,
        block_q=block_q,
        block_k=block_k,
    )
    carry_q = pl.BlockSpec((1, 1, block_q, 1), lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    carry_d = pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0))
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, qi, ki, g=group: (bi, hi // g, ki, 0)
            ),
            carry_q,
            carry_q,
            carry_d,
        ],
        out_specs=[carry_q, carry_q, carry_d],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, h, sq, d), jnp.float32),
        ],
        interpret=interpret,
    )(info, q, k, v, m, l, acc)


def ring_step_ref(
    q, k, v, m, l, acc, *, q_offset, k_offset, kv_len, scale, causal
):
    """jnp twin of :func:`ring_step_fwd` (same layouts, same masking
    convention) — the XLA-path implementation and the differentiable
    recompute target of the ops-layer backward pass."""

    qf = q.astype(jnp.float32)
    h, hk = q.shape[1], k.shape[1]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if hk != h:
        rep = h // hk
        kf = jnp.repeat(kf, rep, axis=1)
        vf = jnp.repeat(vf, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * scale
    sk = s.shape[-1]
    k_local = jnp.arange(sk)[None, :]
    mask = k_local < kv_len
    if causal:
        q_pos = q_offset + jnp.arange(s.shape[-2])[:, None]
        k_pos = k_offset + k_local
        mask = jnp.logical_and(mask, q_pos >= k_pos)
    s = jnp.where(mask[None, None], s, NEG_INF)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m, m_cur)
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc * corr + jnp.einsum("bhqk,bhkd->bhqd", p, vf)
    return m_new, l_new, acc_new
