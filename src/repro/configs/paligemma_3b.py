"""paligemma-3b — SigLIP + gemma prefix-LM VLM backbone.

[arXiv:2407.07726; hf-verified]  18L d_model=2048 8H (GQA kv=1) head_dim=256
d_ff=16384 vocab=257216.  The SigLIP vision tower is a STUB:
``input_specs()`` provides 256 precomputed patch embeddings (dim 1152,
SigLIP-So400m feature width) which the trunk projects with ``mm_proj``.
Image tokens attend bidirectionally (prefix-LM); text is causal.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma_3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257_216,
        num_image_tokens=256,     # 224/14 squared
        prefix_lm=True,
        tie_embeddings=True,
        embed_scale=True,
        act="gelu",
        source="arXiv:2407.07726 (hf:google/paligemma-3b-pt-224)",
    )


def parallel() -> ParallelConfig:
    # MQA (kv=1): kv replicates over 'model'; q heads (8) also do not divide
    # 16 → TP lives on d_ff (16384 = 16·1024) and the 257k vocab.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma_3b_smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_image_tokens=4,
        prefix_lm=True,
        tie_embeddings=True,
        embed_scale=True,
        act="gelu",
    )
