"""zamba2-7b — Mamba-2 backbone with a shared attention block.

[arXiv:2411.15242; unverified]  81 Mamba-2 layers d_model=3584, ssm_state=64,
one shared attention+MLP block (32H kv=32, d_ff=14336) applied every 6 SSM
layers with shared weights (13 applications + 3 tail SSM layers).
vocab=32000.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_7b",
        family="hybrid",
        num_layers=81,
        d_model=3584,
        num_heads=32,
        num_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32_000,
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,
        attn_every=6,
        tie_embeddings=True,
        act="gelu",
        source="arXiv:2411.15242 (hf:Zyphra/Zamba2-7B, unverified)",
    )


def parallel() -> ParallelConfig:
    # SSM inner dim 7168 = 16·448 shards cleanly; attention heads 32 = 16·2.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2_7b_smoke",
        family="hybrid",
        num_layers=7,             # 2 groups of 3 + 1 tail layer
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        attn_every=3,
        tie_embeddings=True,
        act="gelu",
    )
