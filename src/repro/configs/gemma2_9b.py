"""gemma2-9b — dense GQA with local+global alternating attention and logit
softcaps.

[arXiv:2408.00118; hf-verified]  42L d_model=3584 16H (GQA kv=8) head_dim=256
d_ff=14336 vocab=256000; sliding window 4096 on local layers, attn softcap
50, final softcap 30, pre+post sandwich norms, tied + scaled embeddings.
"""

from repro.configs.base import ModelConfig, ParallelConfig, PlanSpace


def plan_space() -> PlanSpace:
    # 42 layers factor as 2·3·7: stages beyond (1, 2, 6) leave ragged
    # stacks, and 16 GQA heads cap tensor at 8 without splitting a KV head.
    return PlanSpace(
        stages=(1, 2, 6),
        rings=(1, 2, 4, 8),
        tensors=(1, 2, 4, 8),
        remats=("none", "dots", "full"),
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2_9b",
        family="dense",
        num_layers=42,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256_000,
        layer_pattern="local_global",
        sliding_window=4096,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        post_norms=True,
        tie_embeddings=True,
        embed_scale=True,
        query_scale=256.0 ** -0.5,
        act="gelu",
        source="arXiv:2408.00118 (hf:google/gemma-2-9b)",
    )


def parallel() -> ParallelConfig:
    # 16 heads divide the model axis exactly; TP over heads + d_ff + vocab.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2_9b_smoke",
        family="dense",
        num_layers=4,               # 2 local/global units
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=32,                # head_dim != d_model/heads, as in gemma-2
        d_ff=128,
        vocab_size=512,
        layer_pattern="local_global",
        sliding_window=8,
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        post_norms=True,
        tie_embeddings=True,
        embed_scale=True,
        query_scale=32.0 ** -0.5,
        act="gelu",
    )
