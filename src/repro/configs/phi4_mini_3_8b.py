"""phi4-mini-3.8b — dense GQA transformer.

[arXiv:2412.08905; hf-verified]  32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064, RoPE, SwiGLU, tied embeddings.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="phi4_mini_3_8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=200_064,
        tie_embeddings=True,
        rope_theta=10_000.0,
        act="silu",
        source="arXiv:2412.08905 (hf:microsoft/Phi-4-mini-instruct)",
    )


def parallel() -> ParallelConfig:
    # 24 heads / 8 kv heads do not divide 16 → TP on d_ff (8192 = 16·512)
    # and vocab; FSDP over data axes carries the rest.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi4_mini_3_8b_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        tie_embeddings=True,
    )
