"""deepseek-v2-236b — MLA + fine-grained MoE (2 shared + 160 routed, top-6).

[arXiv:2405.04434; hf-verified]  60L d_model=5120 128H, MLA with
kv_lora=512 / q_lora=1536 / rope_head_dim=64 / nope=128 / v=128;
first layer dense (d_ff=12288), remaining 59 MoE with expert d_ff=1536.
vocab=102400.
"""

from repro.configs.base import ModelConfig, ParallelConfig, PlanSpace


def plan_space() -> PlanSpace:
    # 60 layers minus the leading dense layer pipeline awkwardly past 4
    # stages; 160 routed experts divide by every power of two up to 8, and
    # expert parallelism rides the tensor (model) axis.
    return PlanSpace(
        stages=(1, 2, 4),
        rings=(1, 2, 4),
        experts=(1, 2, 4, 8),
        tensors=(1, 2, 4, 8),
        microbatches=(1, 2, 4, 8),
        remats=("full",),          # 236B never trains without full remat
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v2_236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=12288,               # the single dense layer
        vocab_size=102_400,
        num_experts=160,
        num_shared_experts=2,
        moe_top_k=6,
        moe_d_ff=1536,
        first_dense_layers=1,
        mla=True,
        q_lora=1536,
        kv_lora=512,
        rope_head_dim=64,
        nope_head_dim=128,
        v_head_dim=128,
        act="silu",
        source="arXiv:2405.04434 (hf:deepseek-ai/DeepSeek-V2)",
    )


def parallel() -> ParallelConfig:
    # 160 experts = 16·10 → true expert parallelism over 'model';
    # 128 MLA heads = 16·8 → head TP for attention.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", shard_experts=True, remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek_v2_236b_smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        num_experts=8,
        num_shared_experts=2,
        moe_top_k=2,
        moe_d_ff=32,
        first_dense_layers=1,
        mla=True,
        q_lora=32,
        kv_lora=32,
        rope_head_dim=8,
        nope_head_dim=16,
        v_head_dim=16,
        act="silu",
    )
