"""mamba2-2.7b — attention-free SSD (state-space duality) LM.

[arXiv:2405.21060; unverified]  64L d_model=2560, ssm_state=128,
head_dim=64 (80 heads at expand=2), vocab=50280.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_2_7b",
        family="ssm",
        num_layers=64,
        d_model=2560,
        num_heads=0,              # attention-free
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        tie_embeddings=True,
        source="arXiv:2405.21060 (hf:state-spaces/mamba2-2.7b, unverified)",
    )


def parallel() -> ParallelConfig:
    # d_inner 5120 = 16·320 (80 heads = 16·5) → clean TP over SSM heads.
    return ParallelConfig(fsdp=True, remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2_2_7b_smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_expand=2,
        tie_embeddings=True,
    )
