"""Configuration schema: model, parallelism and workload shapes.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro/configs/<id>.py``); the four workload shapes are fixed
:class:`ShapeConfig` instances; :class:`ParallelConfig` carries the
distribution plan (which the dry-run and the perf hillclimb toggle).

:class:`ParallelPlan` is the **unified layout object** on top of both: one
frozen value naming every fold the runtime can make (data × stage ×
expert/ring × tensor, plus microbatches, grad-sync buckets and the remat
mode), replacing the scattered knob surface (``TrainerConfig.pipeline_stages``
vs ``ring_attention``, the ``ParallelConfig`` booleans, ``TopologySpec``
dims).  The autotuner (:mod:`repro.tune`) enumerates the per-arch legal
space (:func:`plan_space` / :func:`legal_plans`), scores each candidate with
the roofline model, and emits the winner as a plan every layer consumes —
``TopologySpec.from_plan`` folds it, the Trainer re-forms its fabric from
it, the launchers parse it from ``--plan``.
"""

from __future__ import annotations

import dataclasses
import importlib
import itertools
import math

from repro.core import errors
from repro.core.descriptors import Compression


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention flavour
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    sliding_window: int | None = None
    layer_pattern: str = "uniform"   # uniform | local_global (gemma-2 alternation)
    post_norms: bool = False         # gemma-2 pre+post sandwich norms
    query_scale: float | None = None  # override 1/sqrt(head_dim)

    # embedding / head
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d_model) input scaling

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # MLA (deepseek-v2)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba-2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    attn_every: int = 0              # hybrid: shared attention block period

    # enc-dec
    encoder_layers: int = 0

    # vlm
    num_image_tokens: int = 0
    prefix_lm: bool = False

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    act: str = "silu"

    # provenance
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so the logit dimension shards over any mesh
        axis; synthetic labels are drawn below ``vocab_size``."""

        return _round_up(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and reporting)."""

        d, L = self.d_model, self.num_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            if self.mla:
                attn = (
                    d * self.q_lora
                    + self.q_lora * self.num_heads * (self.nope_head_dim + self.rope_head_dim)
                    + d * (self.kv_lora + self.rope_head_dim)
                    + self.kv_lora * self.num_heads * (self.nope_head_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d
                )
            else:
                attn = (
                    d * self.num_heads * self.head_dim
                    + 2 * d * self.num_kv_heads * self.head_dim
                    + self.num_heads * self.head_dim * d
                )
            if self.num_experts:
                moe_l = L - self.first_dense_layers
                shared = self.num_shared_experts * 3 * d * self.moe_d_ff
                routed = self.num_experts * 3 * d * self.moe_d_ff
                router = d * self.num_experts
                mlp_total = (
                    moe_l * (shared + routed + router)
                    + self.first_dense_layers * 3 * d * self.d_ff
                )
            else:
                mlp_total = L * 3 * d * self.d_ff
            per_layer_total = L * attn + mlp_total + L * 2 * d
            if self.family == "encdec":
                # encoder layers + decoder cross-attention
                enc = self.encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
                cross = L * attn
                per_layer_total += enc + cross
            if self.family == "vlm":
                per_layer_total += 1152 * d  # SigLIP-stub multimodal projector
            return emb + per_layer_total
        if self.family == "ssm":
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            blk = (
                d * (2 * di + 2 * self.ssm_groups * ns + nh)   # in_proj
                + self.ssm_conv * (di + 2 * self.ssm_groups * ns)
                + di * d                                        # out_proj
                + 2 * nh + di + d                               # A, D, dt_bias(+norm)
            )
            return emb + L * blk + L * d
        if self.family == "hybrid":
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            blk = (
                d * (2 * di + 2 * self.ssm_groups * ns + nh)
                + self.ssm_conv * (di + 2 * self.ssm_groups * ns)
                + di * d
                + 2 * nh + di + d
            )
            attn = (
                d * self.num_heads * self.head_dim * 2
                + 2 * d * self.num_kv_heads * self.head_dim
                + 3 * d * self.d_ff
                + 4 * d
            )
            return emb + L * (blk + d) + attn  # one shared attention block
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active (per-token) parameters for MoE (6·N_active·D FLOPs)."""

        if not self.num_experts:
            return self.param_count()
        total = self.param_count()
        d = self.d_model
        moe_l = self.num_layers - self.first_dense_layers
        routed_all = moe_l * self.num_experts * 3 * d * self.moe_d_ff
        routed_active = moe_l * self.moe_top_k * 3 * d * self.moe_d_ff
        return total - routed_all + routed_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass
class ParallelConfig:
    """Distribution plan; the hillclimb toggles live here."""

    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"

    fsdp: bool = True                    # params/opt-state sharded over data_axes
    attn_plan: str = "tp_heads"          # tp_heads | sp (sequence-parallel attention)
    attn_impl: str = "ref"               # ref | chunked (online-softmax) | pallas[_tpu]
    shard_experts: bool = False          # EP: expert dim over model_axis
    moe_dispatch: str = "global"         # global | per_row (data-local dispatch)
    seq_shard_cache: bool = False        # decode: KV cache sharded over sequence
    flash_decode_merge: bool = False     # + exact partial-softmax merge (optimized)
    ring_attention: bool = False         # training SP via ring schedule (optimized)
    overlap_fsdp: bool = False           # all_gather_matmul futures (optimized)
    compression: Compression = Compression.NONE  # cross-pod grad payloads
    remat: str = "full"                  # none | full | dots
    microbatches: int = 1                # gradient-accumulation splits of the global batch
    kv_cache_dtype: str = "bfloat16"     # bfloat16 | int8
    moment_dtype: str = "float32"        # float32 | int8 (8-bit Adam moments)
    scan_layers: bool = True

    @property
    def all_data_axes(self) -> tuple[str, ...]:
        return self.data_axes


# -- the unified parallelism plan --------------------------------------------

#: remat modes a plan may pin (``None`` inherits the ParallelConfig's mode).
REMAT_MODES = ("none", "dots", "full")


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """One frozen value for the whole 4-axis layout space.

    ``data × stage × ring/expert × tensor`` must multiply to the device
    count the plan targets; at most one of ``stage``/``ring`` may exceed 1
    (both re-form the trainer's communicator), ``ring`` and ``tensor`` are
    mutually exclusive (both fold onto the ``model`` mesh axis), and expert
    parallelism rides the model axis (``expert`` is 1 or equals
    ``tensor``).  The data axis is the *elastic* one: the derived
    :class:`~repro.core.epoch.TopologySpec` marks it ``ELASTIC`` so the same
    plan folds at every survivor count.

    Beyond the fold, a plan carries the execution knobs the tuner searches
    over — ``microbatches`` (pipeline streaming / gradient accumulation),
    ``grad_buckets`` (grad-sync partition count, an overlap-vs-latency
    trade), ``remat`` — plus two deliberate placement choices:
    ``dcn_axis`` names the fold axis that crosses ``repro://slice/<k>``
    boundaries on multi-pod layouts (DCN is ~an order of magnitude slower
    than ICI, so which axis pays it is a plan decision, not an accident) and
    ``fanout`` is the serving prefill:decode split.
    """

    data: int = 1
    stage: int = 1
    ring: int = 1
    expert: int = 1
    tensor: int = 1
    microbatches: int = 1
    grad_buckets: int = 1
    remat: str | None = None
    dcn_axis: str | None = None
    fanout: tuple[int, int] | None = None

    def __post_init__(self):
        for f in ("data", "stage", "ring", "expert", "tensor",
                  "microbatches", "grad_buckets"):
            v = getattr(self, f)
            errors.check(
                isinstance(v, int) and v >= 1,
                errors.ErrorClass.ERR_ARG,
                f"ParallelPlan.{f} must be a positive int, got {v!r}",
            )
        errors.check(
            not (self.stage > 1 and self.ring > 1),
            errors.ErrorClass.ERR_TOPOLOGY,
            "plan axes stage (pipeline_stages) and ring (ring_attention) both "
            "re-form the communicator; pick one per plan",
        )
        errors.check(
            not (self.ring > 1 and self.tensor > 1),
            errors.ErrorClass.ERR_TOPOLOGY,
            "plan axes ring and tensor both fold onto the model mesh axis; "
            "pick one per plan",
        )
        errors.check(
            not (self.stage > 1 and self.tensor > 1),
            errors.ErrorClass.ERR_TOPOLOGY,
            "the pipeline step shards over (data, stage) only; tensor "
            "parallelism does not compose with stage > 1 yet",
        )
        errors.check(
            self.expert in (1, self.tensor),
            errors.ErrorClass.ERR_TOPOLOGY,
            f"expert parallelism rides the model axis: expert ({self.expert}) "
            f"must be 1 or equal tensor ({self.tensor})",
        )
        errors.check(
            self.remat is None or self.remat in REMAT_MODES,
            errors.ErrorClass.ERR_ARG,
            f"remat must be one of {REMAT_MODES} (or None to inherit), "
            f"got {self.remat!r}",
        )
        if self.fanout is not None:
            ok = (
                isinstance(self.fanout, tuple)
                and len(self.fanout) == 2
                and all(isinstance(x, int) and x >= 1 for x in self.fanout)
            )
            errors.check(
                ok, errors.ErrorClass.ERR_ARG,
                f"fanout must be a (prefill, decode) pair of positive ints, "
                f"got {self.fanout!r}",
            )
        if self.dcn_axis is not None:
            errors.check(
                self.dcn_axis in self.fold_axes(),
                errors.ErrorClass.ERR_TOPOLOGY,
                f"dcn_axis {self.dcn_axis!r} is not a fold axis of this plan "
                f"(axes: {self.fold_axes()})",
            )

    # -- the fold (what TopologySpec.from_plan consumes) ----------------------

    def fold_dims(self) -> tuple[int, ...]:
        """Concrete fold dims, data axis first (ring/tensor share the
        ``model`` axis, so exactly one of them contributes)."""

        if self.stage > 1:
            return (self.data, self.stage)
        if self.ring > 1:
            return (self.data, self.ring)
        if self.tensor > 1:
            return (self.data, self.tensor)
        return (self.data,)

    def fold_axes(self) -> tuple[str, ...]:
        if self.stage > 1:
            return ("data", "stage")
        if self.ring > 1 or self.tensor > 1:
            return ("data", "model")
        return ("data",)

    def fold_periods(self) -> tuple[bool, ...] | None:
        """Cartesian periods, or ``None`` for a plain (non-cart) fold.  Only
        the ring is periodic — KV rotates all the way around it."""

        if self.stage > 1:
            return (False, False)
        if self.ring > 1:
            return (False, True)
        return None

    @property
    def reforms_fabric(self) -> bool:
        """Whether this plan asks for a fold beyond the communicator's own
        shape (a pure data plan adopts whatever mesh it is handed)."""

        return self.stage > 1 or self.ring > 1 or self.tensor > 1

    @property
    def total_devices(self) -> int:
        return math.prod(self.fold_dims())

    @property
    def fixed_size(self) -> int:
        """Product of the non-data (non-elastic) fold dims."""

        return math.prod(self.fold_dims()[1:])

    @property
    def cart_pset(self) -> str:
        """The ``repro://cart/<dims>`` process-set name this plan's topology
        registers (tuner winners land here)."""

        return "repro://cart/" + "x".join(str(d) for d in self.fold_dims())

    def slug(self) -> str:
        """Compact stable identifier (dry-run artifact tags, bench rows)."""

        parts = [f"d{self.data}"]
        for key, v in (("s", self.stage), ("r", self.ring),
                       ("e", self.expert), ("t", self.tensor)):
            if v > 1:
                parts.append(f"{key}{v}")
        if self.microbatches > 1:
            parts.append(f"mb{self.microbatches}")
        if self.grad_buckets > 1:
            parts.append(f"gb{self.grad_buckets}")
        if self.remat is not None:
            parts.append(f"rm-{self.remat}")
        if self.dcn_axis is not None:
            parts.append(f"dcn-{self.dcn_axis}")
        if self.fanout is not None:
            parts.append(f"f{self.fanout[0]}-{self.fanout[1]}")
        return "_".join(parts)

    @classmethod
    def from_legacy(
        cls,
        *,
        pipeline_stages: int = 0,
        pipeline_microbatches: int = 2,
        ring_attention: int = 0,
    ) -> "ParallelPlan":
        """The plan equivalent of the deprecated ``TrainerConfig`` int knobs
        (the deprecation shims construct through here)."""

        stage = pipeline_stages if pipeline_stages > 1 else 1
        ring = ring_attention if ring_attention > 1 else 1
        return cls(
            stage=stage,
            ring=ring,
            microbatches=max(1, pipeline_microbatches) if stage > 1 else 1,
        )

    def resolved(self, devices: int) -> "ParallelPlan":
        """The same plan with the data axis folded out to ``devices``
        (``ERR_DIMS`` when the fixed axes do not divide the count)."""

        fixed = self.fixed_size
        errors.check(
            devices >= fixed and devices % fixed == 0,
            errors.ErrorClass.ERR_DIMS,
            f"{devices} devices do not fold onto plan {self.slug()!r} "
            f"(fixed axes need a multiple of {fixed})",
        )
        return dataclasses.replace(self, data=devices // fixed)


_PLAN_KEYS = {
    "data": "data", "stage": "stage", "ring": "ring", "expert": "expert",
    "tensor": "tensor", "micro": "microbatches", "microbatches": "microbatches",
    "buckets": "grad_buckets", "grad_buckets": "grad_buckets",
    "remat": "remat", "dcn": "dcn_axis", "dcn_axis": "dcn_axis",
    "fanout": "fanout",
}


def parse_plan(spec: str, devices: int | None = None) -> ParallelPlan:
    """Parse a ``--plan`` argument into a :class:`ParallelPlan`.

    Two grammars (``auto`` is the caller's sentinel, not parsed here):

    * positional ``DxSxExT`` — up to four ``x``-separated ints: data,
      stage, expert, tensor (``2x4`` = 2-way data × 4 pipeline stages);
    * ``key=value`` pairs — ``data=2,ring=4,micro=2,buckets=4,remat=dots,
      dcn=stage,fanout=2:6`` (``micro``/``buckets`` are short for
      ``microbatches``/``grad_buckets``).

    When ``data`` is omitted in the key=value form and ``devices`` is
    given, the data axis fills the remaining devices.  A pipeline plan
    (``stage>1``) with no explicit microbatch count defaults to 2, matching
    the deprecated ``--pipeline-microbatches`` default.
    """

    spec = spec.strip()
    errors.check(
        bool(spec) and spec != "auto",
        errors.ErrorClass.ERR_ARG,
        f"empty or sentinel plan spec {spec!r} (resolve 'auto' via repro.tune)",
    )
    kw: dict = {}
    explicit_micro = False
    if "=" in spec:
        for part in spec.split(","):
            key, _, val = part.partition("=")
            key = key.strip().lower()
            errors.check(
                key in _PLAN_KEYS and val != "",
                errors.ErrorClass.ERR_ARG,
                f"unknown plan key {part!r} (known: {sorted(set(_PLAN_KEYS))})",
            )
            field = _PLAN_KEYS[key]
            if field == "remat":
                kw[field] = val.strip()
            elif field == "dcn_axis":
                kw[field] = val.strip()
            elif field == "fanout":
                p, _, d = val.partition(":")
                try:
                    kw[field] = (int(p), int(d))
                except ValueError:
                    errors.fail(
                        errors.ErrorClass.ERR_ARG,
                        f"fanout must be P:D (e.g. 2:6), got {val!r}",
                    )
            else:
                try:
                    kw[field] = int(val)
                except ValueError:
                    errors.fail(
                        errors.ErrorClass.ERR_ARG,
                        f"plan key {key!r} needs an int, got {val!r}",
                    )
                if field == "microbatches":
                    explicit_micro = True
    else:
        try:
            dims = [int(t) for t in spec.split("x")]
        except ValueError:
            errors.fail(
                errors.ErrorClass.ERR_ARG,
                f"plan spec {spec!r} is neither DxSxExT ints nor key=value "
                f"pairs",
            )
        errors.check(
            1 <= len(dims) <= 4,
            errors.ErrorClass.ERR_ARG,
            f"positional plan takes 1-4 dims (data[xstage[xexpert[xtensor]]]), "
            f"got {len(dims)}",
        )
        for field, v in zip(("data", "stage", "expert", "tensor"), dims):
            kw[field] = v
    # expert rides the model axis: an expert-only request implies tensor
    if kw.get("expert", 1) > 1 and "tensor" not in kw:
        kw["tensor"] = kw["expert"]
    if kw.get("stage", 1) > 1 and not explicit_micro:
        kw.setdefault("microbatches", 2)
    if "data" not in kw and devices is not None:
        fixed = (
            max(1, kw.get("stage", 1))
            * max(1, kw.get("ring", 1))
            * max(1, kw.get("tensor", 1))
        )
        errors.check(
            devices % fixed == 0,
            errors.ErrorClass.ERR_DIMS,
            f"{devices} devices do not fold onto plan {spec!r} "
            f"(fixed axes multiply to {fixed})",
        )
        kw["data"] = devices // fixed
    return ParallelPlan(**kw)


# -- per-arch legal plan space ------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PlanSpace:
    """The axis values the tuner may enumerate for one architecture.  A
    declaration, not a guarantee: :func:`legal_plans` still filters every
    combination against the model/shape/device constraints."""

    stages: tuple[int, ...] = (1, 2, 4, 8)
    rings: tuple[int, ...] = (1, 2, 4, 8)
    experts: tuple[int, ...] = (1,)
    tensors: tuple[int, ...] = (1, 2, 4, 8)
    microbatches: tuple[int, ...] = (1, 2, 4, 8)
    grad_buckets: tuple[int, ...] = (1, 2, 4)
    remats: tuple[str, ...] = ("none", "full")


def plan_space(arch: str) -> PlanSpace:
    """The per-arch legal-space declaration: the arch module's own
    ``plan_space()`` when it declares one, else a family-derived default
    (SSM/hybrid models have no attention ring to shard; MoE models get the
    expert axis up to their expert count)."""

    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    declared = getattr(mod, "plan_space", None)
    if declared is not None:
        return declared()
    cfg = mod.config()
    space = PlanSpace()
    if cfg.family in ("ssm", "hybrid"):
        space = dataclasses.replace(space, rings=(1,))
    if cfg.num_experts:
        space = dataclasses.replace(
            space,
            experts=tuple(
                e for e in (1, 2, 4, 8) if cfg.num_experts % e == 0
            ),
        )
    return space


def legal_plans(
    cfg: ModelConfig,
    shape: ShapeConfig,
    devices: int,
    space: PlanSpace | None = None,
    *,
    slices: int = 1,
) -> list[ParallelPlan]:
    """Every plan in ``space`` that is legal for this (arch × shape ×
    device-count) cell, deterministic order.

    Filters: the cell must be applicable at all (:func:`shape_applicable`);
    the fixed axes must divide the device count (the data axis — the one
    elastic axis — fills the rest); pipeline stages must divide the layer
    stack; the ring must divide the sequence and only shard real attention;
    tensor must divide the head count; experts ride the model axis; the
    per-device batch must split over the microbatches.  On multi-slice
    (multi-pod) folds, each legal plan is emitted once per admissible
    ``dcn_axis`` — an axis whose size divides over the slice count — so
    which fold crosses DCN is scored deliberately, never defaulted.
    """

    ok, _ = shape_applicable(cfg, shape)
    if not ok or devices < 1:
        return []
    space = space or PlanSpace()
    is_train = shape.kind == "train"
    plans: list[ParallelPlan] = []
    micro_opts = space.microbatches if is_train else (1,)
    bucket_opts = space.grad_buckets if is_train else (1,)
    remat_opts = space.remats if is_train else (None,)
    stage_opts = space.stages if is_train else (1,)
    for s, r, e, t in itertools.product(
        stage_opts, space.rings, space.experts, space.tensors
    ):
        if sum(x > 1 for x in (s, r, t)) > 1:
            continue                      # one re-formed fabric per trainer
        if e > 1 and e != t:
            continue                      # expert rides the model axis
        if s > 1 and cfg.num_layers % s != 0:
            continue
        if r > 1 and (
            cfg.family in ("ssm", "hybrid") or shape.seq_len % r != 0
        ):
            continue
        if t > 1 and cfg.num_heads % t != 0:
            continue
        if e > 1 and (not cfg.num_experts or cfg.num_experts % e != 0):
            continue
        fixed = s * max(r, 1) * max(t, 1)
        if devices % fixed != 0:
            continue
        d = devices // fixed
        for m in micro_opts:
            if s > 1 and m < 2:
                continue                  # a 1-deep pipeline never overlaps
            local_batch = shape.global_batch // d
            if (
                is_train
                and (shape.global_batch % d != 0 or local_batch % m != 0)
            ):
                continue
            for b in bucket_opts:
                for remat in remat_opts:
                    base = ParallelPlan(
                        data=d, stage=s, ring=r, expert=e, tensor=t,
                        microbatches=m, grad_buckets=b, remat=remat,
                    )
                    if slices <= 1:
                        plans.append(base)
                        continue
                    axes = base.fold_axes()
                    dims = base.fold_dims()
                    dcn_opts = [
                        a for a, n in zip(axes, dims)
                        if n > 1 and n % slices == 0
                    ]
                    for dcn in dcn_opts or [None]:
                        plans.append(
                            dataclasses.replace(base, dcn_axis=dcn)
                        )
    return plans


# -- registry ----------------------------------------------------------------

ARCHITECTURES = (
    "qwen1_5_32b",
    "phi4_mini_3_8b",
    "gemma2_9b",
    "granite_3_8b",
    "seamless_m4t_large_v2",
    "paligemma_3b",
    "grok_1_314b",
    "deepseek_v2_236b",
    "zamba2_7b",
    "mamba2_2_7b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHITECTURES}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.config()


def get_parallel(arch: str, multi_pod: bool = False) -> ParallelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    p: ParallelConfig = mod.parallel()
    if multi_pod:
        p.data_axes = ("pod", "data")
    return p


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""

    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch × shape) cell runs; reason if skipped (DESIGN.md §5)."""

    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k requires sub-quadratic attention state (full-attention arch)"
    return True, ""
