"""Configuration schema: model, parallelism and workload shapes.

Every assigned architecture is a :class:`ModelConfig` in its own module
(``repro/configs/<id>.py``); the four workload shapes are fixed
:class:`ShapeConfig` instances; :class:`ParallelConfig` carries the
distribution plan (which the dry-run and the perf hillclimb toggle).
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.core.descriptors import Compression


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention flavour
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    attn_logit_softcap: float | None = None
    final_logit_softcap: float | None = None
    sliding_window: int | None = None
    layer_pattern: str = "uniform"   # uniform | local_global (gemma-2 alternation)
    post_norms: bool = False         # gemma-2 pre+post sandwich norms
    query_scale: float | None = None  # override 1/sqrt(head_dim)

    # embedding / head
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d_model) input scaling

    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0
    capacity_factor: float = 1.25

    # MLA (deepseek-v2)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba-2)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    attn_every: int = 0              # hybrid: shared attention block period

    # enc-dec
    encoder_layers: int = 0

    # vlm
    num_image_tokens: int = 0
    prefix_lm: bool = False

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    act: str = "silu"

    # provenance
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to 256 so the logit dimension shards over any mesh
        axis; synthetic labels are drawn below ``vocab_size``."""

        return _round_up(self.vocab_size, 256)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS and reporting)."""

        d, L = self.d_model, self.num_layers
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "vlm", "encdec"):
            if self.mla:
                attn = (
                    d * self.q_lora
                    + self.q_lora * self.num_heads * (self.nope_head_dim + self.rope_head_dim)
                    + d * (self.kv_lora + self.rope_head_dim)
                    + self.kv_lora * self.num_heads * (self.nope_head_dim + self.v_head_dim)
                    + self.num_heads * self.v_head_dim * d
                )
            else:
                attn = (
                    d * self.num_heads * self.head_dim
                    + 2 * d * self.num_kv_heads * self.head_dim
                    + self.num_heads * self.head_dim * d
                )
            if self.num_experts:
                moe_l = L - self.first_dense_layers
                shared = self.num_shared_experts * 3 * d * self.moe_d_ff
                routed = self.num_experts * 3 * d * self.moe_d_ff
                router = d * self.num_experts
                mlp_total = (
                    moe_l * (shared + routed + router)
                    + self.first_dense_layers * 3 * d * self.d_ff
                )
            else:
                mlp_total = L * 3 * d * self.d_ff
            per_layer_total = L * attn + mlp_total + L * 2 * d
            if self.family == "encdec":
                # encoder layers + decoder cross-attention
                enc = self.encoder_layers * (attn + 3 * d * self.d_ff + 2 * d)
                cross = L * attn
                per_layer_total += enc + cross
            if self.family == "vlm":
                per_layer_total += 1152 * d  # SigLIP-stub multimodal projector
            return emb + per_layer_total
        if self.family == "ssm":
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            blk = (
                d * (2 * di + 2 * self.ssm_groups * ns + nh)   # in_proj
                + self.ssm_conv * (di + 2 * self.ssm_groups * ns)
                + di * d                                        # out_proj
                + 2 * nh + di + d                               # A, D, dt_bias(+norm)
            )
            return emb + L * blk + L * d
        if self.family == "hybrid":
            di, ns, nh = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            blk = (
                d * (2 * di + 2 * self.ssm_groups * ns + nh)
                + self.ssm_conv * (di + 2 * self.ssm_groups * ns)
                + di * d
                + 2 * nh + di + d
            )
            attn = (
                d * self.num_heads * self.head_dim * 2
                + 2 * d * self.num_kv_heads * self.head_dim
                + 3 * d * self.d_ff
                + 4 * d
            )
            return emb + L * (blk + d) + attn  # one shared attention block
        raise ValueError(self.family)

    def active_param_count(self) -> int:
        """Active (per-token) parameters for MoE (6·N_active·D FLOPs)."""

        if not self.num_experts:
            return self.param_count()
        total = self.param_count()
        d = self.d_model
        moe_l = self.num_layers - self.first_dense_layers
        routed_all = moe_l * self.num_experts * 3 * d * self.moe_d_ff
        routed_active = moe_l * self.moe_top_k * 3 * d * self.moe_d_ff
        return total - routed_all + routed_active


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass
class ParallelConfig:
    """Distribution plan; the hillclimb toggles live here."""

    data_axes: tuple[str, ...] = ("data",)
    model_axis: str = "model"

    fsdp: bool = True                    # params/opt-state sharded over data_axes
    attn_plan: str = "tp_heads"          # tp_heads | sp (sequence-parallel attention)
    attn_impl: str = "ref"               # ref | chunked (online-softmax) | pallas[_tpu]
    shard_experts: bool = False          # EP: expert dim over model_axis
    moe_dispatch: str = "global"         # global | per_row (data-local dispatch)
    seq_shard_cache: bool = False        # decode: KV cache sharded over sequence
    flash_decode_merge: bool = False     # + exact partial-softmax merge (optimized)
    ring_attention: bool = False         # training SP via ring schedule (optimized)
    overlap_fsdp: bool = False           # all_gather_matmul futures (optimized)
    compression: Compression = Compression.NONE  # cross-pod grad payloads
    remat: str = "full"                  # none | full | dots
    microbatches: int = 1                # gradient-accumulation splits of the global batch
    kv_cache_dtype: str = "bfloat16"     # bfloat16 | int8
    moment_dtype: str = "float32"        # float32 | int8 (8-bit Adam moments)
    scan_layers: bool = True

    @property
    def all_data_axes(self) -> tuple[str, ...]:
        return self.data_axes


# -- registry ----------------------------------------------------------------

ARCHITECTURES = (
    "qwen1_5_32b",
    "phi4_mini_3_8b",
    "gemma2_9b",
    "granite_3_8b",
    "seamless_m4t_large_v2",
    "paligemma_3b",
    "grok_1_314b",
    "deepseek_v2_236b",
    "zamba2_7b",
    "mamba2_2_7b",
)

_ALIASES = {a.replace("_", "-"): a for a in ARCHITECTURES}


def get_config(arch: str) -> ModelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.config()


def get_parallel(arch: str, multi_pod: bool = False) -> ParallelConfig:
    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    p: ParallelConfig = mod.parallel()
    if multi_pod:
        p.data_axes = ("pod", "data")
    return p


def get_smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""

    arch = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.smoke_config()


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch × shape) cell runs; reason if skipped (DESIGN.md §5)."""

    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k requires sub-quadratic attention state (full-attention arch)"
    return True, ""
