"""granite-3-8b — dense GQA transformer.

[hf:ibm-granite/granite-3.0-8b-base; hf-verified family]  40L d_model=4096
32H (GQA kv=8) d_ff=12800 vocab=49155, RoPE, SwiGLU, tied embeddings.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite_3_8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49_155,
        tie_embeddings=True,
        rope_theta=10_000.0,
        act="silu",
        source="hf:ibm-granite/granite-3.0-8b-base",
    )


def parallel() -> ParallelConfig:
    # 32 heads / 16 = 2 per shard — clean head TP; d_ff 12800 = 16·800.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite_3_8b_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        tie_embeddings=True,
    )
