"""grok-1-314b — 8-expert top-2 MoE transformer.

[hf:xai-org/grok-1; unverified]  64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, 8 experts top-2, attention + final logit softcap 30.
"""

from repro.configs.base import ModelConfig, ParallelConfig, PlanSpace


def plan_space() -> PlanSpace:
    # 64 layers pipeline cleanly to 8 stages; 48 heads cap tensor at 8
    # (16 would split a head).  Experts stay replicated — 8 experts shard
    # each expert's d_ff via TP rather than true EP (see parallel()).
    return PlanSpace(
        stages=(1, 2, 4, 8),
        rings=(1, 2, 4),
        tensors=(1, 2, 4, 8),
        grad_buckets=(1, 2, 4, 8),
        remats=("full",),
    )


def config() -> ModelConfig:
    return ModelConfig(
        name="grok_1_314b",
        family="moe",
        num_layers=64,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=32768,
        vocab_size=131_072,
        num_experts=8,
        moe_top_k=2,
        moe_d_ff=32768,
        attn_logit_softcap=30.0,
        final_logit_softcap=30.0,
        act="gelu",
        source="hf:xai-org/grok-1 (unverified)",
    )


def parallel() -> ParallelConfig:
    # 8 experts < 16 model shards → EP does not divide; TP shards each
    # expert's d_ff (32768 = 16·2048) instead, with FSDP over the 8-expert dim.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", shard_experts=False, remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="grok_1_314b_smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        moe_top_k=2,
        moe_d_ff=128,
        attn_logit_softcap=30.0,
        final_logit_softcap=30.0,
        act="gelu",
    )
