"""qwen1.5-32b — dense GQA transformer with QKV bias.

[hf:Qwen/Qwen1.5-32B; hf-verified family]  64L d_model=5120 40H (kv=40)
d_ff=27392 vocab=152064, RoPE, SwiGLU, QKV bias.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1_5_32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=40,
        num_kv_heads=40,
        head_dim=128,
        d_ff=27392,
        vocab_size=152064,
        qkv_bias=True,
        rope_theta=1_000_000.0,
        act="silu",
        source="hf:Qwen/Qwen1.5-32B",
    )


def parallel() -> ParallelConfig:
    # 40 heads do not divide 16 → heads replicate on 'model'; TP lands on
    # d_ff (27392 = 16·1712) and the vocab.  FSDP shards everything else.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1_5_32b_smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=160,
        vocab_size=512,
        qkv_bias=True,
        rope_theta=1_000_000.0,
    )
