"""seamless-m4t-large-v2 — encoder-decoder speech/text transformer backbone.

[arXiv:2308.11596; hf-verified]  24L (encoder) + 24L (decoder) d_model=1024
16H (kv=16) d_ff=8192 vocab=256206.  The modality frontend (w2v-BERT speech
encoder feature extractor) is a STUB: ``input_specs()`` provides precomputed
frame embeddings of dimension d_model.
"""

from repro.configs.base import ModelConfig, ParallelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_large_v2",
        family="encdec",
        num_layers=24,            # decoder layers
        encoder_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256_206,
        tie_embeddings=True,     # the enc-dec trunk shares embed/output proj
        rope_theta=10_000.0,
        act="relu",
        source="arXiv:2308.11596 (hf:facebook/seamless-m4t-v2-large)",
    )


def parallel() -> ParallelConfig:
    # 16 heads = model axis size: one head per model shard.
    return ParallelConfig(fsdp=True, attn_plan="tp_heads", remat="full")


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless_m4t_large_v2_smoke",
        family="encdec",
        num_layers=2,
        encoder_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        tie_embeddings=True,
        act="relu",
    )
