"""Input stand-ins for the dry-run: every model input as a
``jax.ShapeDtypeStruct`` (weak-type-correct, shardable, no allocation).

``input_specs(arch, shape)`` returns the kwargs for the matching step
function (``train_step`` / ``prefill_step`` / ``decode_step``), so the
dry-run is literally::

    jax.jit(step, in_shardings=..., out_shardings=...).lower(**input_specs(...))
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base
from repro.models import api as model_api
from repro.optim import AdamW
from repro.sharding import rules

SIGLIP_DIM = 1152  # the VLM vision-stub feature width (SigLIP-So400m)


def _sds(tree: Any) -> Any:
    """Normalise an eval_shape result to plain ShapeDtypeStructs."""

    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def batch_structs(cfg: base.ModelConfig, shape: base.ShapeConfig, *, with_labels: bool) -> dict:
    """The input-batch stand-in for a full-sequence (train/prefill) step."""

    B, S = shape.global_batch, shape.seq_len
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    batch: dict[str, Any] = {"tokens": tok}
    if cfg.family == "vlm":
        # text tokens + precomputed patch embeddings; total trunk length is
        # num_image_tokens + S_text = S
        batch["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.num_image_tokens), jnp.int32)
        batch["image_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_image_tokens, SIGLIP_DIM), jnp.bfloat16
        )
    if cfg.family == "encdec":
        # precomputed frame embeddings (modality-frontend stub), source
        # length == target length == S (DESIGN.md §5)
        batch["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
    if with_labels:
        batch["labels"] = jax.ShapeDtypeStruct(batch["tokens"].shape, jnp.int32)
    return batch


def param_structs(bundle) -> Any:
    return _sds(jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0))))


def opt_structs(opt: AdamW, params: Any) -> Any:
    return _sds(jax.eval_shape(opt.init, params))


def cache_structs(bundle, cfg, pcfg, shape: base.ShapeConfig) -> Any:
    """Decode-cell cache stand-ins (KV / MLA latent / SSM state / hybrid)."""

    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        def mk():
            params = bundle.init(jax.random.PRNGKey(0))
            b = {
                "frames": jnp.zeros((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": jnp.zeros((B, S), jnp.int32),
            }
            _, cache = bundle.prefill(params, b, pcfg)
            return cache

        return _sds(jax.eval_shape(mk))
    return _sds(jax.eval_shape(lambda: bundle.init_cache(pcfg, B, S)))


def token_struct(shape: base.ShapeConfig) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


# ---------------------------------------------------------------------------
# assembled per-cell kwargs + shardings
# ---------------------------------------------------------------------------


def input_specs(
    arch: str,
    shape_name: str,
    mesh,
    pcfg: base.ParallelConfig | None = None,
    *,
    opt: AdamW | None = None,
):
    """(kwargs, in_shardings, out_shardings builder inputs) for one cell.

    Returns ``(step_kind, kwargs, in_shardings)`` where ``kwargs`` feeds
    ``.lower(**kwargs)``.
    """

    cfg = base.get_config(arch)
    shape = base.SHAPES[shape_name]
    pcfg = pcfg or base.get_parallel(arch, multi_pod="pod" in mesh.axis_names)
    bundle = model_api.build(cfg)

    params = param_structs(bundle)
    pshard = rules.shardings(rules.param_specs(params, mesh, pcfg), mesh)

    if shape.kind == "train":
        opt = opt or AdamW(lr=1e-4, moment_dtype=pcfg.moment_dtype)
        opt_state = opt_structs(opt, params)
        oshard = _moment_shardings(params, pshard, opt_state, mesh)
        batch = batch_structs(cfg, shape, with_labels=True)
        bshard = rules.shardings(rules.batch_spec(batch, mesh, pcfg), mesh)
        kwargs = {"params": params, "opt_state": opt_state, "batch": batch}
        inshard = {"params": pshard, "opt_state": oshard, "batch": bshard}
        return "train", kwargs, inshard

    if shape.kind == "prefill":
        batch = batch_structs(cfg, shape, with_labels=False)
        bshard = rules.shardings(rules.batch_spec(batch, mesh, pcfg), mesh)
        kwargs = {"params": params, "batch": batch}
        inshard = {"params": pshard, "batch": bshard}
        return "prefill", kwargs, inshard

    # decode
    cache = cache_structs(bundle, cfg, pcfg, shape)
    cshard = rules.shardings(rules.cache_specs(cache, mesh, pcfg, cfg), mesh)
    tok = token_struct(shape)
    tshard = rules.shardings(rules.batch_spec({"t": tok}, mesh, pcfg), mesh)["t"]
    kwargs = {"params": params, "cache": cache, "token": tok}
    inshard = {"params": pshard, "cache": cshard, "token": tshard}
    return "decode", kwargs, inshard


def _moment_shardings(params, pshard, opt_state, mesh):
    """Adam moments inherit the matching parameter's sharding (ZeRO).

    int8 moments are stored as FLATTENED ``_Q8`` payloads whose shapes match
    no parameter; replicating them costs 2·N bytes/device (observed: 642
    GB/device for grok-1) — instead shard the flat payload over every mesh
    axis it divides (blocks are 256-padded, so 256-chip divisibility holds).
    """

    from jax.sharding import NamedSharding, PartitionSpec as P

    by_shape = {}
    for leaf, sh in zip(jax.tree.leaves(params), jax.tree.leaves(pshard)):
        by_shape.setdefault(tuple(np.shape(leaf)), sh)

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def flat_spec(n: int) -> P:
        axes = []
        total = 1
        for a, s in axis_sizes.items():
            if n % (total * s) == 0:
                axes.append(a)
                total *= s
        return P(tuple(axes)) if axes else P()

    def shard_for(leaf):
        shape = tuple(np.shape(leaf))
        hit = by_shape.get(shape)
        if hit is not None:
            return hit
        if len(shape) == 1 and shape[0] >= 1024:
            return NamedSharding(mesh, flat_spec(shape[0]))
        return NamedSharding(mesh, P())

    return jax.tree.map(shard_for, opt_state)
