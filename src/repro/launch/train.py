"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch gemma2_9b --smoke \
        --steps 50 --batch 8 --seq 256 --mesh 1x1

Runs the full Trainer (checkpoint/restart, straggler guard, fault injection)
on whatever devices exist; ``--smoke`` selects the reduced same-family config
so the loop runs on CPU.  The production 256/512-chip lowering of the same
step function is exercised by ``repro.launch.dryrun``.
"""

from __future__ import annotations

import argparse
import json
import logging


def resolve_plan(args, cfg, devices):
    """One parser for every layout flag: ``--plan`` wins (``auto`` runs the
    repro.tune roofline search for this cell); the deprecated
    ``--pipeline-stages``/``--ring-attention`` flags are aliases that build
    the equivalent spec and route through :func:`repro.configs.base.parse_plan`.
    Returns ``None`` (pure data plan) when nothing asked for a fold."""

    from repro.configs import base

    if args.plan:
        if args.plan == "auto":
            from repro import tune as tune_mod

            shape = base.ShapeConfig(
                f"train_{args.seq}", args.seq, args.batch, "train"
            )
            result = tune_mod.tune(
                args.arch, shape, devices, config=cfg,
                space=base.plan_space(args.arch),
            )
            logging.getLogger("repro.launch").info(
                "autotuned plan: %s (predicted %.4fs over %d candidates)",
                result.plan.slug(), result.score.step_s, result.n_candidates,
            )
            return result.plan
        return base.parse_plan(args.plan, devices=devices)
    parts = []
    if args.pipeline_stages > 1:
        parts.append(f"stage={args.pipeline_stages}")
        parts.append(f"micro={args.pipeline_microbatches}")
    if args.ring_attention > 1:
        parts.append(f"ring={args.ring_attention}")
    if parts:
        return base.parse_plan(",".join(parts), devices=devices)
    return None


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced same-family config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--mesh", default="auto", help="DxM, e.g. 2x4 (auto: all devices x 1)")
    ap.add_argument(
        "--pset",
        default="repro://world",
        help="session process set the trainer owns (e.g. repro://host/0)",
    )
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument(
        "--async-checkpoint",
        action=argparse.BooleanOptionalAction,
        default=True,
        help="checkpoint writes ride the I/O request engine and overlap the "
        "next persistent step (--no-async-checkpoint joins each save)",
    )
    ap.add_argument(
        "--plan",
        default=None,
        help="the unified parallelism plan: 'auto' (run the repro.tune "
        "roofline autotuner for this cell), positional dims 'DxSxExT' "
        "(e.g. '2x4' = 2-way data x 4 pipeline stages), or key=value pairs "
        "'data=2,ring=4,micro=2,buckets=4,remat=dots'",
    )
    ap.add_argument(
        "--pipeline-stages",
        type=int,
        default=0,
        help="alias for --plan stage=N (same parser; 0/1 = GSPMD step)",
    )
    ap.add_argument(
        "--pipeline-microbatches",
        type=int,
        default=2,
        help="alias for --plan micro=N (with --pipeline-stages)",
    )
    ap.add_argument(
        "--ring-attention",
        type=int,
        default=0,
        help="alias for --plan ring=N: a periodic cart ring folded onto the "
        "model axis; attention shards the sequence over the ring and "
        "rotates KV via cart_shift(+1) permutes (0/1 = dense attn)",
    )
    ap.add_argument("--inject-failure-at", type=int, default=None)
    ap.add_argument(
        "--evict-at",
        default=None,
        metavar="STEP:RANK",
        help="elastic fault drill: evict RANK at STEP; the trainer shrinks "
        "its epoch to the survivors, restores the last committed manifest "
        "and continues — no job restart",
    )
    ap.add_argument(
        "--admit-at",
        default=None,
        metavar="STEP[:COUNT]",
        help="elastic grow drill: hot-join COUNT spare ranks (default 1) at "
        "STEP, re-folding the data axis",
    )
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--out", default=None, help="write metrics history JSON here")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(name)s %(message)s")

    from repro.configs import base
    from repro.launch.mesh import make_host_communicator
    from repro.runtime.faults import FaultInjector
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = base.get_smoke_config(args.arch) if args.smoke else base.get_config(args.arch)
    pcfg = base.get_parallel(args.arch)
    if args.mesh == "auto":
        comm = make_host_communicator(pset=args.pset)
    else:
        d, m = (int(t) for t in args.mesh.split("x"))
        comm = make_host_communicator(d, m, pset=args.pset)

    plan = resolve_plan(args, cfg, comm.group().size())
    tcfg = TrainerConfig(
        steps=args.steps,
        lr=args.lr,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every or max(1, args.steps // 2),
        async_checkpoint=args.async_checkpoint,
        log_every=args.log_every,
        plan=plan,
    )
    injector = None
    if args.inject_failure_at is not None:
        injector = FaultInjector(fail_at_steps=(args.inject_failure_at,))
    if args.evict_at is not None:
        step, _, rank = args.evict_at.partition(":")
        injector = (injector or FaultInjector()).evict_rank(int(step), int(rank or 0))
    if args.admit_at is not None:
        step, _, count = args.admit_at.partition(":")
        injector = (injector or FaultInjector()).admit_rank(int(step), int(count or 1))
    trainer = Trainer(
        cfg, pcfg, tcfg, comm, seq_len=args.seq, global_batch=args.batch, injector=injector
    )
    result = trainer.run()
    print(json.dumps({k: v for k, v in result.items() if k != "metrics"}, indent=1))
    if result["metrics"]:
        first, last = result["metrics"][0], result["metrics"][-1]
        print(f"loss: {first['loss']:.4f} -> {last['loss']:.4f}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
