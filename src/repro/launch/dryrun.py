import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init).  This module is the multi-pod dry-run driver: it
# lowers + compiles every (architecture x input-shape) cell on the production
# mesh, prints memory_analysis()/cost_analysis(), and records the roofline
# terms the perf loop consumes.
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma2_9b --shape train_4k
#   python -m repro.launch.dryrun --arch gemma2_9b --shape train_4k --multi-pod
#   python -m repro.launch.dryrun --all [--jobs 3] [--multi-pod]
#   python -m repro.launch.dryrun --all --both   # single- and multi-pod
#
# Each cell writes artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json; the
# orchestrator (--all) skips cells whose artifact already exists (incremental,
# crash-safe), running each cell in a subprocess.

import argparse
import dataclasses
import json
import math
import subprocess
import sys
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_ARG_ORDER = {
    "train": ("params", "opt_state", "batch"),
    "prefill": ("params", "batch"),
    "decode": ("params", "cache", "token"),
}
_DONATE = {"train": (0, 1), "prefill": (), "decode": (1,)}


def run_cell(arch: str, shape_name: str, multi_pod: bool, overrides: dict, tag: str,
             plan_spec: str | None = None) -> dict:
    import jax

    from repro.configs import base
    from repro.core import tool
    from repro.launch import mesh as mesh_mod
    from repro.launch import specs as specs_mod
    from repro.launch import steps as steps_mod
    from repro.optim import AdamW

    cfg = base.get_config(arch)
    shape = base.SHAPES[shape_name]
    ok, reason = base.shape_applicable(cfg, shape)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    record: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "tag": tag,
        "overrides": overrides,
    }
    if not ok:
        record.update(status="skipped", reason=reason)
        return record

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    pcfg = base.get_parallel(arch, multi_pod=multi_pod)
    for k, v in overrides.items():
        if not hasattr(pcfg, k):
            raise KeyError(f"unknown ParallelConfig field {k!r}")
        setattr(pcfg, k, v)

    opt = AdamW(lr=1e-4, moment_dtype=pcfg.moment_dtype)
    kind, kwargs, inshard = specs_mod.input_specs(arch, shape_name, mesh, pcfg, opt=opt)
    step = steps_mod.make_step(kind, cfg, pcfg, opt)

    order = _ARG_ORDER[kind]
    args = tuple(kwargs[k] for k in order)
    in_shardings = tuple(inshard[k] for k in order)
    out_shardings = None
    if kind == "train":
        out_shardings = (inshard["params"], inshard["opt_state"], None)
    elif kind == "decode":
        out_shardings = (None, inshard["cache"])
    elif kind == "prefill":
        # pin the output KV/SSM cache sharding (otherwise GSPMD has been
        # observed to replicate it over the model axis — §Perf A3)
        from repro.sharding import rules

        out_struct = jax.eval_shape(step, *args)
        cshard = rules.shardings(
            rules.cache_specs(out_struct[1], mesh, pcfg, cfg), mesh
        )
        out_shardings = (None, cshard)

    t0 = time.time()
    with mesh:
        jitted = jax.jit(
            step,
            in_shardings=in_shardings,
            out_shardings=out_shardings,
            donate_argnums=_DONATE[kind],
        )
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # -- memory analysis (proves it fits) ------------------------------------
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for f in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            if hasattr(ma, f):
                mem[f] = int(getattr(ma, f))
        mem["peak_bytes_per_device"] = (
            mem.get("argument_size_in_bytes", 0)
            + mem.get("output_size_in_bytes", 0)
            + mem.get("temp_size_in_bytes", 0)
            - mem.get("alias_size_in_bytes", 0)
        )
        print("memory_analysis:", mem)
    except Exception as e:  # pragma: no cover  # lint: allow-broad-except — recorded in the artifact
        mem["error"] = repr(e)

    # -- cost analysis + roofline (per-device module) -------------------------
    # cost_analysis() counts while bodies ONCE (verified; see
    # core/hloanalysis.py) — the corrected, trip-count-aware walk is the
    # number the roofline uses; raw is recorded for comparison.
    from repro.core import hloanalysis

    hlo = compiled.as_text()
    raw = tool.roofline_terms(compiled, hlo_text=hlo, chips=1)
    cost = hloanalysis.analyze_hlo(hlo)
    terms = {
        "compute_s": cost.flops / tool.PEAK_FLOPS_BF16,
        "memory_s": cost.bytes / tool.HBM_BANDWIDTH,
        "collective_s": cost.collectives.total_operand_bytes / tool.ICI_BANDWIDTH,
        "collective_wire_s": cost.collectives.total_wire_bytes / tool.ICI_BANDWIDTH,
        "hlo_flops": cost.flops,
        "hlo_bytes": cost.bytes,
        "collectives": cost.collectives.as_dict(),
    }
    terms["dominant"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    print("corrected: flops=%.3e bytes=%.3e coll=%.3e | raw cost_analysis: flops=%.3e"
          % (cost.flops, cost.bytes, cost.collectives.total_operand_bytes, raw["hlo_flops"]))

    # useful-model-FLOPs ratio
    n_active = cfg.active_param_count()
    tokens = {
        "train": shape.global_batch * shape.seq_len,
        "prefill": shape.global_batch * shape.seq_len,
        "decode": shape.global_batch,
    }[kind]
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_active * tokens
    hlo_flops_global = terms["hlo_flops"] * chips
    record.update(
        status="ok",
        kind=kind,
        chips=chips,
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        memory=mem,
        roofline=terms,
        roofline_raw_uncorrected=raw,
        model_flops=model_flops,
        hlo_flops_global=hlo_flops_global,
        useful_flop_ratio=(model_flops / hlo_flops_global) if hlo_flops_global else None,
        params=cfg.param_count(),
        active_params=n_active,
        n_hlo_lines=hlo.count("\n"),
    )

    # --plan: record the candidate's ANALYTIC roofline next to the measured
    # cell terms, so the tuner's predicted-vs-measured validation
    # (repro.tune.predicted_vs_measured, benchmarks/roofline.py --regret)
    # reads both sides from one artifact
    if plan_spec:
        from repro.tune import score as tune_score

        plan = (
            base.parse_plan(plan_spec, devices=chips)
            if plan_spec != "auto"
            else None
        )
        if plan is None:
            from repro.tune import search as tune_search

            plan = tune_search.search(
                cfg, shape, chips, space=base.plan_space(arch),
                default_remat=pcfg.remat,
            ).plan
        predicted = tune_score.score_plan(
            cfg, shape, plan, default_remat=pcfg.remat
        )
        record.update(
            plan=dataclasses.asdict(plan),
            plan_slug=plan.slug(),
            predicted_roofline=predicted.as_dict(),
        )
    return record


def artifact_path(arch: str, shape: str, multi_pod: bool, tag: str) -> Path:
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    stem = f"{arch}__{shape}__{mesh_name}" + (f"__{tag}" if tag else "")
    return ARTIFACTS / f"{stem}.json"


def _cell_done(path: Path, overrides: dict, tag: str) -> bool:
    """Incremental-skip key: the cell is done only when the artifact on disk
    was produced by the SAME (overrides, tag) request.  Existence alone used
    to be the key, so ``--all --overrides ...`` silently reused artifacts
    recorded under different overrides."""

    if not path.exists():
        return False
    try:
        rec = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):
        return False          # unreadable/torn artifact: rerun the cell
    return rec.get("overrides", {}) == overrides and rec.get("tag", "") == tag


def _cell_cmd(arch, shape, multi_pod, overrides, tag):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch, "--shape", shape]
    if multi_pod:
        cmd.append("--multi-pod")
    if overrides:
        cmd += ["--overrides", json.dumps(overrides)]
    if tag:
        cmd += ["--tag", tag]
    return cmd


def orchestrate(jobs: int, multi_pod_modes: list[bool], overrides: dict, tag: str,
                archs=None, shapes=None, timeout: int = 3600):
    from repro.configs import base

    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    cells = []
    for mp in multi_pod_modes:
        for arch in archs or base.ARCHITECTURES:
            for shape in shapes or list(base.SHAPES):
                p = artifact_path(arch, shape, mp, tag)
                if _cell_done(p, overrides, tag):
                    continue
                cells.append((arch, shape, mp))
    print(f"{len(cells)} cells to run ({jobs} workers)")

    def one(cell):
        arch, shape, mp = cell
        t0 = time.time()
        proc = subprocess.run(
            _cell_cmd(arch, shape, mp, overrides, tag),
            capture_output=True, text=True, timeout=timeout,
            env={**os.environ, "PYTHONPATH": "src"},
            cwd=str(Path(__file__).resolve().parents[3]),
        )
        status = "ok" if proc.returncode == 0 else "FAIL"
        print(f"[{status}] {arch} {shape} mp={mp} ({time.time()-t0:.0f}s)")
        if proc.returncode != 0:
            tail = "\n".join(proc.stdout.splitlines()[-5:] + proc.stderr.splitlines()[-15:])
            print(tail)
        return proc.returncode

    with ThreadPoolExecutor(max_workers=jobs) as ex:
        rcs = list(ex.map(one, cells))
    print(f"done: {rcs.count(0)}/{len(rcs)} ok")
    return 0 if all(r == 0 for r in rcs) else 1


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true", help="--all over both meshes")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=3)
    ap.add_argument("--overrides", default="{}", help="ParallelConfig overrides (JSON)")
    ap.add_argument("--tag", default="", help="artifact suffix for perf experiments")
    ap.add_argument(
        "--plan",
        default=None,
        help="record this ParallelPlan candidate's analytic roofline terms "
        "in the artifact ('auto' = the repro.tune winner for the cell); "
        "the artifact tag defaults to the plan slug",
    )
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args(argv)
    overrides = json.loads(args.overrides)

    if args.all:
        modes = [False, True] if args.both else [args.multi_pod]
        archs = [args.arch] if args.arch else None
        shapes = [args.shape] if args.shape else None
        return orchestrate(args.jobs, modes, overrides, args.tag, archs, shapes, args.timeout)

    assert args.arch and args.shape, "--arch and --shape required (or --all)"
    tag = args.tag
    if args.plan and not tag:
        # per-candidate artifacts must not clobber the base cell
        tag = "plan-" + (args.plan if args.plan != "auto" else "auto").replace(
            ",", "_").replace("=", "-").replace(":", "-")
    try:
        record = run_cell(args.arch, args.shape, args.multi_pod, overrides, tag,
                          plan_spec=args.plan)
    except Exception:
        traceback.print_exc()
        return 1
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    path = artifact_path(args.arch, args.shape, args.multi_pod, tag)
    path.write_text(json.dumps(record, indent=1))
    print("wrote", path, "status:", record["status"])
    return 0


if __name__ == "__main__":
    sys.exit(main())
