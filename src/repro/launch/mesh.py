"""Production communicators (session-derived) and their meshes.

Construction is session-first: every entry point opens (or is handed) a
:class:`repro.core.session.Session`, picks a named process set, refines it
with the group algebra, and builds the communicator with
``Communicator.from_group`` — so train/serve/IO workloads can each own a
communicator over a *declared subset* of the platform instead of all
sharing ``world()``.

``make_*_mesh`` shims are kept for callers that only need the raw
:class:`jax.sharding.Mesh`; they are FUNCTIONS (not module-level constants)
so that importing this module never touches jax device state; the dry-run
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls them.

Topology (TPU v5e pods of 256 chips):

* single-pod:  (16, 16)        axes ("data", "model") — 256 chips
* multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

The "model" axis maps onto the fast ICI dimension (TP collectives are
latency-sensitive); "data"/"pod" carry FSDP all-gathers and the gradient
reduce-scatters, with the pod axis crossing DCN (which is why the gradient
compression path applies to the pod axis only).
"""

from __future__ import annotations


def make_production_communicator(*, multi_pod: bool = False, session=None):
    """The production communicator: the world pset folded onto the pod grid."""

    from repro.core.communicator import Communicator
    from repro.core.session import default_session

    import math

    from repro.core import errors

    sess = session if session is not None else default_session()
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    world = sess.group("repro://world")
    n = math.prod(shape)
    errors.check(
        n <= world.size(),
        errors.ErrorClass.ERR_DIMS,
        f"production topology {shape} needs {n} devices but the platform "
        f"holds {world.size()}",
    )
    g = world.incl(range(n))
    return Communicator.from_group(
        g, tag="repro://production", shape=shape, axis_names=axes
    )


def make_production_mesh(*, multi_pod: bool = False):
    return make_production_communicator(multi_pod=multi_pod).mesh


def make_host_communicator(
    data: int | None = None,
    model: int = 1,
    *,
    pset: str = "repro://world",
    session=None,
):
    """A small communicator over a process set (tests / examples / benches).

    ``pset`` selects which slice of the platform this workload owns
    (``repro://world`` by default; any session pset — per-host sets,
    user-registered sets — works).  The leading ``data × model`` devices of
    the set are folded onto a ("data", "model") grid.
    """

    from repro.core.communicator import Communicator
    from repro.core.session import default_session

    from repro.core import errors

    g = (session if session is not None else default_session()).group(pset)
    if data is None:
        data = g.size() // model
    errors.check(
        data >= 1 and data * model <= g.size(),
        errors.ErrorClass.ERR_DIMS,
        f"mesh {data}x{model} needs {max(data, 1) * model} devices but pset "
        f"{pset!r} holds {g.size()}",
    )
    return Communicator.from_group(
        g.incl(range(data * model)),
        tag=pset,
        shape=(data, model),
        axis_names=("data", "model"),
    )


def make_host_mesh(data: int | None = None, model: int = 1):
    """A small mesh over whatever devices exist (tests / examples / benches)."""

    return make_host_communicator(data, model).mesh
