"""Production meshes.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls it.

Topology (TPU v5e pods of 256 chips):

* single-pod:  (16, 16)        axes ("data", "model") — 256 chips
* multi-pod:   (2, 16, 16)     axes ("pod", "data", "model") — 512 chips

The "model" axis maps onto the fast ICI dimension (TP collectives are
latency-sensitive); "data"/"pod" carry FSDP all-gathers and the gradient
reduce-scatters, with the pod axis crossing DCN (which is why the gradient
compression path applies to the pod axis only).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """A small mesh over whatever devices exist (tests / examples / benches)."""

    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
