"""Launch layer: production mesh construction, input stand-ins, step
functions, the multi-pod dry-run driver and the train/serve CLIs."""
