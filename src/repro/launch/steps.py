"""The production step functions the dry-run lowers and the launchers run.

Each is a pure jax function of explicit pytrees (params / opt_state / batch /
cache / token) so the same callable serves ``jax.jit`` at 8 CPU devices and
512 production chips.

:func:`make_persistent_step` is the persistent-mode entry: the step is
AOT-lowered and compiled once against an example argument list (with the
production donation pattern — params/opt-state for train, cache for decode)
and returned as a :class:`~repro.core.futures.PersistentRequest` whose every
call is an ``MPI_Start``-style re-fire.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import base
from repro.core.futures import PersistentRequest
from repro.models import api as model_api
from repro.optim import AdamW, clip_by_global_norm

#: the production buffer-donation pattern per step kind
DONATION = {"train": (0, 1), "prefill": (), "decode": (1,)}


def make_train_step(cfg: base.ModelConfig, pcfg: base.ParallelConfig, opt: AdamW):
    bundle = model_api.build(cfg)

    def grad_of(params, batch):
        def loss_fn(p):
            loss, metrics = bundle.loss(p, batch, pcfg, None)
            return loss, metrics

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        k = getattr(pcfg, "microbatches", 1)
        if k > 1:
            # gradient accumulation: peak activation memory divides by k at
            # the cost of re-gathering FSDP weights per microbatch (§Perf B3).
            # The f32 accumulator MUST carry the parameter shardings —
            # unpinned, the scan carry replicates it (observed: +5 TB/device).
            mb = jax.tree.map(
                lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch
            )

            def _acc_init():
                from jax.sharding import PartitionSpec as P

                from repro.models.common import _ambient_mesh_shape
                from repro.sharding import rules as _rules

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                shape = _ambient_mesh_shape()
                if not shape:
                    return zeros
                shim = type("M", (), {"shape": shape})()
                specs = _rules.param_specs(params, shim, pcfg)
                return jax.tree.map(
                    lambda z, s: jax.lax.with_sharding_constraint(z, s),
                    zeros, specs,
                    is_leaf=lambda x: isinstance(x, P),
                )

            def one(acc, mbatch):
                (loss, metrics), grads = grad_of(params, mbatch)
                acc = jax.tree.map(jnp.add, acc, grads)
                return acc, (loss, metrics)

            acc, (losses, metrics) = jax.lax.scan(one, _acc_init(), mb)
            grads = jax.tree.map(lambda g: (g / k).astype(jnp.float32), acc)
            metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        else:
            (loss, metrics), grads = grad_of(params, batch)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: base.ModelConfig, pcfg: base.ParallelConfig):
    bundle = model_api.build(cfg)

    def prefill_step(params, batch):
        logits, cache = bundle.prefill(params, batch, pcfg, None)
        return logits, cache

    return prefill_step


def make_decode_step(cfg: base.ModelConfig, pcfg: base.ParallelConfig):
    bundle = model_api.build(cfg)

    def decode_step(params, cache, token):
        logits, cache = bundle.decode(params, cache, token, pcfg, None)
        return logits, cache

    return decode_step


def make_step(kind: str, cfg, pcfg, opt: AdamW | None = None):
    if kind == "train":
        return make_train_step(cfg, pcfg, opt or AdamW(lr=1e-4, moment_dtype=pcfg.moment_dtype))
    if kind == "prefill":
        return make_prefill_step(cfg, pcfg)
    if kind == "decode":
        return make_decode_step(cfg, pcfg)
    raise ValueError(kind)


def make_persistent_step(
    kind: str,
    cfg,
    pcfg,
    example_args: tuple,
    opt: AdamW | None = None,
    *,
    donate: bool = True,
    warm_start: bool = False,
    **jit_kwargs: Any,
) -> PersistentRequest:
    """Persistent mode: AOT-lower one production step for ``example_args``.

    ``example_args`` may be concrete arrays or ``jax.ShapeDtypeStruct``
    stand-ins (pass ``in_shardings``/``out_shardings`` through
    ``jit_kwargs`` to pin the production layout).  The returned request is a
    drop-in callable for the jitted step with zero re-trace risk.
    """

    fn = make_step(kind, cfg, pcfg, opt)
    donate_argnums = DONATION[kind] if donate else ()
    jitted = jax.jit(fn, donate_argnums=donate_argnums, **jit_kwargs)
    return PersistentRequest(
        jitted, example_args, donate_argnums=donate_argnums, warm_start=warm_start
    )
