"""Serving launcher: batched prefill + decode with the runtime Server.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b --smoke \
        --requests 8 --prompt-len 64 --new-tokens 16

``--disaggregate`` splits the serving process set into prefill and decode
worker groups (``<pset>/prefill`` / ``<pset>/decode``): prefill ranks
compute the KV cache and stream it into the decode ranks' RMA window
(``--kv-pages`` pages per handoff); decode rides its persistent request.
"""

from __future__ import annotations

import argparse
import json

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="auto")
    ap.add_argument(
        "--pset",
        default="repro://world",
        help="session process set the server owns (e.g. repro://host/1)",
    )
    ap.add_argument(
        "--disaggregate",
        action="store_true",
        help="split the pset into prefill/decode groups; KV crosses via RMA",
    )
    ap.add_argument("--prefill-fraction", type=float, default=0.5)
    ap.add_argument("--kv-pages", type=int, default=4)
    args = ap.parse_args(argv)
    if args.disaggregate and args.mesh != "auto":
        ap.error("--mesh has no effect with --disaggregate (group layouts "
                 "come from --prefill-fraction); drop one of the two")

    from repro.configs import base
    from repro.launch.mesh import make_host_communicator
    from repro.runtime.server import (
        DisaggregatedServer,
        Request,
        Server,
        ServerConfig,
    )

    cfg = base.get_smoke_config(args.arch) if args.smoke else base.get_config(args.arch)
    pcfg = base.get_parallel(args.arch)
    comm = None
    if not args.disaggregate:
        if args.mesh == "auto":
            comm = make_host_communicator(pset=args.pset)
        else:
            d, m = (int(t) for t in args.mesh.split("x"))
            comm = make_host_communicator(d, m, pset=args.pset)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        toks = rng.integers(1, cfg.vocab_size, size=(args.prompt_len,), dtype=np.int32)
        extra = {}
        if cfg.family == "vlm":
            extra["image_embeds"] = rng.standard_normal(
                (cfg.num_image_tokens, 1152), dtype=np.float32
            )
        if cfg.family == "encdec":
            extra["frames"] = rng.standard_normal(
                (args.prompt_len, cfg.d_model), dtype=np.float32
            )
        reqs.append(Request(tokens=toks, extra=extra))

    scfg = ServerConfig(max_batch=args.requests,
                        max_new_tokens=args.new_tokens,
                        temperature=args.temperature)
    if args.disaggregate:
        server = DisaggregatedServer(
            cfg, pcfg, scfg,
            pset=args.pset,
            prefill_fraction=args.prefill_fraction,
            kv_pages=args.kv_pages,
        )
    else:
        server = Server(cfg, pcfg, scfg, comm)
    tokens, stats = server.generate(reqs)
    print("generated shape:", tokens.shape)
    print(json.dumps({k: round(v, 4) if isinstance(v, float) else v for k, v in stats.items()},
                     indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
