"""Serving launcher: batched prefill + decode with the runtime Server.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2_9b --smoke \
        --requests 8 --prompt-len 64 --new-tokens 16

``--disaggregate`` splits the serving process set into prefill and decode
worker groups (``<pset>/prefill`` / ``<pset>/decode``): prefill ranks
compute the KV cache and stream it into the decode ranks' RMA window
(``--kv-pages`` pages per handoff); decode rides its persistent request.
``--fanout P:D`` makes that split heterogeneous (2:6, 3:5, ...) with the
KV routed along the dist-graph fan-out adjacency.  ``--continuous-batching``
serves through the paged-KV engine instead of one fixed batch: requests are
admitted into the running decode iteration and retire at their stop token.
"""

from __future__ import annotations

import argparse
import json

import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default="auto")
    ap.add_argument(
        "--pset",
        default="repro://world",
        help="session process set the server owns (e.g. repro://host/1)",
    )
    ap.add_argument(
        "--disaggregate",
        action="store_true",
        help="split the pset into prefill/decode groups; KV crosses via RMA",
    )
    ap.add_argument("--prefill-fraction", type=float, default=0.5)
    ap.add_argument("--kv-pages", type=int, default=4)
    ap.add_argument(
        "--plan",
        default=None,
        help="the unified parallelism plan: 'auto' (repro.tune roofline "
        "search on the prefill cell), 'DxT' dims, or key=value pairs; "
        "'fanout=P:D' selects the heterogeneous disaggregated split",
    )
    ap.add_argument(
        "--fanout",
        default=None,
        metavar="P:D",
        help="alias for --plan fanout=P:D (same parser): heterogeneous "
        "prefill:decode worker split (e.g. 2:6, 3:5); implies "
        "--disaggregate and replaces --prefill-fraction",
    )
    ap.add_argument(
        "--continuous-batching",
        action="store_true",
        help="serve through the continuous-batching engine (paged KV block "
        "pool, in-flight admission) instead of one fixed batch",
    )
    args = ap.parse_args(argv)
    if args.plan and args.fanout:
        ap.error("--fanout is an alias for --plan fanout=P:D; pass one")
    if args.plan and args.mesh != "auto":
        ap.error("--plan subsumes --mesh (the plan's data/model dims are "
                 "the mesh); drop one of the two")
    if args.fanout is not None:
        args.disaggregate = True
    if args.disaggregate and args.mesh != "auto":
        ap.error("--mesh has no effect with --disaggregate (group layouts "
                 "come from --prefill-fraction/--fanout); drop one of the two")

    from repro.configs import base
    from repro.core.session import default_session
    from repro.launch.mesh import make_host_communicator
    from repro.runtime.server import (
        DisaggregatedServer,
        Request,
        Server,
        ServerConfig,
    )

    cfg = base.get_smoke_config(args.arch) if args.smoke else base.get_config(args.arch)
    pcfg = base.get_parallel(args.arch)

    # one parser for every layout flag: --plan wins; --fanout routes through
    # the same grammar as "fanout=P:D"
    plan = None
    if args.plan == "auto":
        shape = base.ShapeConfig(
            f"prefill_{args.prompt_len}", args.prompt_len, args.requests,
            "prefill",
        )
        from repro import tune as tune_mod

        result = tune_mod.tune(
            args.arch, shape, config=cfg, space=base.plan_space(args.arch),
        )
        plan = result.plan
        print(f"autotuned plan: {plan.slug()} "
              f"(predicted {result.score.step_s:.4f}s)")
    elif args.plan:
        plan = base.parse_plan(
            args.plan, devices=default_session().group().size()
        )
    elif args.fanout is not None:
        plan = base.parse_plan(
            f"fanout={args.fanout}", devices=default_session().group().size()
        )
    if plan is not None and plan.fanout is not None:
        args.disaggregate = True
    if args.continuous_batching and args.disaggregate:
        ap.error("--continuous-batching schedules a single-group Server; "
                 "it does not compose with --disaggregate/--fanout yet")

    comm = None
    if not args.disaggregate:
        if plan is not None:
            d, m = (plan.fold_dims() + (1,))[:2]
            comm = make_host_communicator(d, m, pset=args.pset)
        elif args.mesh == "auto":
            comm = make_host_communicator(pset=args.pset)
        else:
            d, m = (int(t) for t in args.mesh.split("x"))
            comm = make_host_communicator(d, m, pset=args.pset)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        toks = rng.integers(1, cfg.vocab_size, size=(args.prompt_len,), dtype=np.int32)
        extra = {}
        if cfg.family == "vlm":
            extra["image_embeds"] = rng.standard_normal(
                (cfg.num_image_tokens, 1152), dtype=np.float32
            )
        if cfg.family == "encdec":
            extra["frames"] = rng.standard_normal(
                (args.prompt_len, cfg.d_model), dtype=np.float32
            )
        reqs.append(Request(tokens=toks, extra=extra))

    scfg = ServerConfig(max_batch=min(args.requests, 4) if args.continuous_batching
                        else args.requests,
                        max_new_tokens=args.new_tokens,
                        temperature=args.temperature)
    if args.disaggregate:
        fanout = plan.fanout if plan is not None else None
        server = DisaggregatedServer(
            cfg, pcfg, scfg,
            pset=args.pset,
            prefill_fraction=args.prefill_fraction,
            kv_pages=args.kv_pages,
            fanout=fanout,
        )
    else:
        server = Server(cfg, pcfg, scfg, comm)

    if args.continuous_batching:
        from repro.runtime.engine import Engine, EngineConfig

        eng = Engine(server, EngineConfig(prompt_bucket=args.prompt_len))
        handles = [eng.submit(r) for r in reqs]
        eng.run()
        stats = eng.stats()
        print("generated lengths:", [len(h.generated) for h in handles])
    else:
        tokens, stats = server.generate(reqs)
        print("generated shape:", tokens.shape)
    print(json.dumps({k: round(v, 4) if isinstance(v, float) else v for k, v in stats.items()},
                     indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
