"""Search strategies over the legal plan space.

Two modes, both deterministic:

* ``exhaustive`` — score every legal plan, return the minimum.  The space
  is small (hundreds of candidates at single-host device counts), scoring
  is closed-form arithmetic, and the minimum is the *definition* of the
  right answer — so brute force is the default, not the fallback.
* ``coordinate`` — greedy coordinate descent: start from the pure data
  plan and sweep one axis at a time (stage, ring, tensor/expert,
  microbatches, buckets, remat, dcn), taking the best candidate that
  differs from the incumbent only on that axis, until a full sweep changes
  nothing.  O(axes · values · sweeps) scores instead of the full product —
  the mode a much larger space would need.  ``autotuner_regret`` in the
  bench gate tracks its score against the exhaustive minimum.
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import (
    ModelConfig,
    ParallelPlan,
    PlanSpace,
    ShapeConfig,
    legal_plans,
)
from repro.core import errors, tool
from repro.tune.score import Score, score_plan

tool.pvar_register("tune:candidates", "legal plans enumerated per tuner run")
tool.pvar_register("tune:scored", "plans scored by the roofline model")
tool.pvar_register(
    "tune:winner_registered",
    "winning plans whose repro://cart/<dims> pset was registered",
)

#: the axes coordinate descent sweeps, in sweep order.  ``data`` is never a
#: coordinate — it is derived (the elastic fill of the device count).  The
#: whole fabric is ONE coordinate: stage/ring/tensor are mutually exclusive
#: folds, so moving between them is a multi-field step a per-field sweep
#: could never take (stage=4 → tensor=4 changes two fields at once).  The
#: remat mode rides along too — which fabric wins depends on whether its
#: memory pressure can be paid in recompute (ring + rm-none vs tp + rm-full
#: are genuinely coupled choices).
_COORDS = (
    ("stage", "ring", "tensor", "expert", "microbatches", "remat"),
    ("microbatches",),
    ("grad_buckets",),
    ("remat",),
    ("dcn_axis",),
)


@dataclasses.dataclass(frozen=True)
class TuneResult:
    """The tuner's verdict for one (arch × shape × devices) cell."""

    plan: ParallelPlan
    score: Score
    mode: str
    n_candidates: int
    n_scored: int
    table: tuple[tuple[str, float], ...]   # top candidates, (slug, step_s)

    def as_dict(self) -> dict:
        return {
            "plan": dataclasses.asdict(self.plan),
            "slug": self.plan.slug(),
            "cart_pset": self.plan.cart_pset,
            "score": self.score.as_dict(),
            "mode": self.mode,
            "n_candidates": self.n_candidates,
            "n_scored": self.n_scored,
            "table": [list(row) for row in self.table],
        }


def _rank_key(scored: tuple[ParallelPlan, Score]) -> tuple[float, str]:
    plan, sc = scored
    return (sc.step_s, plan.slug())


def search(
    cfg: ModelConfig,
    shape: ShapeConfig,
    devices: int,
    *,
    space: PlanSpace | None = None,
    slices: int = 1,
    mode: str = "exhaustive",
    default_remat: str = "full",
    calibration: dict | None = None,
    top: int = 5,
) -> TuneResult:
    """Pick the best legal plan for the cell.  Deterministic: a fixed
    (config, shape, devices, space, calibration) tuple always returns the
    same plan — ties break on the plan slug, never enumeration order."""

    errors.check(
        mode in ("exhaustive", "coordinate"),
        errors.ErrorClass.ERR_ARG,
        f"unknown search mode {mode!r} (exhaustive | coordinate)",
    )
    candidates = legal_plans(cfg, shape, devices, space, slices=slices)
    errors.check(
        len(candidates) > 0,
        errors.ErrorClass.ERR_TOPOLOGY,
        f"no legal plan for {cfg.name} x {shape.name} on {devices} devices",
    )
    tool.pvar_add("tune:candidates", len(candidates))

    def sc(plan: ParallelPlan) -> Score:
        tool.pvar_count("tune:scored")
        return score_plan(
            cfg, shape, plan,
            default_remat=default_remat, calibration=calibration,
        )

    if mode == "exhaustive":
        scored = sorted(((p, sc(p)) for p in candidates), key=_rank_key)
        n_scored = len(scored)
    else:
        scored, n_scored = _coordinate(candidates, sc)
    best_plan, best_score = scored[0]
    table = tuple((p.slug(), s.step_s) for p, s in scored[:top])
    return TuneResult(
        plan=best_plan,
        score=best_score,
        mode=mode,
        n_candidates=len(candidates),
        n_scored=n_scored,
        table=table,
    )


def _coordinate(candidates, sc):
    """Greedy coordinate descent over the candidate list; returns the
    visited plans ranked, plus how many scores it actually paid for."""

    def value(plan, fields):
        return tuple(getattr(plan, f) for f in fields)

    all_fields = [f.name for f in dataclasses.fields(ParallelPlan)]
    cache: dict[ParallelPlan, Score] = {}

    def cached(plan):
        if plan not in cache:
            cache[plan] = sc(plan)
        return cache[plan]

    # the starting incumbent: the most "plain" candidate (pure data fill if
    # it is legal, else the lexically first slug)
    current = min(candidates, key=lambda p: (p.fixed_size, p.slug()))
    cached(current)
    for _sweep in range(8):
        changed = False
        for coord in _COORDS:
            frozen = [
                f for f in all_fields if f not in coord and f != "data"
            ]
            peers = [
                p for p in candidates
                if value(p, frozen) == value(current, frozen)
            ]
            best = min(peers, key=lambda p: (cached(p).step_s, p.slug()))
            if best != current and cached(best).step_s < cached(current).step_s:
                current = best
                changed = True
        if not changed:
            break
    ranked = sorted(cache.items(), key=_rank_key)
    return ranked, len(cache)
