"""Deterministic roofline scoring for :class:`~repro.configs.base.ParallelPlan`.

The score of a plan is a *predicted step time in seconds* on the hardware
model in :mod:`repro.core.tool` (TPU v5e numbers: ``PEAK_FLOPS_BF16``,
``HBM_BANDWIDTH``, ``ICI_BANDWIDTH``, ``DCN_BANDWIDTH``).  The model is a
closed-form roofline — pure arithmetic over the :class:`ModelConfig`, the
:class:`ShapeConfig` and the plan — so scoring is **deterministic**: no
wall clock, no RNG, no jax.  When a :mod:`repro.launch.dryrun` artifact for
the (arch, shape) cell exists, its measured HLO-flops ratio *calibrates*
the compute term (the only term analytic 6·N·D undercounts), keeping the
score a function of the artifact set alone.

Terms (train kind; serving shapes drop the backward/pipeline/grad terms):

* ``compute_s`` — remat-multiplied model FLOPs over all chips at peak.
* ``memory_s`` — per-device HBM traffic: sharded weights touched fwd/bwd/
  update plus activation stores at the remat mode's residency factor.
* ``bubble_s`` — the GPipe pipeline fill/drain bubble
  ``(s-1)/(m+s-1) · compute``; the term that makes microbatches *matter*.
* ``wire_s`` — exposed collective seconds after overlap credits: the
  data-axis grad all-reduce (hidden up to backward compute as
  ``grad_buckets`` grows, each bucket paying ``COLLECTIVE_LAUNCH_S``),
  pipeline-boundary permutes, ring KV rotation (~90 % hidden behind the
  blockwise kernel, per the fused-ring bench), per-layer tensor-parallel
  all-reduces and MoE all-to-alls.  The axis named ``plan.dcn_axis`` bills
  its wire bytes at DCN bandwidth instead of ICI.
* memory feasibility — predicted peak bytes vs ``HBM_BYTES``; an
  over-budget plan is *penalized* quadratically rather than discarded, so
  search stays total even at device counts where nothing fits.

Wire-byte factors reuse :func:`repro.core.tool._wire_factor` — the same
ring-algorithm accounting the HLO analyzer applies to compiled modules, so
predicted and measured wire bytes are comparable series.
"""

from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path

from repro.configs.base import ModelConfig, ParallelPlan, ShapeConfig
from repro.core import tool
from repro.core.tool import _wire_factor

#: extra forward FLOPs paid re-materialising activations in backward.
REMAT_FLOP_MULT = {"none": 1.0, "dots": 7.0 / 6.0, "full": 8.0 / 6.0}

#: resident activation bytes per token·layer, in units of d_model·2 bytes
#: (bf16): everything (~14 tensors) / attention probs + mlp in (~6) / layer
#: boundaries only (~2).
REMAT_RESIDENCY = {"none": 14.0, "dots": 6.0, "full": 2.0}

#: fraction of ring-rotation wire hidden behind blockwise compute (the
#: fused-ring bench holds the tax ≤ 1.05, i.e. ≥ ~90 % overlap).
RING_OVERLAP = 0.9

#: fraction of pipeline-boundary permute wire hidden behind stage compute.
PIPELINE_OVERLAP = 0.8

#: fraction of per-layer TP all-reduce wire hidden behind the matmuls.
TENSOR_OVERLAP = 0.3


@dataclasses.dataclass(frozen=True)
class Score:
    """One plan's predicted step decomposition (seconds, bytes)."""

    step_s: float                # the ranking key (includes penalty)
    compute_s: float
    memory_s: float
    bubble_s: float
    wire_s: float
    launch_s: float
    peak_bytes: float
    fits: bool

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _axis_bandwidth(axis: str, plan: ParallelPlan) -> float:
    """ICI, unless this fold axis is the one the plan routes across DCN."""

    if plan.dcn_axis is not None and axis == plan.dcn_axis:
        return tool.DCN_BANDWIDTH
    return tool.ICI_BANDWIDTH


def load_calibration(
    arch: str, shape: str, artifacts_dir: str | Path | None = None
) -> dict:
    """Measured terms from the (arch, shape) dry-run artifact, if one was
    recorded: ``{"flops_scale": hlo_flops_global / model_flops}``.  A pure
    function of the artifact files — nothing else — so a fixed artifact set
    gives a fixed calibration (and a fixed tuner output)."""

    if artifacts_dir is None:
        from repro.launch import dryrun

        artifacts_dir = dryrun.ARTIFACTS
    out: dict = {}
    for mesh in ("pod_16x16", "multipod_2x16x16"):
        p = Path(artifacts_dir) / f"{arch}__{shape}__{mesh}.json"
        if not p.exists():
            continue
        try:
            rec = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        ratio = rec.get("useful_flop_ratio")
        if rec.get("status") == "ok" and ratio:
            out["flops_scale"] = 1.0 / float(ratio)
            out["source"] = p.name
            break
    return out


def score_plan(
    cfg: ModelConfig,
    shape: ShapeConfig,
    plan: ParallelPlan,
    *,
    default_remat: str = "full",
    calibration: dict | None = None,
) -> Score:
    """Predicted step seconds for ``plan`` (lower is better).

    Pure and deterministic: two calls with equal arguments return equal
    scores, and plan ordering never depends on dict iteration or time.
    """

    n = plan.total_devices
    d, s, r, e, t = plan.data, plan.stage, plan.ring, plan.expert, plan.tensor
    m = max(1, plan.microbatches)
    remat = plan.remat if plan.remat is not None else default_remat
    is_train = shape.kind == "train"
    bf16 = 2

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    tokens_local = tokens / d
    flop_mult = 6.0 if is_train else 2.0
    model_flops = flop_mult * cfg.active_param_count() * tokens
    if calibration and calibration.get("flops_scale"):
        model_flops *= float(calibration["flops_scale"])
    if is_train:
        model_flops *= REMAT_FLOP_MULT[remat]
    compute_s = model_flops / (n * tool.PEAK_FLOPS_BF16)

    # -- HBM traffic ---------------------------------------------------------
    # weights: sharded over every axis (fsdp over data, slices over
    # stage/tensor); touched fwd + bwd + optimizer update in train.
    param_bytes_local = bf16 * cfg.param_count() / n
    weight_touches = 3.0 if is_train else 1.0
    layers_local = cfg.num_layers / s
    act_residency = REMAT_RESIDENCY[remat] if is_train else 2.0
    act_traffic = (
        (tokens_local / max(r, 1)) * cfg.d_model * layers_local
        * act_residency * bf16 / max(t, 1)
    )
    memory_s = (
        weight_touches * param_bytes_local + act_traffic
    ) / tool.HBM_BANDWIDTH

    wire_s = 0.0
    launch_s = 0.0

    # -- data axis: gradient all-reduce, bucketed + overlapped ---------------
    if is_train and d > 1:
        grad_bytes = bf16 * cfg.param_count() / (s * max(t, 1))
        ar_s = grad_bytes * _wire_factor("all-reduce", d) / _axis_bandwidth(
            "data", plan
        )
        b = max(1, plan.grad_buckets)
        # all buckets but the last overlap backward, capped by what backward
        # can hide (~2/3 of compute is the backward pass)
        hidden = min(ar_s * (1 - 1 / b), (2.0 / 3.0) * compute_s)
        wire_s += ar_s - hidden
        launch_s += b * tool.COLLECTIVE_LAUNCH_S

    # -- stage axis: microbatch boundary permutes + the bubble ---------------
    bubble_s = 0.0
    if s > 1:
        bubble_s = compute_s * (s - 1) / (m + s - 1)
        mb_act_bytes = (tokens_local / m) * cfg.d_model * bf16
        crossings = (2 if is_train else 1) * (m + s - 2)
        perm_s = (
            crossings * mb_act_bytes * _wire_factor("collective-permute", s)
            / _axis_bandwidth("stage", plan)
        )
        wire_s += perm_s * (1 - PIPELINE_OVERLAP)
        launch_s += crossings * tool.COLLECTIVE_LAUNCH_S

    # -- ring axis: KV rotation, mostly hidden behind blockwise compute ------
    if r > 1:
        kv_heads = cfg.num_kv_heads or cfg.num_heads
        kv_shard = (
            2 * (tokens_local / r) * kv_heads * cfg.head_dim * bf16
        )
        rot_s = (
            cfg.num_layers * (r - 1) * kv_shard
            * (2 if is_train else 1)
            / _axis_bandwidth("model", plan)
        )
        wire_s += rot_s * (1 - RING_OVERLAP)
        launch_s += cfg.num_layers * (r - 1) * tool.COLLECTIVE_LAUNCH_S

    # -- tensor axis: per-layer activation all-reduces (Megatron pattern) ----
    if t > 1:
        act_bytes = (tokens_local / max(r, 1)) * cfg.d_model * bf16
        per_layer = 2 * (2 if is_train else 1)   # attn + mlp, fwd (+ bwd)
        ar_s = (
            layers_local * per_layer * act_bytes
            * _wire_factor("all-reduce", t) / _axis_bandwidth("model", plan)
        )
        wire_s += ar_s * (1 - TENSOR_OVERLAP)
        launch_s += layers_local * per_layer * tool.COLLECTIVE_LAUNCH_S

    # -- expert axis: token dispatch/combine all-to-alls ---------------------
    if e > 1 and cfg.num_experts:
        top_k = max(1, cfg.moe_top_k)
        a2a_bytes = (tokens_local / max(r, 1)) * cfg.d_model * bf16 * top_k
        per_layer = 2 * (2 if is_train else 1)   # dispatch + combine
        moe_layers = max(0, cfg.num_layers - cfg.first_dense_layers) / s
        a2a_s = (
            moe_layers * per_layer * a2a_bytes
            * _wire_factor("all-to-all", e) / _axis_bandwidth("model", plan)
        )
        wire_s += a2a_s
        launch_s += moe_layers * per_layer * tool.COLLECTIVE_LAUNCH_S

    # grad-accumulation microbatching without a pipeline: per-microbatch
    # dispatch overhead only (compute total unchanged)
    if s == 1 and m > 1:
        launch_s += m * tool.COLLECTIVE_LAUNCH_S

    # -- memory feasibility ---------------------------------------------------
    # resident: bf16 params + f32 Adam moments (train), sharded over every
    # axis, plus the activation working set of ONE microbatch slice.
    state_mult = (bf16 + 8) if is_train else bf16
    state_bytes = state_mult * cfg.param_count() / n
    act_store = (
        (tokens_local / (m * max(r, 1))) * cfg.d_model * layers_local
        * (REMAT_RESIDENCY[remat] if is_train else 2.0) * bf16 / max(t, 1)
    )
    peak_bytes = state_bytes + act_store
    fits = peak_bytes <= tool.HBM_BYTES

    step_s = max(compute_s, memory_s) + bubble_s + wire_s + launch_s
    if not fits:
        step_s *= (peak_bytes / tool.HBM_BYTES) ** 2
    return Score(
        step_s=step_s,
        compute_s=compute_s,
        memory_s=memory_s,
        bubble_s=bubble_s,
        wire_s=wire_s,
        launch_s=launch_s,
        peak_bytes=peak_bytes,
        fits=fits,
    )


def score_key(cfg, shape, plan, **kw) -> tuple:
    """Total deterministic ordering: step seconds, then the plan slug so
    exact ties break lexically instead of by enumeration accident."""

    return (score_plan(cfg, shape, plan, **kw).step_s, plan.slug())


def predicted_vs_measured(
    cfg: ModelConfig, shape: ShapeConfig, plan: ParallelPlan, record: dict
) -> dict | None:
    """Compare the analytic roofline against a dry-run artifact's measured
    terms (the bench-matrix validation hook).  Returns ratios or ``None``
    when the artifact carries no roofline block."""

    terms = record.get("roofline")
    if not terms or record.get("status") != "ok":
        return None
    sc = score_plan(cfg, shape, plan)
    chips = record.get("chips") or plan.total_devices
    measured_compute = terms["compute_s"]
    predicted_compute = sc.compute_s * plan.total_devices / chips
    return {
        "predicted_compute_s": predicted_compute,
        "measured_compute_s": measured_compute,
        "compute_ratio": (
            predicted_compute / measured_compute if measured_compute else math.inf
        ),
        "predicted_wire_s": sc.wire_s,
        "measured_wire_s": terms.get("collective_wire_s", 0.0),
    }
