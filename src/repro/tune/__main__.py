"""CLI: ``python -m repro.tune --arch gemma2_9b --shape train_4k``.

Prints the candidate table and the winning :class:`ParallelPlan` (both
human-readable and as a ``--plan``-compatible spec string), registers the
winner's ``repro://cart/<dims>`` process set, and optionally dumps the full
result as JSON for downstream tooling (``--json``).
"""

from __future__ import annotations

import argparse
import json
import sys

from repro import tune as tune_mod
from repro.configs.base import SHAPES, ARCHITECTURES


def _plan_spec(plan) -> str:
    """A ``--plan`` key=value spec reproducing this plan exactly."""

    parts = [f"data={plan.data}"]
    for key, v in (
        ("stage", plan.stage), ("ring", plan.ring),
        ("expert", plan.expert), ("tensor", plan.tensor),
    ):
        if v > 1:
            parts.append(f"{key}={v}")
    if plan.microbatches > 1:
        parts.append(f"micro={plan.microbatches}")
    if plan.grad_buckets > 1:
        parts.append(f"buckets={plan.grad_buckets}")
    if plan.remat is not None:
        parts.append(f"remat={plan.remat}")
    if plan.dcn_axis is not None:
        parts.append(f"dcn={plan.dcn_axis}")
    if plan.fanout is not None:
        parts.append(f"fanout={plan.fanout[0]}:{plan.fanout[1]}")
    return ",".join(parts)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--arch", required=True, choices=ARCHITECTURES)
    ap.add_argument("--shape", default="train_4k", choices=sorted(SHAPES))
    ap.add_argument("--devices", type=int, default=None,
                    help="device count to plan for (default: session world)")
    ap.add_argument("--slices", type=int, default=None,
                    help="pod-slice count (default: session repro://slice/*)")
    ap.add_argument("--mode", default="exhaustive",
                    choices=("exhaustive", "coordinate"))
    ap.add_argument("--top", type=int, default=5)
    ap.add_argument("--no-register", action="store_true",
                    help="skip registering the winner's cart pset")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="ignore recorded dryrun artifacts")
    ap.add_argument("--json", action="store_true",
                    help="emit the full TuneResult as JSON on stdout")
    args = ap.parse_args(argv)

    result = tune_mod.tune(
        args.arch,
        args.shape,
        args.devices,
        slices=args.slices,
        mode=args.mode,
        calibrate=not args.no_calibrate,
        register=not args.no_register,
        top=args.top,
    )
    if args.json:
        print(json.dumps(result.as_dict(), indent=1))
        return 0

    plan, sc = result.plan, result.score
    print(f"tuned {args.arch} x {args.shape} over {result.n_candidates} "
          f"legal plans ({result.mode}, {result.n_scored} scored)")
    print(f"  winner : {plan.slug()}  ->  --plan {_plan_spec(plan)}")
    print(f"  pset   : {plan.cart_pset}"
          + ("" if not args.no_register else "  (not registered)"))
    print(f"  step_s : {sc.step_s:.4f}  (compute {sc.compute_s:.4f}, "
          f"memory {sc.memory_s:.4f}, bubble {sc.bubble_s:.4f}, "
          f"wire {sc.wire_s:.4f}, launch {sc.launch_s:.6f})")
    print(f"  memory : {sc.peak_bytes / 2**30:.2f} GiB "
          f"{'fits' if sc.fits else 'OVER BUDGET'}")
    print("  top candidates:")
    for slug, step_s in result.table:
        marker = "*" if slug == plan.slug() else " "
        print(f"   {marker} {step_s:10.4f}s  {slug}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
