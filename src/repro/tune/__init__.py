"""``repro.tune`` — the roofline-driven parallelism autotuner.

The ROADMAP's "the system, not the user, should choose the cart fold" item:
enumerate the legal 4-axis layout space (data × stage × expert/ring ×
tensor, plus microbatches, grad-sync buckets and remat) for an
(architecture × input shape × device count) cell, score every candidate
with the deterministic roofline model (:mod:`repro.tune.score` — the same
hardware constants and wire-byte factors :mod:`repro.core.tool` applies to
compiled HLO), and emit the winner as a frozen
:class:`~repro.configs.base.ParallelPlan`:

>>> from repro import tune
>>> result = tune.tune("gemma2_9b", "train_4k")
>>> result.plan                      # doctest: +SKIP
ParallelPlan(data=8, ...)

The winning plan is what the rest of the stack consumes — the trainer folds
it (``TrainerConfig(plan=...)`` → ``TopologySpec.from_plan``), the launch
CLIs parse it (``--plan auto`` resolves through this module), and the
session records it: :func:`tune` registers the winner's topology as a
``repro://cart/<dims>`` process set, interleaved across ``repro://slice/<k>``
boundaries when the plan names a ``dcn_axis`` (multi-pod folds cross DCN on
the axis the *tuner* chose, never by accident).

Offline and hardware-free: scoring is closed-form, optionally calibrated by
recorded :mod:`repro.launch.dryrun` artifacts, and the CLI
(``python -m repro.tune --arch gemma2_9b --shape train_4k``) runs in
seconds on a laptop.
"""

from __future__ import annotations

from repro.configs.base import (          # noqa: F401  (public API re-exports)
    SHAPES,
    ParallelPlan,
    PlanSpace,
    ShapeConfig,
    legal_plans,
    parse_plan,
    plan_space,
)
from repro.core import errors, tool
from repro.core.session import Group, Session, default_session
from repro.tune.score import (            # noqa: F401
    Score,
    load_calibration,
    predicted_vs_measured,
    score_plan,
)
from repro.tune.search import TuneResult, search  # noqa: F401

_SLICE_PREFIX = "repro://slice/"


def fold_group(session: Session, plan: ParallelPlan) -> Group:
    """The device group for ``plan``'s fold, in fold (row-major) order.

    Single-slice sessions — or plans without a ``dcn_axis`` — take the
    leading ``plan.total_devices`` world devices.  When the plan names a
    ``dcn_axis`` and the session reports multiple ``repro://slice/<k>``
    sets, the devices are interleaved so that axis is split into one
    contiguous block per slice: neighbours along every *other* axis stay
    inside a slice (ICI), and only the chosen axis crosses DCN.
    """

    n = plan.total_devices
    world = session.group()
    errors.check(
        world.size() >= n,
        errors.ErrorClass.ERR_GROUP,
        f"plan {plan.slug()!r} needs {n} devices; session has {world.size()}",
    )
    slice_names = sorted(
        p for p in session.psets() if p.startswith(_SLICE_PREFIX)
    )
    if plan.dcn_axis is None or len(slice_names) < 2:
        return world.incl(range(n))
    k = len(slice_names)
    dims, axes = plan.fold_dims(), plan.fold_axes()
    a = axes.index(plan.dcn_axis)
    errors.check(
        dims[a] % k == 0,
        errors.ErrorClass.ERR_TOPOLOGY,
        f"dcn axis {plan.dcn_axis!r} of extent {dims[a]} does not split "
        f"over {k} slices",
    )
    per_slice = n // k
    pools = [list(session.pset(nm)) for nm in slice_names]
    for nm, pool in zip(slice_names, pools):
        errors.check(
            len(pool) >= per_slice,
            errors.ErrorClass.ERR_GROUP,
            f"slice pset {nm!r} has {len(pool)} devices; the fold needs "
            f"{per_slice} per slice",
        )
    devices = []
    for flat in range(n):
        # the row-major coordinate along the dcn axis decides the owning slice
        coord = _unravel(flat, dims)
        devices.append(pools[coord[a] * k // dims[a]].pop(0))
    return Group(devices)


def _trailing(dims, i):
    out = 1
    for d in dims[i + 1:]:
        out *= d
    return out


def _unravel(flat: int, dims) -> tuple[int, ...]:
    coord = []
    for i in range(len(dims)):
        t = _trailing(dims, i)
        coord.append((flat // t) % dims[i])
    return tuple(coord)


def tune(
    arch: str,
    shape: str | ShapeConfig = "train_4k",
    devices: int | None = None,
    *,
    slices: int | None = None,
    mode: str = "exhaustive",
    space: PlanSpace | None = None,
    calibrate: bool = True,
    register: bool = True,
    session: Session | None = None,
    top: int = 5,
    config=None,
) -> TuneResult:
    """Tune one (arch × shape × device count) cell and return the
    :class:`~repro.tune.search.TuneResult`.

    ``devices`` defaults to the session world size (the count the winner's
    pset can actually be registered over); ``slices`` defaults to the
    session's ``repro://slice/<k>`` count.  ``register=False`` skips the
    pset side effect (pure scoring, e.g. for the regret bench).  ``config``
    overrides the arch's :class:`ModelConfig` (the smoke-config launchers
    tune the model they actually run).
    """

    from repro.configs import base

    cfg = config if config is not None else base.get_config(arch)
    shp = SHAPES[shape] if isinstance(shape, str) else shape
    sess = session if session is not None else default_session()
    if devices is None:
        devices = sess.group().size()
    if slices is None:
        slices = max(
            1, sum(1 for p in sess.psets() if p.startswith(_SLICE_PREFIX))
        )
    result = search(
        cfg,
        shp,
        devices,
        space=space if space is not None else plan_space(arch),
        slices=slices,
        mode=mode,
        default_remat=base.get_parallel(arch).remat,
        calibration=load_calibration(arch, shp.name) if calibrate else None,
        top=top,
    )
    if register and result.plan.total_devices <= sess.group().size():
        sess.register_pset(result.plan.cart_pset, fold_group(sess, result.plan))
        tool.pvar_count("tune:winner_registered")
    return result
