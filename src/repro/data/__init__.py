"""Data pipeline: deterministic synthetic token streams, shard-aware
batching, and stateless resume (the loader state is just the step index)."""

from repro.data.pipeline import TokenPipeline, make_batch_specs  # noqa: F401

