"""Deterministic, shard-aware synthetic token pipeline.

Production framing: the loader yields *global* batches placed under the data
sharding; every batch is a pure function of ``(seed, step)`` so restart/resume
needs no loader checkpoint (stateless resume — the property elastic restarts
rely on).  The synthetic stream is a order-k Markov chain over the vocab with
a fixed transition structure, giving a learnable (non-uniform) distribution so
training-loss curves are meaningful.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def make_batch_specs(batch: dict, mesh, pcfg) -> dict[str, P]:
    """PartitionSpecs for each batch field (leading batch dim over the data
    axes when divisible, else replicated — e.g. long_500k's batch of 1)."""

    n = int(np.prod([mesh.shape[a] for a in pcfg.data_axes]))
    return {
        k: (P(pcfg.data_axes) if np.shape(v)[0] % n == 0 else P())
        for k, v in batch.items()
    }


@dataclasses.dataclass
class TokenPipeline:
    """Deterministic synthetic LM data.

    Every batch is ``f(seed, step)``: host-built with numpy (cheap, no RNG
    state carried), then ``device_put`` under the batch sharding.
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    modality: str = "lm"          # lm | audio | vlm
    frame_dim: int = 0            # encdec frontend stub dim
    frame_len: int = 0
    image_tokens: int = 0
    image_dim: int = 0

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence([self.seed, step]))

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        """The global batch for ``step`` (host numpy)."""

        rng = self._rng(step)
        b, s, v = self.global_batch, self.seq_len, self.vocab_size
        # order-1 Markov stream: token_{t+1} = (a * token_t + noise) % v
        start = rng.integers(0, v, size=(b, 1))
        steps_noise = rng.integers(0, 7, size=(b, s - 1))
        toks = [start]
        for t in range(s - 1):
            toks.append((toks[-1] * 31 + 17 + steps_noise[:, t : t + 1]) % v)
        tokens = np.concatenate(toks, axis=1).astype(np.int32)
        batch: dict[str, np.ndarray] = {"tokens": tokens}
        if self.modality == "audio":
            batch["frames"] = rng.standard_normal(
                (b, self.frame_len, self.frame_dim), dtype=np.float32
            ).astype(np.float32)
        if self.modality == "vlm":
            batch["image_embeds"] = rng.standard_normal(
                (b, self.image_tokens, self.image_dim), dtype=np.float32
            ).astype(np.float32)
        return batch

    def device_batch(self, step: int, mesh, pcfg) -> dict[str, jax.Array]:
        """Global batch placed under the data sharding (batch dim over the
        data axes; replicated when not divisible)."""

        hb = self.host_batch(step)
        out = {}
        for k, v in hb.items():
            axes = pcfg.data_axes
            n = int(np.prod([mesh.shape[a] for a in axes]))
            spec = P(axes) if v.shape[0] % n == 0 else P()
            arr = v
            if k != "tokens":
                arr = arr.astype(jnp.bfloat16)
            out[k] = jax.device_put(arr, NamedSharding(mesh, spec))
        return out

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.host_batch(step)
            step += 1
