"""Checkpointing built on the parallel-IO component (``repro.core.io``):
sharded save/restore, async save, atomic step manifests, elastic re-shard."""

from repro.checkpoint.manager import CheckpointManager  # noqa: F401
