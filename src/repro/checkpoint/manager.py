"""Sharded, atomic, async checkpointing on the parallel-IO request engine.

Layout::

    <dir>/step_000100/            one core.io File dataset per step
        manifest.json             array records (fragments, offsets, checksums)
        <leaf>.<offset>.npy       per-shard fragments
        _COMPLETE                 atomic completion marker (written last)
    <dir>/latest                  text file: the newest complete step

Fault-tolerance properties:

* a crash mid-save never corrupts an older checkpoint (new directory +
  completion marker);
* restore picks the newest *complete* step — a torn save is skipped;
* **elastic restore**: fragments record global offsets, so a checkpoint
  written on one mesh restores onto any other mesh/sharding (the fragments
  are reassembled to the global array and re-placed through the file's
  ``set_view`` storage representation);
* **async save on the request engine**: the device→host gather is
  synchronous (cheap, and required — the trainer's persistent step donates
  its buffers, so the copy must land before the next ``MPI_Start``), then
  the file writes run as **one I/O request per dtype bucket**
  (``File.awrite_fragments``), joined with ``when_all`` and chained with
  ``then()`` into a **single manifest commit** (one ``MPI_File_sync``-style
  atomic write per step, not one rewrite per array);
* **errors are never swallowed**: ``wait()`` (and ``get()`` on the request
  ``save()`` returns) re-raises any background failure as ``ERR_IO``, and a
  failed save never writes ``_COMPLETE`` or advances ``latest``.  Every
  fragment is read back and checksum-verified before the manifest commits
  (``FileSpec.verify``);
* an ``atexit`` hook joins the outstanding save, so interpreter shutdown
  cannot kill a daemon I/O thread mid-save.
"""

from __future__ import annotations

import atexit
import json
import logging
import os
import re
import sys
import weakref
from typing import Any

import jax
import numpy as np

from repro.analysis import events as analysis_events
from repro.core import errors
from repro.core import io as pio
from repro.core.descriptors import Mode
from repro.core.futures import Future, when_all


def _flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k)))) for k in path
        )
        out.append((name or "leaf", leaf))
    return out


log = logging.getLogger("repro.checkpoint")

_MANAGERS: "weakref.WeakSet[CheckpointManager]" = weakref.WeakSet()


@atexit.register
def _drain_managers_at_exit() -> None:
    for mgr in list(_MANAGERS):
        try:
            mgr.wait()
        except errors.Error as e:
            print(
                f"repro.checkpoint: pending save failed at interpreter exit: {e}",
                file=sys.stderr,
            )


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        *,
        keep: int = 3,
        async_save: bool = True,
        verify: bool = True,
        injector: Any | None = None,
    ):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self.verify = verify
        #: optional runtime.faults.FaultInjector whose ``check_io`` is wired
        #: as the fragment write hook (torn-save fault injection)
        self.injector = injector
        self._pending: pio.IORequest | None = None
        os.makedirs(directory, exist_ok=True)
        _MANAGERS.add(self)

    # -- save ----------------------------------------------------------------

    def save(
        self,
        step: int,
        tree: Any,
        *,
        extra: dict | None = None,
        meta: dict | None = None,
    ) -> Future:
        """Save a pytree checkpoint for ``step``.

        Returns the completion request: a host future resolving to the step
        directory once every fragment is durable (read-back verified) and
        the manifest, ``_COMPLETE`` marker and ``latest`` pointer are
        committed.  With ``async_save`` the request runs in the background
        and the caller overlaps it with further work; :meth:`wait` (called
        automatically before the next save and at exit) joins it and
        **re-raises any failure** as ``ERR_IO``.

        ``meta`` tags the manifest with writer context (``manifest["meta"]``
        — the elastic runtime records ``{"epoch", "world_size"}`` so a
        restore onto a different survivor set knows the fragments were
        sharded under another fabric); read back via :meth:`manifest_meta`.
        """

        from repro.core import tool

        self.wait()
        tool.pvar_count("ckpt_save")
        step_dir = os.path.join(self.directory, f"step_{step:08d}")
        leaves = _flatten_with_names(tree)

        # synchronous device→host gather of addressable shards (donated
        # buffers may be re-fired immediately after save() returns).
        # Deliberately NOT File._gather: leaf names are sanitised ('/'→'.')
        # and checksums are deferred to the bucket threads (off the issue
        # path) — keep the fragment/record shape in sync with File._gather.
        records: dict[str, dict] = {}
        buckets: dict[np.dtype, list[tuple[str, np.ndarray]]] = {}
        entry_by_frag: dict[str, dict] = {}
        for name, leaf in leaves:
            frags: list[tuple[tuple[int, ...], np.ndarray]] = []
            if isinstance(leaf, jax.Array):
                gshape, dtype = tuple(leaf.shape), np.dtype(leaf.dtype)
                seen = set()
                for sh in leaf.addressable_shards:
                    start = tuple(s.start or 0 for s in sh.index)
                    if start in seen:
                        continue
                    seen.add(start)
                    frags.append((start, np.asarray(sh.data)))
            else:
                arr = np.asarray(leaf)
                gshape, dtype = tuple(arr.shape), arr.dtype
                frags.append(((0,) * arr.ndim, arr))
            entries = []
            for start, buf in frags:
                fragname = f"{name.replace('/', '.')}.{'_'.join(map(str, start))}.npy"
                if fragname in entry_by_frag:
                    # sanitised names can collide ("a/b" vs {"a": {"b"}});
                    # last-writer-wins would silently restore wrong data
                    errors.fail(
                        errors.ErrorClass.ERR_IO,
                        f"leaf {name!r} collides with another leaf on "
                        f"fragment {fragname!r} after '/'→'.' sanitisation",
                    )
                buckets.setdefault(dtype, []).append((fragname, buf))
                entries.append(
                    {
                        "fragment": fragname,
                        "offset": list(start),
                        "shape": list(buf.shape),
                        # filled by the commit continuation: digests are
                        # computed on the I/O threads, off the issue path
                        "checksum": None,
                    }
                )
                entry_by_frag[fragname] = entries[-1]
            record = {
                "name": name,
                "shape": list(gshape),
                "dtype": str(dtype),
                "fragments": entries,
            }
            alias = pio.storage_alias(dtype)
            if alias is not None:
                record["etype"] = str(alias)
            records[name] = record

        f = pio.open(step_dir, Mode.CREATE | Mode.WRONLY, checksum=True,
                     verify=self.verify)
        if self.injector is not None and hasattr(self.injector, "check_io"):
            f.write_hook = self.injector.check_io

        # one I/O request per dtype bucket, joined into a single commit; the
        # buckets are created inactive and fanned out by the driver below,
        # so the issue path pays one thread launch, not one per bucket
        reqs = [
            f.awrite_fragments(f"ckpt[{step}] bucket {dt}", frags, start=False)
            for dt, frags in buckets.items()
        ]

        def commit(joined: Future) -> str:
            # joins every bucket; a failed write raises ERR_IO here.  Each
            # bucket resolves to its {fragment: checksum} map — merge them
            # into the records before the single manifest sync point.
            for sums in joined.get():
                for fragname, digest in sums.items():
                    entry_by_frag[fragname]["checksum"] = digest
            f.commit_manifest(records, meta)  # ONE manifest sync point per step
            if extra:
                pio._atomic_write(
                    os.path.join(step_dir, "extra.json"), json.dumps(extra).encode()
                )
            pio._atomic_write(os.path.join(step_dir, "_COMPLETE"), b"ok")
            pio._atomic_write(os.path.join(self.directory, "latest"), str(step).encode())
            self._gc()
            return step_dir

        chain = when_all(reqs).then(commit)  # lazy: nothing blocks here

        def drive():
            for r in reqs:
                r.start()  # fan the bucket threads out together
            return chain._wait_value()

        # drive the chain on its own I/O thread so the commit lands without
        # the caller waiting; the returned request is the completion handle
        completion = pio.IORequest(f"ckpt[{step}] commit", drive)
        if self.async_save:
            if analysis_events.RECORDING:
                # only async saves can dangle: a sync save joins inline below
                analysis_events.record_ckpt("ckpt_save", id(self), step)
            self._pending = completion
        else:
            # join inline — a failure raises from save() itself — but leave
            # the returned request valid so the caller's get()/then() still
            # works (it resolves immediately)
            completion._wait_value()
        return completion

    def wait(self) -> str | None:
        """Join the outstanding save and return its step directory.

        A failure captured in the background — a fragment write error, a
        read-back verify mismatch — is **re-raised here as ``ERR_IO``** (it
        used to be silently dropped with the save reported as success);
        ``latest`` never advances past a failed save.  Callers that already
        consumed the request ``save()`` returned have seen its outcome, so
        the join is a no-op then.
        """

        from repro.core import tool

        req, self._pending = self._pending, None
        if req is None:
            return None
        if analysis_events.RECORDING:
            analysis_events.record_ckpt("ckpt_join", id(self))
        if not req.valid():
            # caller consumed the returned request (get/then); only re-raise
            # a failure that was never actually delivered to anyone
            exc = req.drain()
            if exc is not None and not req.delivered:
                raise exc
            return None
        tool.pvar_count("ckpt_wait")
        return req.get()

    def pending(self) -> bool:
        """Is a background save still in flight (``MPI_Test`` style)?"""

        return self._pending is not None and not self._pending.test()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            import shutil

            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.directory, d, "_COMPLETE")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None, *, shardings: Any = None):
        """Restore into the structure of ``template``.

        ``shardings``: matching pytree of NamedShardings (or None leaves) —
        pass the *current* mesh's shardings for elastic restore onto a
        different topology than the writer's (the straggler/failure recovery
        path).  Each record is read through ``set_view`` with its recorded
        storage etype, so extended dtypes (bf16, fp8) reinterpret through
        the declared representation rather than a blind cast; checksums
        verify every fragment on the way back in.
        Returns (tree, step).
        """

        from repro.core import tool

        # join the in-flight save BEFORE resolving the step: an unjoined
        # save is invisible to latest_step(), so waiting later would restore
        # a stale step (or fail) when the pending one was about to land.
        # Tolerantly: "a torn save is skipped" — restore proceeds from the
        # newest COMPLETE step even when the pending save just failed (the
        # failure is logged and counted, not dropped).
        try:
            self.wait()
        except errors.Error as e:
            tool.pvar_count("ckpt_save_failed")
            log.warning("pending save failed; restoring newest complete step: %s", e)
        step = step if step is not None else self.latest_step()
        errors.check(
            step is not None, errors.ErrorClass.ERR_IO, f"no checkpoint in {self.directory}"
        )
        tool.pvar_count("ckpt_restore")
        step_dir = os.path.join(self.directory, f"step_{step:08d}")
        f = pio.open(step_dir, Mode.RDONLY, checksum=True)
        arrays = f.manifest()["arrays"]
        names = [n for n, _ in _flatten_with_names(template)]
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        flat_s = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_t)
        )
        restored = []
        for name, tmpl, shd in zip(names, flat_t, flat_s):
            rec = arrays.get(name)
            if rec is None:
                errors.fail(
                    errors.ErrorClass.ERR_IO, f"array {name!r} not in {step_dir}"
                )
            f.set_view(etype=rec.get("etype"))
            arr = f.read_at_all(name, shd)
            if hasattr(tmpl, "dtype") and arr.dtype != tmpl.dtype:
                arr = arr.astype(tmpl.dtype)
            restored.append(arr)
        return treedef.unflatten(restored), step

    def extra(self, step: int) -> dict:
        p = os.path.join(self.directory, f"step_{step:08d}", "extra.json")
        if os.path.exists(p):
            with open(p) as fh:
                return json.load(fh)
        return {}

    def manifest_meta(self, step: int | None = None) -> dict:
        """The writer-context tags of a step's manifest (``{"epoch":
        ..., "world_size": ...}`` under the elastic runtime); ``{}`` for
        pre-elastic checkpoints."""

        step = step if step is not None else self.latest_step()
        if step is None:
            return {}
        step_dir = os.path.join(self.directory, f"step_{step:08d}")
        f = pio.open(step_dir, Mode.RDONLY)
        return f.manifest().get("meta", {})
