"""Sharded, atomic, async-capable checkpointing.

Layout::

    <dir>/step_000100/            one core.io File dataset per step
        manifest.json             array records (fragments, offsets, checksums)
        <leaf>.<offset>.npy       per-shard fragments
        _COMPLETE                 atomic completion marker (written last)
    <dir>/latest                  text file: the newest complete step

Fault-tolerance properties:

* a crash mid-save never corrupts an older checkpoint (new directory +
  completion marker);
* restore picks the newest *complete* step — a torn save is skipped;
* **elastic restore**: fragments record global offsets, so a checkpoint
  written on one mesh restores onto any other mesh/sharding (the fragments
  are reassembled to the global array and re-placed);
* async save: the device→host transfer happens synchronously (cheap), the
  file writes go to a background thread; ``wait()`` joins before the next
  save or at exit.
"""

from __future__ import annotations

import json
import os
import re
import threading
from typing import Any

import jax
import numpy as np

from repro.core import errors
from repro.core import io as pio
from repro.core.descriptors import Mode


def _flatten_with_names(tree: Any) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(
            str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k)))) for k in path
        )
        out.append((name or "leaf", leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> str:
        """Save a pytree checkpoint for ``step``.  Returns the step dir."""

        self.wait()
        step_dir = os.path.join(self.directory, f"step_{step:08d}")
        leaves = _flatten_with_names(tree)
        # synchronous device→host gather of addressable shards
        host_shards: list[tuple[str, list[tuple[tuple[int, ...], np.ndarray]], tuple, str]] = []
        for name, leaf in leaves:
            if isinstance(leaf, jax.Array):
                frags = []
                seen = set()
                for sh in leaf.addressable_shards:
                    start = tuple(s.start or 0 for s in sh.index)
                    if start in seen:
                        continue
                    seen.add(start)
                    frags.append((start, np.asarray(sh.data)))
                host_shards.append((name, frags, tuple(leaf.shape), str(np.dtype(leaf.dtype))))
            else:
                arr = np.asarray(leaf)
                host_shards.append(
                    (name, [((0,) * arr.ndim, arr)], tuple(arr.shape), str(arr.dtype))
                )

        def write():
            f = pio.open(step_dir, Mode.CREATE | Mode.WRONLY, checksum=True)
            for name, frags, gshape, dtype in host_shards:
                entries = []
                for start, buf in frags:
                    fragname = f"{name.replace('/', '.')}.{'_'.join(map(str, start))}.npy"
                    f._write_fragment(fragname, buf)
                    entries.append(
                        {
                            "fragment": fragname,
                            "offset": list(start),
                            "shape": list(buf.shape),
                            "checksum": pio._checksum(buf),
                        }
                    )
                f._update_manifest(
                    name,
                    {"name": name, "shape": list(gshape), "dtype": dtype, "fragments": entries},
                )
            if extra:
                pio._atomic_write(
                    os.path.join(step_dir, "extra.json"), json.dumps(extra).encode()
                )
            pio._atomic_write(os.path.join(step_dir, "_COMPLETE"), b"ok")
            pio._atomic_write(
                os.path.join(self.directory, "latest"), str(step).encode()
            )
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()
        return step_dir

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            import shutil

            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d+)", d)
            if m and os.path.exists(os.path.join(self.directory, d, "_COMPLETE")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None, *, shardings: Any = None):
        """Restore into the structure of ``template``.

        ``shardings``: matching pytree of NamedShardings (or None leaves) —
        pass the *current* mesh's shardings for elastic restore onto a
        different topology than the writer's.
        Returns (tree, step).
        """

        step = step if step is not None else self.latest_step()
        errors.check(
            step is not None, errors.ErrorClass.ERR_IO, f"no checkpoint in {self.directory}"
        )
        self.wait()
        step_dir = os.path.join(self.directory, f"step_{step:08d}")
        f = pio.open(step_dir, Mode.RDONLY, checksum=True)
        names = [n for n, _ in _flatten_with_names(template)]
        flat_t, treedef = jax.tree_util.tree_flatten(template)
        flat_s = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_t)
        )
        restored = []
        for name, tmpl, shd in zip(names, flat_t, flat_s):
            arr = f.read_at_all(name, shd)
            if hasattr(tmpl, "dtype") and arr.dtype != tmpl.dtype:
                arr = arr.astype(tmpl.dtype)
            restored.append(arr)
        return treedef.unflatten(restored), step

    def extra(self, step: int) -> dict:
        p = os.path.join(self.directory, f"step_{step:08d}", "extra.json")
        if os.path.exists(p):
            with open(p) as fh:
                return json.load(fh)
        return {}
