"""Trace-time event ledger — layer 1 of the communication-correctness
analyzer (the MUST / MPI-Checker role for this interface).

MUST observes an MPI program by interposing on the profiling interface and
recording one event per communication call per rank; checkers then walk the
event graph for defects the type system cannot rule out (mismatched
collective order, wait-for cycles, leaked requests).  The adaptation here:
the single-controller SPMD program *traces* its communication — so the
natural interposition point is trace time, and one recorded event describes
the operation for every rank at once (the SPMD program IS the per-rank
program).  Hand-built rank-level schedules (``send_recv`` perms,
``cart_shift`` tables, fan-out rounds) carry genuine per-rank structure, and
the ledger also accepts explicitly per-rank events (``rank=``) for
multi-controller traces and seeded-defect tests.

Recording is **off by default** and toggled by the ``analysis_recording``
control variable (:mod:`repro.core.tool`), the MPI_T cvar idiom the
``error_checking`` macro analogue already uses.  The interface layers guard
every hook on the module-level :data:`RECORDING` bool, so the disabled cost
is one attribute read — measured ≤ 1% on the persistent-series hot path
(``benchmarks/interface_overhead.py``).

This module is import-light on purpose (no repro.core imports): the core
layers import it at module scope without cycles.
"""

from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Any, Iterable, Sequence

#: Hot-path guard.  The interface layers read this module attribute directly
#: (``if events.RECORDING: events.record(...)``); everything else — ledger
#: allocation, locking, metadata extraction — happens only when it is True.
RECORDING = False

_LOCK = threading.Lock()
_TOKENS = itertools.count(1)


@dataclasses.dataclass(frozen=True)
class Event:
    """One recorded communication/lifecycle event.

    ``ranks`` is the rank set the event applies to (``None`` = every rank of
    the communicator is implied, the SPMD default); ``data`` holds
    kind-specific fields (perms, dtype buckets, epoch ids, tokens).
    """

    seq: int
    kind: str
    comm: str = ""
    op: str = ""
    ranks: tuple[int, ...] | None = None
    data: dict[str, Any] = dataclasses.field(default_factory=dict)


class Ledger:
    """Append-only event log plus the live-object tables the lifecycle
    checkers need (outstanding trace futures, window epochs)."""

    def __init__(self) -> None:
        self.events: list[Event] = []
        self._seq = itertools.count()

    def append(self, kind: str, **kw: Any) -> Event:
        data = kw.pop("data", None) or {}
        ev = Event(seq=next(self._seq), kind=kind, data=data, **kw)
        with _LOCK:
            self.events.append(ev)
        return ev

    def of_kind(self, *kinds: str) -> list[Event]:
        return [e for e in self.events if e.kind in kinds]

    def __len__(self) -> int:
        return len(self.events)


_LEDGER = Ledger()


def ledger() -> Ledger:
    return _LEDGER


def reset() -> Ledger:
    """Drop all recorded events (new empty ledger); returns it."""

    global _LEDGER
    _LEDGER = Ledger()
    return _LEDGER


def set_recording(enabled: bool) -> bool:
    """Toggle event recording; returns the previous value.  Normally driven
    by the ``analysis_recording`` cvar, not called directly."""

    global RECORDING
    prev = RECORDING
    RECORDING = bool(enabled)
    return prev


def next_token() -> int:
    """A process-unique id for tracked objects (futures, requests, windows).
    Monotonic — never reused, unlike ``id()``."""

    return next(_TOKENS)


# ---------------------------------------------------------------------------
# typed record helpers (all no-ops unless RECORDING; callers still guard on
# the bool to keep the disabled path to one attribute read)
# ---------------------------------------------------------------------------


def _comm_size(comm: Any) -> int:
    size = getattr(comm, "size", None)
    return int(size()) if callable(size) else 0


def comm_id(comm: Any) -> str:
    """A stable per-communicator key: tag + axis names + size.  Distinct
    communicator objects over the same axes compare equal on purpose — a
    ``dup()`` is ``MPI_IDENT`` and shares the match order."""

    if isinstance(comm, str):
        return comm
    tag = getattr(comm, "tag", "") or ""
    axes = ",".join(getattr(comm, "axis_names", ()) or ())
    return f"{tag}|{axes}|{_comm_size(comm)}"


def dtype_bucket(value: Any) -> tuple[str, ...]:
    """The dtype-bucket signature of an operand aggregate: the sorted tuple
    of leaf dtype names — the C2 datatype key a collective is matched on."""

    import jax

    names = []
    for leaf in jax.tree_util.tree_leaves(value):
        dt = getattr(leaf, "dtype", None)
        names.append(str(dt) if dt is not None else type(leaf).__name__)
    return tuple(sorted(names))


def record_collective(
    comm: Any,
    op: str,
    operand: Any = None,
    *,
    rank: int | None = None,
) -> None:
    """One collective call on ``comm`` (op kind + dtype bucket).  With
    ``rank`` the event applies to that rank only (per-rank traces and
    seeded-defect tests); otherwise to every rank of the communicator."""

    if not RECORDING:
        return
    ranks = (rank,) if rank is not None else tuple(range(_comm_size(comm)))
    _LEDGER.append(
        "collective",
        comm=comm_id(comm),
        op=op,
        ranks=ranks,
        data={"bucket": dtype_bucket(operand) if operand is not None else ()},
    )


def record_p2p_round(
    comm: Any,
    perm: Sequence[tuple[int, int]],
    *,
    mode: str = "sendrecv",
    op: str = "send_recv",
    size: int | None = None,
) -> None:
    """One matching round of point-to-point traffic.

    ``mode="sendrecv"`` is the combined ``MPI_Sendrecv`` form (completes
    atomically; cycles are legal — every ring schedule is one).
    ``mode="sync"`` models unbuffered blocking sends issued before the
    matching receives — the schedule the deadlock checker must reject when
    the round's permutation contains a cycle.
    """

    if not RECORDING:
        return
    if size is None:
        size = _comm_size(comm)
    _LEDGER.append(
        "p2p_round",
        comm=comm_id(comm),
        op=op,
        data={"perm": tuple((int(s), int(d)) for s, d in perm),
              "mode": mode, "size": int(size)},
    )


def record_p2p(kind: str, rank: int, peer: int, *, comm: str = "", op: str = "") -> None:
    """A raw blocking ``send``/``recv`` op for one rank (per-rank traces and
    seeded-defect schedules)."""

    if not RECORDING:
        return
    _LEDGER.append(kind, comm=comm, op=op or kind, ranks=(int(rank),),
                   data={"peer": int(peer)})


def record(kind: str, **kw: Any) -> None:
    """Generic escape hatch (lifecycle hooks use the typed wrappers below)."""

    if not RECORDING:
        return
    _LEDGER.append(kind, **kw)


# -- future / request lifecycle ---------------------------------------------


def record_future_create(token: int, label: str = "") -> None:
    if not RECORDING:
        return
    _LEDGER.append("tf_create", data={"token": int(token), "label": label})


def record_future_consume(token: int, how: str = "get") -> None:
    if not RECORDING:
        return
    _LEDGER.append("tf_consume", data={"token": int(token), "how": how})


def record_persistent_init(token: int, *, donated: bool, label: str = "") -> None:
    if not RECORDING:
        return
    _LEDGER.append(
        "preq_init", data={"token": int(token), "donated": bool(donated),
                           "label": label}
    )


def record_persistent_start(
    token: int, *, donated: bool, prev_outstanding: bool, has_continuations: bool
) -> None:
    if not RECORDING:
        return
    _LEDGER.append(
        "preq_start",
        data={"token": int(token), "donated": bool(donated),
              "prev_outstanding": bool(prev_outstanding),
              "has_continuations": bool(has_continuations)},
    )


# -- RMA windows -------------------------------------------------------------


def record_fence(win: int, epoch: int) -> None:
    if not RECORDING:
        return
    _LEDGER.append("win_fence", data={"win": int(win), "epoch": int(epoch)})


def record_rma_put(
    win: int, epoch: int, targets: Iterable[int], page: Any, *, requested: bool
) -> None:
    if not RECORDING:
        return
    _LEDGER.append(
        "rma_put",
        data={"win": int(win), "epoch": int(epoch),
              "targets": tuple(int(t) for t in targets),
              "page": page, "requested": bool(requested)},
    )


def record_rma_apply(win: int, issue_epoch: int, apply_epoch: int) -> None:
    if not RECORDING:
        return
    _LEDGER.append(
        "rma_apply",
        data={"win": int(win), "issue_epoch": int(issue_epoch),
              "apply_epoch": int(apply_epoch)},
    )


def record_rma_pages(kind: str, win: int, count: int) -> None:
    """``kind`` ∈ {"rma_attach", "rma_detach"} — dynamic-window page
    registration traffic (mirrored from ``kvpool.bind_window``)."""

    if not RECORDING:
        return
    _LEDGER.append(kind, data={"win": int(win), "count": int(count)})


# -- file I/O / checkpoint ---------------------------------------------------


def record_io_split(kind: str, path: str, name: str) -> None:
    """``kind`` ∈ {"io_split_begin", "io_split_end"} — File split
    collectives (one active per handle; unended begins are findings)."""

    if not RECORDING:
        return
    _LEDGER.append(kind, data={"path": path, "name": name})


def record_ckpt(kind: str, mgr: int, step: int | None = None) -> None:
    """``kind`` ∈ {"ckpt_save", "ckpt_join"} — async checkpoint saves must
    be joined before trace exit."""

    if not RECORDING:
        return
    _LEDGER.append(kind, data={"mgr": int(mgr), "step": step})
