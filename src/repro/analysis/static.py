"""Source-level meta-checks: defects in *this repo's own code* rather than
in a traced program.

* :func:`swallowed_failures` — ``except Exception:`` / bare ``except:``
  handlers that neither re-raise nor report: the handler converts a real
  failure into silence, the exact anti-pattern a typed error interface
  exists to kill.  A handler is fine if its body re-raises (``raise``),
  prints the traceback (top-level CLI guard), or the ``except`` line carries
  ``# lint: allow-broad-except`` with a justification.
* :func:`unregistered_pvars` — every *literal* pvar name passed to
  ``tool.pvar_count`` / ``tool.pvar_add`` in the tree must be registered in
  ``tool.PVARS`` (``pvar_register``): an undocumented counter is invisible
  to ``pvar_info`` and drifts silently.  Dynamically-formatted names
  (f-strings in the facade binder) are covered at runtime by
  ``tool.pvar_strict`` instead.
"""

from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, Iterator

from repro.analysis.checkers import Finding
from repro.core.errors import ErrorClass

ALLOW_PRAGMA = "lint: allow-broad-except"


def _py_files(paths: Iterable[str | Path]) -> Iterator[Path]:
    for p in paths:
        p = Path(p)
        if p.is_dir():
            yield from sorted(p.rglob("*.py"))
        elif p.suffix == ".py":
            yield p


def _is_broad(handler: ast.ExceptHandler) -> bool:
    t = handler.type
    if t is None:
        return True                       # bare except:
    if isinstance(t, ast.Name) and t.id in ("Exception", "BaseException"):
        return True
    if isinstance(t, ast.Tuple):
        return any(isinstance(e, ast.Name) and e.id in ("Exception", "BaseException")
                   for e in t.elts)
    return False


def _reports_or_reraises(handler: ast.ExceptHandler) -> bool:
    for node in ast.walk(handler):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            fn = node.func
            if isinstance(fn, ast.Attribute) and fn.attr in ("print_exc", "format_exc"):
                return True
    return False


def swallowed_failures(paths: Iterable[str | Path]) -> list[Finding]:
    findings: list[Finding] = []
    for path in _py_files(paths):
        src = path.read_text()
        lines = src.splitlines()
        try:
            tree = ast.parse(src, filename=str(path))
        except SyntaxError as exc:
            findings.append(Finding(
                ErrorClass.ERR_OTHER, "syntax",
                f"unparseable: {exc}", f"{path}",
            ))
            continue
        for node in ast.walk(tree):
            if not isinstance(node, ast.ExceptHandler) or not _is_broad(node):
                continue
            line = lines[node.lineno - 1] if node.lineno <= len(lines) else ""
            if ALLOW_PRAGMA in line:
                continue
            if _reports_or_reraises(node):
                continue
            what = "bare except" if node.type is None else "except Exception"
            findings.append(Finding(
                ErrorClass.ERR_OTHER, "swallowed-failure",
                f"{what} swallows the error without re-raising or reporting "
                f"— catch the specific expected exception and let the rest "
                f"propagate", f"{path}:{node.lineno}",
            ))
    return findings


def _literal_pvar_writes(paths: Iterable[str | Path]) -> list[tuple[str, str]]:
    """(pvar name, file:line) for every literal pvar_count/pvar_add call."""

    writes: list[tuple[str, str]] = []
    for path in _py_files(paths):
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError:
            continue                      # reported by swallowed_failures
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else (
                fn.id if isinstance(fn, ast.Name) else "")
            if name not in ("pvar_count", "pvar_add"):
                continue
            arg = node.args[0]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                writes.append((arg.value, f"{path}:{node.lineno}"))
    return writes


def unregistered_pvars(paths: Iterable[str | Path]) -> list[Finding]:
    # importing the runtime/checkpoint layers runs their module-level
    # pvar_register calls, populating the registry the audit compares against
    import repro.checkpoint.manager   # noqa: F401
    import repro.core                 # noqa: F401
    import repro.runtime.engine       # noqa: F401
    import repro.runtime.kvpool       # noqa: F401
    import repro.runtime.server       # noqa: F401
    import repro.runtime.trainer      # noqa: F401
    import repro.tune                 # noqa: F401
    from repro.core import tool

    findings: list[Finding] = []
    for name, where in _literal_pvar_writes(paths):
        if name not in tool.PVARS:
            findings.append(Finding(
                ErrorClass.ERR_ARG, "unregistered-pvar",
                f"pvar {name!r} is written but never pvar_register()ed — "
                f"undocumented counters are invisible to pvar_info and "
                f"drift silently", where,
            ))
    return findings


def run_static(paths: Iterable[str | Path]) -> list[Finding]:
    paths = list(paths)
    return swallowed_failures(paths) + unregistered_pvars(paths)
