"""Reusable HLO predicate passes — layer 2 of the analyzer.

`benchmarks/hlo_parity.py` proved schedule properties (no all-gathers, N−1
collective-permutes, 1/N wire fractions) with ad-hoc regex/counting code,
and the tier-1 tests re-implemented the same counting inline.  These passes
are that logic, once: each takes a compiled module (or its HLO text) and
returns a :class:`PassResult` with the evidence, so bench scripts and tests
assert the *same* predicate and cannot drift apart.

All passes accept either the HLO text or any object with ``as_text()``
(``jax`` compiled executables and :class:`~repro.core.futures`
persistent requests both qualify).
"""

from __future__ import annotations

import dataclasses
from typing import Any

from repro.core.hloanalysis import analyze_hlo
from repro.core.tool import CollectiveStats


@dataclasses.dataclass(frozen=True)
class PassResult:
    """One HLO predicate verdict: the claim, whether it holds, and the
    measured evidence backing it."""

    name: str
    ok: bool
    detail: dict[str, Any] = dataclasses.field(default_factory=dict)

    def __bool__(self) -> bool:
        return self.ok

    def __str__(self) -> str:
        state = "ok" if self.ok else "FAIL"
        return f"{self.name}: {state} {self.detail}"


def _text(module: Any) -> str:
    as_text = getattr(module, "as_text", None)
    return as_text() if callable(as_text) else str(module)


def collective_stats(module: Any) -> CollectiveStats:
    """Trip-count-corrected collective stats of one compiled module."""

    return analyze_hlo(_text(module)).collectives


def stats_dict(module: Any) -> dict[str, Any]:
    """The (counts, operand bytes, wire bytes) summary row the parity bench
    records — two modules lower identically iff these compare equal."""

    s = collective_stats(module)
    return {
        "counts": dict(s.count),
        "operand_bytes": s.total_operand_bytes,
        "wire_bytes": s.total_wire_bytes,
    }


def no_collective(module: Any, *kinds: str) -> PassResult:
    """No collective of any of ``kinds`` appears (e.g. prove a sharded
    schedule never materialises via ``all-gather``)."""

    s = collective_stats(module)
    present = {k: s.count[k] for k in kinds if s.count.get(k, 0)}
    return PassResult(
        "no-collective", not present,
        {"forbidden": kinds, "present": present},
    )


def collective_count(module: Any, kind: str, expected: int) -> PassResult:
    """Exactly ``expected`` collectives of ``kind`` (trip-count-corrected)."""

    s = collective_stats(module)
    got = int(s.count.get(kind, 0))
    return PassResult(
        "collective-count", got == expected,
        {"kind": kind, "expected": expected, "got": got},
    )


def permute_count(module: Any, expected: int) -> PassResult:
    """Exactly ``expected`` ``collective-permute`` ops — the round count of
    a ring/halo schedule."""

    res = collective_count(module, "collective-permute", expected)
    return PassResult("permute-count", res.ok, res.detail)


def wire_fraction_below(
    module: Any, dense: Any, bound: float, *, name: str = "wire-fraction"
) -> PassResult:
    """Wire bytes of ``module`` are at most ``bound`` × those of the dense
    reference — the sparsity proof for neighborhood collectives."""

    mw = collective_stats(module).total_wire_bytes
    dw = collective_stats(dense).total_wire_bytes
    frac = (mw / dw) if dw else None
    return PassResult(
        name, frac is not None and frac <= bound,
        {"wire_bytes": mw, "dense_wire_bytes": dw,
         "fraction": frac, "bound": bound},
    )


def neighbor_sparsity(module: Any, dense: Any, *, max_fraction: float = 1.0) -> PassResult:
    """A neighborhood collective lowered *sparse*: axis-local
    collective-permutes only — zero dense ``all-to-all``/``all-reduce`` —
    with wire bytes scaling with the topology degree, not world size."""

    s = collective_stats(module)
    sparse = (
        s.count.get("all-to-all", 0) == 0
        and s.count.get("all-reduce", 0) == 0
        and s.count.get("collective-permute", 0) > 0
    )
    wf = wire_fraction_below(module, dense, max_fraction)
    return PassResult(
        "neighbor-sparsity", sparse and wf.ok,
        {"counts": dict(s.count), "sparse": sparse, **wf.detail},
    )


def ring_schedule(
    module: Any, n: int, *, shard_bytes: float | None = None, tol: float = 1e-9
) -> PassResult:
    """The ring-attention schedule proof: exactly ``n − 1``
    collective-permutes, zero KV all-gathers, and (when ``shard_bytes`` —
    the *global* rotated aggregate, e.g. K+V — is given) a per-step wire
    fraction of ``1/n``: each step moves one shard of the aggregate."""

    s = collective_stats(module)
    permutes = int(s.count.get("collective-permute", 0))
    allgathers = int(s.count.get("all-gather", 0))
    per_step_fraction = None
    fraction_ok = True
    if shard_bytes:
        per_step_fraction = s.total_wire_bytes / max(permutes, 1) / shard_bytes
        fraction_ok = abs(per_step_fraction - 1.0 / n) < tol
    return PassResult(
        "ring-schedule",
        permutes == n - 1 and allgathers == 0 and fraction_ok,
        {"permutes": permutes, "expected_permutes": n - 1,
         "kv_allgathers": allgathers,
         "per_step_wire_fraction": per_step_fraction},
    )


def identical_lowering(a: Any, b: Any) -> PassResult:
    """Two modules lower to the same collective program — the zero-overhead
    parity claim (kinds, counts, payload and wire bytes all equal)."""

    sa, sb = stats_dict(a), stats_dict(b)
    return PassResult("identical-lowering", sa == sb, {"a": sa, "b": sb})


def pvar_invariant(
    counters: dict[str, Any], name: str, expected: int
) -> PassResult:
    """A ``trace:*`` pvar invariant: the counter must read exactly
    ``expected`` (e.g. ``trace:train_step == 1`` — one AOT trace, ever)."""

    got = int(counters.get(name, 0))
    return PassResult(
        "pvar-invariant", got == expected,
        {"pvar": name, "expected": expected, "got": got},
    )
