"""Communication-correctness analysis for the interface (the MUST /
MPI-Checker role): a trace-time event-graph lint plus reusable HLO schedule
passes, both surfaced through ``python -m repro.analysis.lint``.

* :mod:`repro.analysis.events` — the recording ledger (guarded by the
  ``analysis_recording`` cvar, off by default).
* :mod:`repro.analysis.checkers` — event-graph checkers: collective
  order/signature matching, deadlock detection on the point-to-point
  matching graph, future/request lifecycle, RMA epoch discipline,
  I/O joins.  Findings carry typed :class:`~repro.core.errors.ErrorClass`.
* :mod:`repro.analysis.hlo` — predicate passes over compiled modules
  (no-collective, permute counts, wire fractions, ring schedules).
* :mod:`repro.analysis.static` — source meta-checks (swallowed failures,
  unregistered pvars).

Only the ledger is imported eagerly (it is import-light by design); the
checker/HLO layers import on demand so the core interface does not pay for
them.
"""

from repro.analysis import events

__all__ = ["events"]
