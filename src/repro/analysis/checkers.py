"""Event-graph checkers — the MUST-style lint passes over a recorded
:class:`~repro.analysis.events.Ledger`.

Each checker consumes the ledger and returns typed :class:`Finding`\\ s
carrying an :class:`~repro.core.errors.ErrorClass`:

* :func:`check_collective_order` — cross-rank collective ordering/signature
  mismatch per communicator (``ERR_NOT_SAME``): every member rank must issue
  the same (op kind, dtype bucket) sequence on a communicator, the classic
  MUST collective-matching check.
* :func:`check_deadlock` — wait-for cycles and unmatched operations on the
  point-to-point matching graph (``ERR_PENDING``): combined ``send_recv``
  rounds complete atomically, but rounds lowered as unbuffered blocking
  sends (``mode="sync"``) deadlock exactly when the round's permutation
  contains a cycle; raw per-rank ``send``/``recv`` streams are matched by
  the standard non-overtaking simulation.  Illegal matching rounds (two
  sends out of one rank, two writes into one rank) are ``ERR_RANK``.
* :func:`check_future_lifecycle` — requests leaked or raced
  (``ERR_REQUEST`` / ``ERR_BUFFER``): TraceFutures dangling un-consumed at
  trace exit, and ``MPI_Start`` re-fires of a *donated*
  :class:`~repro.core.futures.PersistentRequest` while a previous start's
  future is still unconsumed (the ``then()`` chain would read
  donated-over buffers).
* :func:`check_rma_epochs` — one-sided synchronization defects beyond the
  runtime per-epoch ledger (``ERR_WIN`` / ``ERR_RMA_ATTACH``): a put issued
  in one fence epoch but applied in a later one (a ``then()`` continuation
  escaping its epoch), and dynamic-window attach/detach imbalance at trace
  exit (KV blocks never released).
* :func:`check_io_joins` — split collectives begun but never ended and
  checkpoint saves never joined (``ERR_IO``): a torn save that exits the
  trace un-waited is indistinguishable from data loss.

:func:`run_all` aggregates every checker, in this order.
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Any, Sequence

from repro.analysis.events import Event, Ledger, ledger as _default_ledger
from repro.core.errors import ErrorClass


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer finding: a typed defect with its evidence."""

    code: ErrorClass
    check: str
    message: str
    subject: str = ""

    def as_dict(self) -> dict[str, Any]:
        return {
            "code": self.code.name,
            "check": self.check,
            "message": self.message,
            "subject": self.subject,
        }

    def __str__(self) -> str:
        sub = f" [{self.subject}]" if self.subject else ""
        return f"{self.code.name} {self.check}{sub}: {self.message}"


# ---------------------------------------------------------------------------
# (a) collective order / signature matching
# ---------------------------------------------------------------------------


def check_collective_order(ledger: Ledger | None = None) -> list[Finding]:
    ledger = ledger or _default_ledger()
    seqs: dict[str, dict[int, list[tuple[str, tuple]]]] = defaultdict(
        lambda: defaultdict(list)
    )
    for ev in ledger.of_kind("collective"):
        for r in ev.ranks or ():
            seqs[ev.comm][int(r)].append((ev.op, tuple(ev.data.get("bucket", ()))))
    findings: list[Finding] = []
    for comm, by_rank in seqs.items():
        ranks = sorted(by_rank)
        ref_rank = ranks[0]
        ref = by_rank[ref_rank]
        for r in ranks[1:]:
            seq = by_rank[r]
            for i, (a, b) in enumerate(zip(ref, seq)):
                if a[0] != b[0]:
                    findings.append(Finding(
                        ErrorClass.ERR_NOT_SAME, "collective-order",
                        f"rank {ref_rank} issues {a[0]} as collective #{i} "
                        f"but rank {r} issues {b[0]} — mismatched collective "
                        f"order across ranks", comm,
                    ))
                    break
                if a[1] != b[1]:
                    findings.append(Finding(
                        ErrorClass.ERR_NOT_SAME, "collective-signature",
                        f"collective #{i} ({a[0]}) has dtype bucket {a[1]} on "
                        f"rank {ref_rank} but {b[1]} on rank {r} — mismatched "
                        f"datatype signature", comm,
                    ))
                    break
            else:
                if len(ref) != len(seq):
                    findings.append(Finding(
                        ErrorClass.ERR_NOT_SAME, "collective-order",
                        f"rank {ref_rank} issues {len(ref)} collectives but "
                        f"rank {r} issues {len(seq)} — some ranks hang in a "
                        f"collective the others never enter", comm,
                    ))
    return findings


# ---------------------------------------------------------------------------
# (b) deadlock detection on the point-to-point matching graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _Op:
    kind: str            # "send" | "recv" | "xchg"
    rank: int
    peer: int = -1       # send → destination, recv → source
    round_id: int = -1   # xchg ops complete round-atomically


def _round_legal(perm: Sequence[tuple[int, int]]) -> str | None:
    srcs = [s for s, _ in perm]
    dsts = [d for _, d in perm]
    if len(set(srcs)) != len(srcs):
        return "an origin sends twice in one matching round"
    if len(set(dsts)) != len(dsts):
        return "a target is written twice in one matching round"
    return None


def _expand_ops(ledger: Ledger) -> tuple[dict[tuple[str, int], list[_Op]], list[Finding]]:
    """Per-(comm, rank) ordered op queues from the recorded rounds/streams."""

    queues: dict[tuple[str, int], list[_Op]] = defaultdict(list)
    findings: list[Finding] = []
    round_ids = 0
    for ev in ledger.events:
        if ev.kind == "p2p_round":
            perm = [tuple(p) for p in ev.data["perm"]]
            illegal = _round_legal(perm)
            if illegal:
                findings.append(Finding(
                    ErrorClass.ERR_RANK, "matching-round",
                    f"{ev.op} round {perm}: {illegal}", ev.comm,
                ))
                continue
            if ev.data.get("mode", "sendrecv") == "sendrecv":
                round_ids += 1
                for s, d in perm:
                    queues[(ev.comm, s)].append(
                        _Op("xchg", s, peer=d, round_id=round_ids))
                    if d != s:
                        queues[(ev.comm, d)].append(
                            _Op("xchg", d, peer=s, round_id=round_ids))
            else:   # "sync": unbuffered blocking sends issued before receives
                for s, d in perm:
                    queues[(ev.comm, s)].append(_Op("send", s, peer=d))
                for s, d in perm:
                    queues[(ev.comm, d)].append(_Op("recv", d, peer=s))
        elif ev.kind in ("send", "recv"):
            r = (ev.ranks or (0,))[0]
            queues[(ev.comm, int(r))].append(
                _Op(ev.kind, int(r), peer=int(ev.data["peer"])))
    return queues, findings


def check_deadlock(ledger: Ledger | None = None) -> list[Finding]:
    ledger = ledger or _default_ledger()
    queues, findings = _expand_ops(ledger)
    # simulate matching per communicator independently
    comms = sorted({c for c, _ in queues})
    for comm in comms:
        ranks = sorted(r for c, r in queues if c == comm)
        ptr = {r: 0 for r in ranks}

        def current(r: int) -> _Op | None:
            q = queues[(comm, r)]
            return q[ptr[r]] if ptr[r] < len(q) else None

        progress = True
        while progress:
            progress = False
            # 1. blocking send/recv pairs whose partners are both current
            for r in ranks:
                op = current(r)
                if op is None or op.kind != "send":
                    continue
                partner = current(op.peer) if op.peer in ptr else None
                if partner is not None and partner.kind == "recv" and partner.peer == r:
                    ptr[r] += 1
                    ptr[op.peer] += 1
                    progress = True
            # 2. sendrecv rounds: complete when every participant is at the round
            pending_rounds: dict[int, list[int]] = defaultdict(list)
            for r in ranks:
                op = current(r)
                if op is not None and op.kind == "xchg":
                    pending_rounds[op.round_id].append(r)
            for rid, members in pending_rounds.items():
                all_here = all(
                    (cur := current(r)) is not None and cur.kind == "xchg"
                    and cur.round_id == rid
                    for r in _round_members(queues, comm, rid)
                )
                if all_here:
                    # a rank that is both origin and target of the round holds
                    # several contiguous xchg ops for it — drain them all
                    for r in _round_members(queues, comm, rid):
                        q = queues[(comm, r)]
                        while (ptr[r] < len(q) and q[ptr[r]].kind == "xchg"
                               and q[ptr[r]].round_id == rid):
                            ptr[r] += 1
                    progress = True
                    break
        blocked = {r: current(r) for r in ranks if current(r) is not None}
        if not blocked:
            continue
        cycle = _wait_cycle(blocked)
        if cycle:
            path = " -> ".join(str(r) for r in cycle)
            findings.append(Finding(
                ErrorClass.ERR_PENDING, "deadlock",
                f"wait-for cycle {path}: every rank in the cycle is blocked "
                f"in an unbuffered send/recv waiting on the next — the "
                f"schedule deadlocks (use the combined send_recv form or "
                f"reorder the rounds)", comm,
            ))
        else:
            detail = ", ".join(
                f"rank {r} blocked in {op.kind}"
                f"{' to' if op.kind == 'send' else ' from'} {op.peer}"
                for r, op in sorted(blocked.items())
            )
            findings.append(Finding(
                ErrorClass.ERR_PENDING, "unmatched-p2p",
                f"operations never matched: {detail} — the partner never "
                f"issues the matching call", comm,
            ))
    return findings


def _round_members(queues, comm: str, rid: int) -> list[int]:
    members = []
    for (c, r), q in queues.items():
        if c == comm and any(op.kind == "xchg" and op.round_id == rid for op in q):
            members.append(r)
    return sorted(members)


def _wait_cycle(blocked: dict[int, _Op]) -> list[int] | None:
    """A cycle in the wait-for graph of blocked ranks (each waits on its
    partner), or None if the stall is an unmatched op, not a cycle."""

    waits: dict[int, int] = {}
    for r, op in blocked.items():
        if op.peer in blocked:
            waits[r] = op.peer
    seen: dict[int, int] = {}
    for start in waits:
        path: list[int] = []
        r = start
        while r in waits and r not in seen:
            seen[r] = start
            path.append(r)
            r = waits[r]
        if r in path:       # closed a cycle within this walk
            return path[path.index(r):] + [r]
    return None


# ---------------------------------------------------------------------------
# (c) future / request lifecycle
# ---------------------------------------------------------------------------


def check_future_lifecycle(ledger: Ledger | None = None) -> list[Finding]:
    ledger = ledger or _default_ledger()
    findings: list[Finding] = []
    created: dict[int, str] = {}
    for ev in ledger.of_kind("tf_create"):
        created[ev.data["token"]] = ev.data.get("label", "")
    for ev in ledger.of_kind("tf_consume"):
        created.pop(ev.data["token"], None)
    if created:
        labels = sorted(set(filter(None, created.values()))) or ["<anonymous>"]
        findings.append(Finding(
            ErrorClass.ERR_REQUEST, "dangling-future",
            f"{len(created)} TraceFuture(s) never consumed at trace exit "
            f"(never forced by get()/then()/when_all — their communication "
            f"is silently dropped from the program): {', '.join(labels[:6])}",
        ))
    donated: dict[int, str] = {}
    for ev in ledger.of_kind("preq_init"):
        if ev.data.get("donated"):
            donated[ev.data["token"]] = ev.data.get("label", "")
    for ev in ledger.of_kind("preq_start"):
        if ev.data.get("donated") and ev.data.get("prev_outstanding"):
            label = donated.get(ev.data["token"], "")
            findings.append(Finding(
                ErrorClass.ERR_BUFFER, "donated-start-race",
                f"persistent request{f' {label!r}' if label else ''} with "
                f"donated buffers re-started while a previous start's future "
                f"is still unconsumed — the outstanding then() chain reads "
                f"donated-over memory",
            ))
    return findings


# ---------------------------------------------------------------------------
# (d) RMA epoch discipline
# ---------------------------------------------------------------------------


def check_rma_epochs(ledger: Ledger | None = None) -> list[Finding]:
    ledger = ledger or _default_ledger()
    findings: list[Finding] = []
    for ev in ledger.of_kind("rma_apply"):
        if ev.data["issue_epoch"] != ev.data["apply_epoch"]:
            findings.append(Finding(
                ErrorClass.ERR_WIN, "cross-epoch-put",
                f"put issued in fence epoch {ev.data['issue_epoch']} but "
                f"applied in epoch {ev.data['apply_epoch']} — a then() "
                f"continuation escaped its access epoch (complete the chain "
                f"before the closing fence)", f"win:{ev.data['win']}",
            ))
    attached: dict[int, int] = defaultdict(int)
    for ev in ledger.of_kind("rma_attach"):
        attached[ev.data["win"]] += ev.data["count"]
    for ev in ledger.of_kind("rma_detach"):
        attached[ev.data["win"]] -= ev.data["count"]
    for win, balance in sorted(attached.items()):
        if balance != 0:
            findings.append(Finding(
                ErrorClass.ERR_RMA_ATTACH, "attach-detach-imbalance",
                f"dynamic window ends the trace with {balance:+d} "
                f"attach/detach imbalance — pages (KV blocks) registered but "
                f"never released", f"win:{win}",
            ))
    return findings


# ---------------------------------------------------------------------------
# (e) file I/O / checkpoint joins
# ---------------------------------------------------------------------------


def check_io_joins(ledger: Ledger | None = None) -> list[Finding]:
    ledger = ledger or _default_ledger()
    findings: list[Finding] = []
    open_splits: dict[str, str] = {}
    for ev in ledger.of_kind("io_split_begin", "io_split_end"):
        key = ev.data["path"]
        if ev.kind == "io_split_begin":
            open_splits[key] = ev.data["name"]
        else:
            open_splits.pop(key, None)
    for path, name in sorted(open_splits.items()):
        findings.append(Finding(
            ErrorClass.ERR_IO, "split-collective-open",
            f"split collective on {name!r} begun but never ended — the "
            f"*_at_all_end call is missing", path,
        ))
    saves: dict[int, int] = defaultdict(int)
    for ev in ledger.of_kind("ckpt_save"):
        saves[ev.data["mgr"]] += 1
    for ev in ledger.of_kind("ckpt_join"):
        saves[ev.data["mgr"]] = 0
    for mgr, n in sorted(saves.items()):
        if n > 0:
            findings.append(Finding(
                ErrorClass.ERR_IO, "unjoined-save",
                f"{n} checkpoint save(s) in flight at trace exit and never "
                f"joined — a torn save would read as success (call "
                f"manager.wait())", f"ckpt:{mgr}",
            ))
    return findings


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------

ALL_CHECKS = (
    check_collective_order,
    check_deadlock,
    check_future_lifecycle,
    check_rma_epochs,
    check_io_joins,
)


def run_all(ledger: Ledger | None = None) -> list[Finding]:
    """Every event-graph checker over one ledger, findings concatenated."""

    ledger = ledger or _default_ledger()
    findings: list[Finding] = []
    for chk in ALL_CHECKS:
        findings.extend(chk(ledger))
    return findings
