"""The analyzer CLI — the CI gate.

    python -m repro.analysis.lint examples/ --strict

Two passes:

1. **Static** — the source meta-checks (:mod:`repro.analysis.static`) over
   ``src/`` and ``benchmarks/``: swallowed ``except Exception`` handlers and
   unregistered pvar writes.
2. **Dynamic** — every example script runs in a fresh subprocess with the
   ``analysis_recording`` cvar enabled; at exit the event-graph checkers
   (:mod:`repro.analysis.checkers`) walk the recorded ledger and report
   findings over a line protocol (``ANALYSIS_FINDINGS <json>``).  A script
   that crashes is itself a finding (``ERR_OTHER``).

``--strict`` exits non-zero on any finding; without it the lint only
reports.  ``--no-run`` skips the dynamic pass (static checks only).
"""

from __future__ import annotations

import argparse
import json
import os
import shlex
import subprocess
import sys
from pathlib import Path

from repro.analysis.checkers import Finding
from repro.core.errors import ErrorClass

ROOT = Path(__file__).resolve().parents[3]
MARKER = "ANALYSIS_FINDINGS "

_RUNNER = r"""
import json, runpy, sys
from repro.core import tool
from repro.analysis import checkers

path = sys.argv[1]
sys.argv = [path] + sys.argv[2:]
tool.cvar_set("analysis_recording", True)
tool.pvar_strict(True)
rc = 0
try:
    runpy.run_path(path, run_name="__main__")
except SystemExit as exc:
    rc = int(exc.code or 0) if not isinstance(exc.code, str) else 1
findings = checkers.run_all()
print(MARKER + json.dumps([f.as_dict() for f in findings]))
sys.exit(rc)
""".replace("MARKER", repr(MARKER))


def _example_env() -> dict[str, str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def _lint_args(path: Path) -> list[str]:
    """Per-script lint arguments: a ``# lint-args: …`` line in the script's
    head scales a long-running example down to gate size (the demo defaults
    stay untouched)."""

    for line in path.read_text().splitlines()[:30]:
        if line.strip().startswith("# lint-args:"):
            return shlex.split(line.split(":", 1)[1])
    return []


def lint_script(path: Path, *, timeout: int = 900) -> list[Finding]:
    """Run one script under recording; its event-graph findings."""

    try:
        proc = subprocess.run(
            [sys.executable, "-c", _RUNNER, str(path), *_lint_args(path)],
            capture_output=True, text=True, env=_example_env(),
            timeout=timeout, cwd=str(ROOT),
        )
    except subprocess.TimeoutExpired:
        return [Finding(
            ErrorClass.ERR_OTHER, "script-timeout",
            f"did not finish within {timeout}s under recording", str(path),
        )]
    findings: list[Finding] = []
    payload = None
    for line in proc.stdout.splitlines():
        if line.startswith(MARKER):
            payload = json.loads(line[len(MARKER):])
    if proc.returncode != 0:
        findings.append(Finding(
            ErrorClass.ERR_OTHER, "script-failed",
            f"exited {proc.returncode}: {proc.stderr.strip().splitlines()[-1] if proc.stderr.strip() else 'no stderr'}",
            str(path),
        ))
    if payload is None:
        if proc.returncode == 0:
            findings.append(Finding(
                ErrorClass.ERR_OTHER, "no-findings-channel",
                "script produced no ANALYSIS_FINDINGS line", str(path),
            ))
    else:
        for f in payload:
            findings.append(Finding(
                ErrorClass[f["code"]], f["check"], f["message"],
                f.get("subject") or str(path),
            ))
    return findings


def _scripts(paths: list[str]) -> list[Path]:
    out: list[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.glob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    return out


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.analysis.lint",
        description="communication-correctness lint: static meta-checks + "
                    "event-graph analysis of example runs",
    )
    ap.add_argument("paths", nargs="*", default=["examples"],
                    help="scripts or directories to run under recording")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on any finding")
    ap.add_argument("--no-run", action="store_true",
                    help="static meta-checks only; skip running scripts")
    ap.add_argument("--static-paths", nargs="*",
                    default=["src", "benchmarks"],
                    help="trees for the static meta-checks")
    ap.add_argument("--timeout", type=int, default=900)
    args = ap.parse_args(argv)

    from repro.analysis.static import run_static

    findings = run_static([ROOT / p for p in args.static_paths])
    scripts = [] if args.no_run else _scripts(args.paths or ["examples"])
    for script in scripts:
        print(f"[lint] {script}", flush=True)
        findings.extend(lint_script(script, timeout=args.timeout))

    for f in findings:
        print(f"  {f}")
    n = len(findings)
    print(f"[lint] {len(scripts)} script(s) analyzed, {n} finding(s)")
    return 1 if (args.strict and n) else 0


if __name__ == "__main__":
    raise SystemExit(main())
