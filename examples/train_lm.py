"""End-to-end training driver: a ~100M-parameter dense LM on the synthetic
Markov stream with the full production stack (sharding rules, AdamW,
checkpointing, straggler guard).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/train_lm.py --steps 300

On CPU this is slow at the full 100M scale; ``--small`` selects a ~14M
variant that finishes a few hundred steps in minutes.
"""
# lint-args: --small --steps 60

import argparse
import json

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import Trainer, TrainerConfig


def model_100m() -> ModelConfig:
    # ~103M params: 12L, d=768, 12H, d_ff=2048, 32k vocab (GPT-2-small-ish)
    return ModelConfig(
        name="demo_100m", family="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, head_dim=64, d_ff=2048,
        vocab_size=32_000, tie_embeddings=True,
    )


def model_small() -> ModelConfig:
    return ModelConfig(
        name="demo_14m", family="dense", num_layers=4, d_model=256,
        num_heads=4, num_kv_heads=2, head_dim=64, d_ff=1024,
        vocab_size=8_000, tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg = model_small() if args.small else model_100m()
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")
    trainer = Trainer(
        cfg,
        ParallelConfig(remat="none"),
        TrainerConfig(
            steps=args.steps, lr=1e-3, warmup_steps=20,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=max(50, args.steps // 4), log_every=10,
        ),
        make_host_mesh(),
        seq_len=args.seq,
        global_batch=args.batch,
    )
    result = trainer.run()
    losses = [m["loss"] for m in result["metrics"]]
    if not losses:
        # a previous run's checkpoint in --checkpoint-dir already reached
        # --steps; the restore resumes past the last step and trains nothing
        print(f"already complete at step {result['final_step']} "
              f"(stale {args.checkpoint_dir}; remove it to retrain)")
        return
    print(f"steps: {result['final_step']}  loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss did not decrease"
    if args.out:
        with open(args.out, "w") as f:
            json.dump(result, f, indent=1)


if __name__ == "__main__":
    main()
