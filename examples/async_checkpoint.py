"""Nonblocking collective file I/O as the checkpoint subsystem (MPI 4.0
chapter 14): request-based async saves that overlap compute, a single
manifest commit per step, typed failure propagation (a torn save can never
read as success), and file views that round-trip the C2 packed layout
page-by-page.

    PYTHONPATH=src python examples/async_checkpoint.py
"""

import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core as mpx
from repro.core import errors
from repro.core import io as pio
from repro.checkpoint import CheckpointManager
from repro.runtime.faults import FaultInjector


def async_save_overlaps_compute(ckpt_dir: str) -> None:
    state = {
        "params": jnp.arange(1 << 20, dtype=jnp.float32),
        "moments": jnp.ones((1 << 19,), jnp.bfloat16),
    }
    mgr = CheckpointManager(ckpt_dir, async_save=True)
    step_fn = jax.jit(lambda a: a @ a.T / 256.0 + 1.0)
    x = jnp.ones((256, 256))
    jax.block_until_ready(step_fn(x))

    t0 = time.perf_counter()
    req = mgr.save(1, state)         # returns with the I/O still in flight
    issue_ms = (time.perf_counter() - t0) * 1e3
    for _ in range(20):              # "the next persistent step"
        x = step_fn(x)
    jax.block_until_ready(x)
    step_dir = mgr.wait()            # durability point; re-raises failures
    print(f"async save issued in {issue_ms:.1f} ms, committed to {step_dir}")
    assert req.test()                # completion observable on the request

    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    np.testing.assert_array_equal(
        np.asarray(restored["moments"], np.float32),
        np.asarray(state["moments"], np.float32),
    )
    print(f"restored step {step}: bf16 bucket round-tripped exactly")


def torn_save_surfaces(ckpt_dir: str) -> None:
    state = {"w": jnp.ones((128, 128))}
    mgr = CheckpointManager(
        ckpt_dir, async_save=True, injector=FaultInjector(fail_fragments=("w",))
    )
    mgr.save(1, state)
    try:
        mgr.wait()
        raise AssertionError("a torn save must not report success")
    except errors.IoError as e:
        print(f"torn save surfaced as typed failure: {e}")
    assert mgr.latest_step() is None  # `latest` never advanced


def paged_view_roundtrip(path: str) -> None:
    @dataclasses.dataclass
    class KVCache:
        keys: object
        values: object

    cache = KVCache(
        keys=jnp.arange(64, dtype=jnp.bfloat16).reshape(8, 8) / 9,
        values=jnp.ones((8, 8), jnp.float32),
    )
    f = pio.open(path, pio.Mode.CREATE | pio.Mode.WRONLY)
    f.set_view(filetype=cache, num_pages=4)   # the RMA-window page layout
    rec = f.write_at_all("kv", cache)
    out = (
        pio.open(path, pio.Mode.RDONLY)
        .set_view(filetype=cache, num_pages=4)
        .read_at_all("kv")
    )
    np.testing.assert_array_equal(
        np.asarray(out.keys, np.float32), np.asarray(cache.keys, np.float32)
    )
    print(f"paged view round-trip: {len(rec['fragments'])} page fragments, "
          f"{len(rec['view']['groups'])} dtype groups")


def chained_requests(path: str) -> None:
    f = pio.open(path, pio.Mode.CREATE | pio.Mode.WRONLY)
    reqs = [f.iwrite_at_all(n, np.full(8, i)) for i, n in enumerate("abc")]
    names = mpx.when_all(reqs).then(
        lambda joined: [r["name"] for r in joined.get()]
    )
    print(f"when_all + then over I/O requests: {names.get()}")


def main():
    with tempfile.TemporaryDirectory() as d:
        async_save_overlaps_compute(f"{d}/ckpt")
        torn_save_surfaces(f"{d}/torn")
        paged_view_roundtrip(f"{d}/view.mpio")
        chained_requests(f"{d}/chain.mpio")
    print("ok")


if __name__ == "__main__":
    main()
