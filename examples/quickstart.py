"""Quickstart — the paper's two listings, runnable.

Listing 1: user-defined types in communication without manual datatype
registration (aggregate reflection).
Listing 2: requests as futures chained with .then() into an async sequence.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro import core as mpx


# --- Listing 1: aggregate reflection ----------------------------------------

@dataclasses.dataclass
class Particle:
    position: jax.Array     # (3,)
    velocity: jax.Array     # (3,)
    mass: jax.Array         # ()


def listing1():
    comm = mpx.world()
    mpx.register_aggregate(Particle)   # the PFR step (also implicit on use)

    @comm.spmd
    def exchange():
        p = Particle(
            position=jnp.ones((3,)),
            velocity=jnp.full((3,), comm.rank(), jnp.float32),
            mass=jnp.float32(1.0),
        )
        # no manual MPI_Type_create_struct: the interface reflects the
        # aggregate, packs it per-dtype, runs ONE collective per buffer
        return comm.allreduce(p)

    total = exchange()
    print("Listing 1 — allreduced Particle:")
    print("  position:", total.position, " velocity:", total.velocity,
          " mass:", total.mass)


# --- Listing 2: futures with continuations -----------------------------------

def listing2():
    comm = mpx.world()

    @comm.spmd
    def chain():
        data = jnp.where(comm.rank() == 0, jnp.int32(1), jnp.int32(0))
        f = mpx.future(comm.immediate_broadcast(data, root=0))
        f = f.then(lambda fut: comm.immediate_broadcast(
            jnp.where(comm.rank() == 1, fut.get() + 1, fut.get()), root=1))
        f = f.then(lambda fut: comm.immediate_broadcast(
            jnp.where(comm.rank() == 2, fut.get() + 1, fut.get()), root=2))
        return f.get()          # data == 3 on all ranks

    print("Listing 2 — chained broadcasts:", int(chain()), "(expect 3)")


# --- task graph: forks + when_all (MPI_Waitall) -------------------------------

def task_graph():
    comm = mpx.world()

    @comm.spmd
    def graph():
        a = comm.immediate_allreduce(jnp.float32(comm.rank()))
        b = comm.immediate_broadcast(jnp.float32(100.0), root=0)
        joined = mpx.trace_when_all([a, b])
        return joined.then(lambda f: f.get()[0] + f.get()[1]).get()

    print("task graph (fork/join):", float(graph()))


if __name__ == "__main__":
    print(f"world: {mpx.world().size()} devices")
    listing1()
    listing2()
    task_graph()
