"""Sessions in anger: trainer and server on disjoint process sets.

The MPI 4.0 pitch made concrete — one platform, two workloads, neither ever
touches ``world()``.  A session enumerates the devices, the first half is
registered as ``repro://train`` and the second as ``repro://serve``; the
Trainer and the Server each build their communicator from *their* group
with ``Communicator.from_group``, so training steps and decode steps run on
disjoint hardware.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/session_train_serve.py
"""

import numpy as np

from repro import core as mpx
from repro.configs.base import ModelConfig, ParallelConfig
from repro.runtime.server import Request, Server, ServerConfig
from repro.runtime.trainer import Trainer, TrainerConfig


def tiny_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    )


def main():
    sess = mpx.Session.init()
    world = sess.group("repro://world")
    n = world.size()
    print(f"session: {sess} — psets: {sess.psets()}")

    half = max(1, n // 2)
    sess.register_pset("repro://train", world.incl(range(half)))
    sess.register_pset("repro://serve", world.difference(world.incl(range(half))) or world)

    train_comm = mpx.Communicator.from_group(
        sess.group("repro://train"),
        tag="repro://train",
        shape=(half, 1),
        axis_names=("data", "model"),
    )
    serve_group = sess.group("repro://serve")
    serve_comm = mpx.Communicator.from_group(
        serve_group,
        tag="repro://serve",
        shape=(serve_group.size(), 1),
        axis_names=("data", "model"),
    )
    overlap = train_comm.group().intersection(serve_comm.group())
    print(f"train: {train_comm}\nserve: {serve_comm}\n"
          f"overlapping devices: {overlap.size()} (expect 0 with >1 device)")

    cfg, pcfg = tiny_cfg(), ParallelConfig()
    trainer = Trainer(
        cfg, pcfg, TrainerConfig(steps=10, lr=1e-3, log_every=5),
        train_comm, seq_len=64, global_batch=4,
    )
    result = trainer.run()
    print(f"trained to step {result['final_step']}: "
          f"loss {result['metrics'][-1]['loss']:.4f}")

    server = Server(cfg, pcfg, ServerConfig(max_batch=4, max_new_tokens=8), serve_comm)
    rng = np.random.default_rng(0)
    reqs = [
        Request(tokens=rng.integers(1, cfg.vocab_size, size=(16,), dtype=np.int32))
        for _ in range(4)
    ]
    tokens, stats = server.generate(reqs)
    print(f"served {tokens.shape} tokens at {stats['tokens_per_s']:.1f} tok/s "
          f"on {serve_comm.size()} devices")


if __name__ == "__main__":
    main()
