"""Disaggregated prefill/decode serving over the RMA window transport.

The ROADMAP's serving-scale scenario made concrete on one platform: the
serving process set is split into a *prefill* group and a *decode* group
(MPI 4.0 group algebra, PR 1); prefill ranks compute the KV cache and
``rput`` it — page by page, each page's request chained onto the previous —
into an RMA window exposed by the decode ranks (MPI 4.0 chapter 12, the C1
one-sided interface); the decode group then generates tokens on its own
persistent decode request, never touching prefill hardware again.

The check that matters: at ``temperature=0`` the disaggregated pipeline is
**token-for-token identical** to the single-group ``Server.generate``
baseline — the transport moved the whole cache, bit-exactly, through the
window (the decode-side buffers start as zeros, so parity proves the pages
actually crossed).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/disaggregated_serve.py
"""

import numpy as np

from repro import core as mpx
from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import make_host_communicator
from repro.runtime.server import DisaggregatedServer, Request, Server, ServerConfig


def tiny_cfg() -> ModelConfig:
    # float32: the window transport is bit-exact in any dtype (pack +
    # permute + masked select, no arithmetic), but bf16 *compute* rounds
    # differently across mesh partitionings, which can flip near-tied
    # argmaxes between the 8-device baseline and the 4-device decode group —
    # the parity check below isolates the transport, not XLA's bf16 rounding
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128, dtype="float32",
    )


def main():
    cfg, pcfg = tiny_cfg(), ParallelConfig()
    scfg = ServerConfig(max_batch=4, max_new_tokens=12, temperature=0.0)

    rng = np.random.default_rng(0)
    reqs = [
        Request(tokens=rng.integers(1, cfg.vocab_size, size=(24,), dtype=np.int32))
        for _ in range(scfg.max_batch)
    ]

    # single-group baseline: prefill + decode share the whole process set
    baseline = Server(cfg, pcfg, scfg, make_host_communicator())
    base_tokens, base_stats = baseline.generate(reqs)

    # disaggregated: prefill and decode on disjoint halves, KV over RMA
    server = DisaggregatedServer(cfg, pcfg, scfg, kv_pages=4)
    sess = mpx.default_session()
    print(f"prefill pset: {sess.pset_info('repro://world/prefill')}")
    print(f"decode pset:  {sess.pset_info('repro://world/decode')}")
    overlap = server.prefill.comm.group().intersection(server.decode.comm.group())
    print(f"overlapping devices: {overlap.size()} (expect 0 with >1 device)")

    tokens, stats = server.generate(reqs)
    print(f"prefill {stats['prefill_s']*1e3:.0f} ms on {stats['prefill_devices']} devices  "
          f"| KV {stats['kv_bytes']/1024:.1f} KiB in {stats['kv_pages']} pages "
          f"({stats['transfer_s']*1e3:.0f} ms)  "
          f"| decode {stats['decode_s']*1e3:.0f} ms on {stats['decode_devices']} devices  "
          f"| {stats['tokens_per_s']:.1f} tok/s")

    assert np.array_equal(tokens, base_tokens), (
        f"disaggregated tokens diverged from the single-group baseline:\n"
        f"{tokens}\nvs\n{base_tokens}"
    )
    print(f"token-for-token parity with the single-group baseline: OK {tokens.shape}")
    pv = mpx.tool.pvar_read()
    print("pvars:", {k: v for k, v in pv.items() if k.startswith("rma_") or "kv_transfer" in k})


if __name__ == "__main__":
    main()
