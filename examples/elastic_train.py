"""Elastic training demo: the job survives a rank eviction without a restart.

A trainer on a ``(4, 2)`` fabric loses rank 2 at step 5.  ULFM-style, the
epoch is revoked, the survivor group is ``Group.difference``-shrunk, the
fabric rebuilds over 6 devices as ``(3, 2)``, the last committed manifest
restores onto the survivors, and the loop continues — same process, new
communicator generation.  At step 8 a spare device hot-joins and the data
axis grows back to 4.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_train.py
"""

import tempfile

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.communicator import Communicator
from repro.core.session import Session
from repro.runtime.faults import FaultInjector
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    cfg = ModelConfig(
        name="tiny", family="dense", num_layers=1, d_model=32, num_heads=2,
        num_kv_heads=2, head_dim=16, d_ff=64, vocab_size=64,
    )
    world = Session.init().group("repro://world")
    comm = Communicator.from_group(
        world, tag="repro://train", shape=(4, 2), axis_names=("data", "model"))
    injector = FaultInjector().evict_rank(5, 2).admit_rank(8)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg,
            ParallelConfig(),
            TrainerConfig(steps=10, lr=1e-3, checkpoint_dir=ckpt_dir,
                          checkpoint_every=2, log_every=2, seed=7),
            comm,
            seq_len=32,
            global_batch=12,
            injector=injector,
        )
        result = trainer.run()
    print(
        f"finished step {result['final_step']} on epoch "
        f"{result['epoch']} (world size {result['world_size']}): "
        f"{result['evictions']} eviction(s), {result['joins']} hot-join(s), "
        f"0 job restarts"
    )
    assert result["final_step"] == 10
    assert result["evictions"] == 1 and result["joins"] == 1
    assert result["restarts"] == 0
    assert result["epoch"] == 2 and result["world_size"] == 8


if __name__ == "__main__":
    main()
