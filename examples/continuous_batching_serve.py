"""Continuous-batching serving on the RMA paged-KV engine: ragged requests
join the running decode iteration as slots free up, grow their KV block by
block out of a budgeted pool (preempting the newest row under pressure),
and retire at their own generation budget — then every output is checked
token-for-token against the fixed-batch Server oracle.

    PYTHONPATH=src python examples/continuous_batching_serve.py
"""

import argparse

import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import make_host_communicator
from repro.runtime.engine import Engine, EngineConfig
from repro.runtime.server import Request, Server, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="live KV block budget (small values force preemption)")
    args = ap.parse_args()

    # float32 keeps the oracle comparison exact: near-tied argmaxes under
    # bf16 rounding can flip between batch shapes
    cfg = ModelConfig(
        name="demo", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256, dtype="float32",
    )
    scfg = ServerConfig(max_batch=4, max_new_tokens=8, temperature=0.0)
    server = Server(cfg, ParallelConfig(), scfg, make_host_communicator())

    bucket = 8
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(1, cfg.vocab_size,
                     (int(rng.integers(2, bucket + 1)),), dtype=np.int32)
        for _ in range(args.requests)
    ]
    budgets = [int(rng.integers(2, scfg.max_new_tokens + 1)) for _ in prompts]

    eng = Engine(server, EngineConfig(
        prompt_bucket=bucket, block_tokens=4, pool_blocks=args.pool_blocks))
    handles = [eng.submit(p, max_new=b) for p, b in zip(prompts, budgets)]
    eng.run()
    stats = eng.stats()
    print(f"{stats['finished']} requests in {stats['steps']} decode steps "
          f"({stats['generated_tokens']} tokens, "
          f"{stats['preemptions']} preemptions)")
    for h in handles:
        print(f"  request {h.rid}: prompt {len(h.tokens):>2} tokens -> "
              f"{h.generated}")

    # parity: the fixed-batch Server on bucket-left-padded prompts generates
    # the same tokens — continuous batching changed the schedule, not the math
    for start in range(0, len(prompts), scfg.max_batch):
        group = prompts[start:start + scfg.max_batch]
        reqs = [Request(tokens=np.concatenate(
            [np.zeros((bucket - len(p),), np.int32), p])) for p in group]
        tokens, _ = server.generate(reqs)
        for j in range(len(group)):
            h = handles[start + j]
            expect = np.asarray(tokens[j])[: len(h.generated)]
            assert np.array_equal(np.asarray(h.generated), expect), (
                f"request {h.rid} diverged from the fixed-batch oracle"
            )
    print("parity: every request matches the fixed-batch oracle token-for-token")


if __name__ == "__main__":
    main()
