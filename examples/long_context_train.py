"""Long-context training on the fused ring-attention path: the trainer's
process set folded onto a ``(data, ring)`` cart, the sequence sharded over
the periodic ring, KV rotating through the flash kernel — ``N - 1``
collective-permutes per layer, never a KV all-gather.  First the ring path
is parity-checked against the dense reference at a small size, then a few
steps train at a sequence length whose dense KV would not fit one device's
smoke budget.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/long_context_train.py
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ParallelPlan
from repro.core import _compat, topology
from repro.kernels.flash_attention import ops as fa
from repro.kernels.ring_attention import ops as ring_ops
from repro.launch.mesh import make_host_communicator
from repro.runtime.trainer import Trainer, TrainerConfig


def parity_check(ring_size: int = 4) -> None:
    mesh = _compat.make_mesh((ring_size,), ("ring",))
    cart = topology.CartComm(mesh, ("ring",), dims=(ring_size,),
                             periods=(True,), managed=False, tag="lc-demo")
    spec = P(None, "ring", None, None)
    q, k, v = (jax.random.normal(key, (2, 128, 4, 16))
               for key in jax.random.split(jax.random.PRNGKey(0), 3))
    body = lambda ql, kl, vl: ring_ops.ring_attention(
        cart, ql, kl, vl, causal=True, impl="ref", block_q=16, block_k=16)
    with mesh:
        out = jax.jit(_compat.shard_map(
            body, mesh=mesh, in_specs=(spec,) * 3, out_specs=spec))(q, k, v)
    ref = fa.flash_attention(q, k, v, causal=True, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=5e-5, rtol=5e-5)
    print(f"parity: ring({ring_size}) == dense reference at S=128")


def train_long(seq_len: int = 1024, ring_size: int = 4) -> None:
    cfg = ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128, dtype="float32",
    )
    trainer = Trainer(  # re-forms the 8 devices as a (2, 4) (data, ring) cart
        cfg, ParallelConfig(),
        TrainerConfig(steps=3, lr=1e-3, log_every=1,
                      plan=ParallelPlan(ring=ring_size)),
        make_host_communicator(), seq_len=seq_len, global_batch=2,
    )
    result = trainer.run()
    loss = float(result["metrics"][-1]["loss"])
    assert jnp.isfinite(loss), loss
    print(f"trained {seq_len}-token sequences on a (2, {ring_size}) "
          f"(data, ring) cart: final loss {loss:.3f}")


if __name__ == "__main__":
    parity_check()
    train_long()
