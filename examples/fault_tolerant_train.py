"""Fault-tolerance demo: training survives an injected worker failure by
restoring the newest complete checkpoint and replaying (deterministic data —
no loader state needed).

    PYTHONPATH=src python examples/fault_tolerant_train.py
"""

import tempfile

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.faults import FaultInjector
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    cfg = ModelConfig(
        name="demo", family="dense", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=4_096,
        tie_embeddings=True,
    )
    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            cfg,
            ParallelConfig(remat="none"),
            TrainerConfig(steps=40, lr=1e-3, checkpoint_dir=ckpt_dir,
                          checkpoint_every=10, log_every=10),
            make_host_mesh(),
            seq_len=128,
            global_batch=4,
            injector=FaultInjector(fail_at_steps=(17, 28)),  # two failures
        )
        result = trainer.run()
        print(f"finished step {result['final_step']} after "
              f"{result['restarts']} recoveries (injected failures at 17, 28)")
        assert result["final_step"] == 40
        assert result["restarts"] == 2


if __name__ == "__main__":
    main()
