"""Batched serving: prefill + token-by-token decode with a persistent
sharded KV cache, on any of the assigned architectures (smoke scale).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_batched.py --arch gemma2_9b
"""

import argparse

import numpy as np

from repro.configs import base
from repro.launch.mesh import make_host_mesh
from repro.runtime.server import Request, Server, ServerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2_9b", choices=base.ARCHITECTURES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = base.get_smoke_config(args.arch)
    pcfg = base.get_parallel(args.arch)
    server = Server(
        cfg, pcfg,
        ServerConfig(max_batch=args.batch, max_new_tokens=args.new_tokens,
                     temperature=args.temperature),
        make_host_mesh(),
    )

    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(args.batch):
        extra = {}
        if cfg.family == "vlm":
            extra["image_embeds"] = rng.standard_normal(
                (cfg.num_image_tokens, 1152)).astype(np.float32)
        if cfg.family == "encdec":
            extra["frames"] = rng.standard_normal(
                (args.prompt_len, cfg.d_model)).astype(np.float32)
        reqs.append(Request(
            tokens=rng.integers(1, cfg.vocab_size, (args.prompt_len,), dtype=np.int32),
            extra=extra,
        ))

    tokens, stats = server.generate(reqs)
    print(f"arch={args.arch}  generated {tokens.shape} tokens")
    print(f"prefill {stats['prefill_s']*1e3:.0f} ms   "
          f"decode {stats['decode_s']*1e3:.0f} ms   "
          f"{stats['tokens_per_s']:.1f} tok/s")
    print("first sequence:", tokens[0][:16], "...")


if __name__ == "__main__":
    main()
