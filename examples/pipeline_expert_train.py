"""Chapter 8 in anger: the pipeline/expert-parallel fabric.

Two demonstrations of virtual process topologies as production traffic
shaping:

1. a Trainer in **pipeline-parallel mode** — the process set folded onto a
   ``(data, stage)`` Cartesian grid, microbatches streamed through
   ``cart_shift(+1)`` stage boundaries, the whole step still one persistent
   executable (``trace:train_step == 1``);
2. **expert dispatch over the router's expert map** — top-k MoE routing
   restricted to a ring neighborhood (device-limited routing) and the token
   exchange riding ``neighbor_alltoallv`` over a ``DistGraphComm``, sparse
   ``collective-permute`` traffic instead of a world-dense ``all_to_all``.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python examples/pipeline_expert_train.py
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import core as mpx
from repro.configs.base import ModelConfig, ParallelConfig, ParallelPlan
from repro.core import tool, topology
from repro.core.hloanalysis import analyze_hlo
from repro.models import mlp
from repro.runtime.trainer import Trainer, TrainerConfig


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128, dtype="float32",
    )
    base.update(kw)
    return ModelConfig(**base)


def pipeline_demo():
    comm = mpx.world()
    stages = 2 if comm.size() % 2 == 0 else 1
    if stages < 2:
        print("pipeline demo needs an even device count; skipping")
        return
    trainer = Trainer(
        tiny_cfg(), ParallelConfig(),
        TrainerConfig(steps=10, lr=1e-3, log_every=5,
                      plan=ParallelPlan(stage=stages, microbatches=2)),
        comm, seq_len=64, global_batch=8,
    )
    print(f"pipeline topology: {trainer.comm}")
    result = trainer.run()
    pvars = tool.pvar_read()
    print(f"trained to step {result['final_step']}: "
          f"loss {result['metrics'][-1]['loss']:.4f} — "
          f"traces {pvars.get('trace:train_step')}, "
          f"persistent starts {pvars.get('persistent_start')}")
    stats = analyze_hlo(trainer._compiled.as_text()).collectives
    print(f"step collectives: {dict(stats.count)} (stage boundaries are "
          f"collective-permutes; no dense world alltoall)")


def expert_demo():
    comm = mpx.world()
    n = comm.size()
    cfg = tiny_cfg(family="moe", num_experts=2 * n, moe_top_k=2, moe_d_ff=96)
    params = mlp.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    srcs, dsts = mlp.expert_dispatch_graph(n, cfg.num_experts, radius=1)
    graph = topology.dist_graph_create_adjacent(comm, srcs, dsts)
    print(f"expert graph: {graph} — rank 0 neighbors "
          f"{graph.dist_graph_neighbors(0)[2]}")

    def run(x, router, wg, wu, wd):
        y, aux = mlp.moe_neighbor(
            {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd},
            x, cfg, graph,
        )
        return y, aux["dropped_fraction"]

    tokens = jax.random.normal(jax.random.PRNGKey(1), (8 * n, cfg.d_model))
    y, dropped = graph.spmd(
        run,
        in_specs=(P("world"), P(), P("world"), P("world"), P("world")),
        out_specs=(P("world"), P()),
    )(tokens, params["router"], params["w_gate"], params["w_up"], params["w_down"])
    print(f"dispatched {tokens.shape[0]} tokens over the graph: "
          f"out {y.shape}, dropped fraction {float(dropped):.3f}, "
          f"neighbor_alltoallv issued: {tool.pvar_read()['neighbor_alltoallv']}")


def main():
    pipeline_demo()
    expert_demo()


if __name__ == "__main__":
    main()
