"""Checkpoint manager: atomic save/restore, retention, resume metadata, and
the parallel-IO file layer underneath it."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))},
        "opt": {"mu": jnp.ones((8, 8)), "step": jnp.asarray(3)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = _state()
    mgr.save(10, state, extra={"step": 10})
    mgr.wait()
    assert mgr.latest_step() == 10

    template = jax.tree.map(jnp.zeros_like, state)
    restored, step = mgr.restore(template)
    assert step == 10
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.extra(10)["step"] == 10


def test_retention_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    mgr.wait()
    assert mgr.steps() == [3, 4]


def test_restore_latest_complete_ignores_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    mgr.wait()
    # simulate a crash mid-write of step 3: directory without manifest
    broken = tmp_path / "step_00000003"
    broken.mkdir()
    (broken / "garbage.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 2
    _, step = mgr.restore(jax.tree.map(jnp.zeros_like, _state()))
    assert step == 2


def test_async_save_overlaps_and_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, _state(5))
    mgr.wait()   # must block until durable
    assert mgr.latest_step() == 5


def test_io_file_roundtrip(tmp_path):
    from repro.core import io as pio

    path = str(tmp_path / "file.mpio")
    f = pio.open(path, pio.Mode.CREATE | pio.Mode.WRONLY)
    f.write_at_all("x", np.arange(16).reshape(4, 4))
    f.write_at_all("y", np.ones((3,), np.float32))

    r = pio.open(path, pio.Mode.RDONLY)
    assert sorted(r.names()) == ["x", "y"]
    np.testing.assert_array_equal(r.read_at_all("x"), np.arange(16).reshape(4, 4))
    man = r.manifest()
    assert man["arrays"]["x"]["shape"] == [4, 4]
