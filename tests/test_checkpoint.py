"""Checkpoint manager: atomic save/restore, retention, resume metadata, and
the parallel-IO file layer underneath it."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))},
        "opt": {"mu": jnp.ones((8, 8)), "step": jnp.asarray(3)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = _state()
    mgr.save(10, state, extra={"step": 10})
    mgr.wait()
    assert mgr.latest_step() == 10

    template = jax.tree.map(jnp.zeros_like, state)
    restored, step = mgr.restore(template)
    assert step == 10
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.extra(10)["step"] == 10


def test_retention_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, _state(s))
    mgr.wait()
    assert mgr.steps() == [3, 4]


def test_restore_latest_complete_ignores_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=False)
    mgr.save(1, _state(1))
    mgr.save(2, _state(2))
    mgr.wait()
    # simulate a crash mid-write of step 3: directory without manifest
    broken = tmp_path / "step_00000003"
    broken.mkdir()
    (broken / "garbage.npy").write_bytes(b"xx")
    assert mgr.latest_step() == 2
    _, step = mgr.restore(jax.tree.map(jnp.zeros_like, _state()))
    assert step == 2


def test_async_save_overlaps_and_waits(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, _state(5))
    mgr.wait()   # must block until durable
    assert mgr.latest_step() == 5


def test_async_save_overlaps_foreground_work(tmp_path):
    """save() returns with the I/O still in flight (the gather is the only
    synchronous part); wait() is the durability point."""

    import threading

    gate = threading.Event()

    class SlowDisk:
        def check_io(self, frag):
            assert gate.wait(10)

    mgr = CheckpointManager(str(tmp_path), async_save=True, injector=SlowDisk())
    req = mgr.save(1, _state())
    assert not req.test()                  # still writing: save didn't block
    assert mgr.pending()
    gate.set()
    mgr.wait()
    assert not mgr.pending()
    assert mgr.latest_step() == 1


def test_failed_async_save_raises_from_wait(tmp_path):
    """A fragment-write fault in the background save surfaces as ERR_IO
    from wait() — it used to be reported as success — and `latest` never
    advances past the failed step."""

    from repro.core import errors
    from repro.runtime.faults import FaultInjector

    mgr = CheckpointManager(str(tmp_path), keep=3, async_save=True)
    mgr.save(1, _state(1))
    mgr.wait()
    assert mgr.latest_step() == 1

    mgr.injector = FaultInjector(fail_fragments=("params.w",))
    mgr.save(2, _state(2))
    with pytest.raises(errors.IoError):
        mgr.wait()
    assert mgr.latest_step() == 1          # the torn save is not "latest"
    assert (tmp_path / "latest").read_text() == "1"

    # the injector fires once: the retried save lands
    mgr.save(2, _state(2))
    mgr.wait()
    assert mgr.latest_step() == 2


def test_failed_save_raises_from_returned_request(tmp_path):
    from repro.core import errors
    from repro.runtime.faults import FaultInjector

    mgr = CheckpointManager(
        str(tmp_path), async_save=True,
        injector=FaultInjector(fail_fragments=("opt.mu",)),
    )
    req = mgr.save(3, _state())
    with pytest.raises(errors.IoError):
        req.get()
    assert mgr.wait() is None              # outcome was already delivered
    assert mgr.latest_step() is None


def test_restore_sees_inflight_save(tmp_path):
    """restore() joins the pending async save BEFORE resolving the step —
    an unjoined save used to be invisible to latest_step()."""

    import jax.numpy as jnp

    mgr = CheckpointManager(str(tmp_path), async_save=True)
    state = _state()
    mgr.save(1, state)                     # no explicit wait
    restored, step = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 1


def test_sync_save_returns_usable_request(tmp_path):
    """With async_save=False the returned request is already complete but
    still valid: get() resolves immediately instead of ERR_REQUEST."""

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    req = mgr.save(1, _state())
    assert req.test()
    assert req.get().endswith("step_00000001")


def test_wait_reraises_error_from_chained_unwaited_request(tmp_path):
    """A failed save whose returned request was then()-chained but never
    waited is NOT silently dropped: wait() still surfaces the error."""

    from repro.core import errors
    from repro.runtime.faults import FaultInjector

    mgr = CheckpointManager(
        str(tmp_path), async_save=True,
        injector=FaultInjector(fail_fragments=("params.w",)),
    )
    req = mgr.save(1, _state())
    req.then(lambda r: "chain never waited")   # consumes without delivering
    with pytest.raises(errors.IoError):
        mgr.wait()
    assert mgr.latest_step() is None


def test_leaf_name_collision_fails_fast(tmp_path):
    """'/'→'.' sanitisation can collide leaf fragment names; that must be a
    typed save-time failure, not last-writer-wins corruption at restore."""

    import jax.numpy as jnp

    from repro.core import errors

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    with pytest.raises(errors.IoError, match="collides"):
        mgr.save(1, {"a.b": jnp.ones(4), "a": {"b": jnp.zeros(4)}})


def test_single_manifest_commit_per_save(tmp_path):
    """One manifest sync point per step, however many arrays the tree has
    (the per-array rewrite was O(n²) over a checkpoint)."""

    from repro.core import tool

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    before = tool.pvar_read().get("io_manifest_commit", 0)
    mgr.save(1, _state())                  # 4 leaves
    assert tool.pvar_read().get("io_manifest_commit", 0) == before + 1


def test_mid_save_crash_leaves_no_manifest(tmp_path):
    """Atomicity under a mid-save crash: a save that dies writing fragments
    commits nothing — no manifest, no _COMPLETE — so restore skips it."""

    import jax.numpy as jnp

    from repro.core import errors
    from repro.runtime.faults import FaultInjector

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _state(1))

    mgr.injector = FaultInjector(fail_fragments=("opt.step",))
    with pytest.raises(errors.IoError):
        mgr.save(2, _state(2))             # sync save joins inline
    step2 = tmp_path / "step_00000002"
    assert not (step2 / "manifest.json").exists()
    assert not (step2 / "_COMPLETE").exists()
    _, step = mgr.restore(jax.tree.map(jnp.zeros_like, _state()))
    assert step == 1


def test_async_save_request_is_chainable(tmp_path):
    """save() returns the completion request: test()/then() work like any
    request in the engine."""

    mgr = CheckpointManager(str(tmp_path), async_save=True)
    req = mgr.save(7, _state())
    done = req.then(lambda r: ("committed", r.get()))
    tag, step_dir = done.get()
    assert tag == "committed" and step_dir.endswith("step_00000007")
    assert mgr.latest_step() == 7


def test_bf16_state_roundtrip(tmp_path):
    """bf16 leaves bucket separately, store as the uint16 alias, and restore
    through the recorded etype view; parity asserted in float32."""

    import jax.numpy as jnp

    state = {
        "w32": jnp.linspace(0, 1, 16, dtype=jnp.float32).reshape(4, 4),
        "w16": jnp.linspace(0, 1, 16, dtype=jnp.bfloat16).reshape(4, 4),
    }
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, state)
    restored, _ = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert restored["w16"].dtype == jnp.bfloat16
    for k in state:
        np.testing.assert_array_equal(
            np.asarray(restored[k], np.float32), np.asarray(state[k], np.float32)
        )


def test_io_file_roundtrip(tmp_path):
    from repro.core import io as pio

    path = str(tmp_path / "file.mpio")
    f = pio.open(path, pio.Mode.CREATE | pio.Mode.WRONLY)
    f.write_at_all("x", np.arange(16).reshape(4, 4))
    f.write_at_all("y", np.ones((3,), np.float32))

    r = pio.open(path, pio.Mode.RDONLY)
    assert sorted(r.names()) == ["x", "y"]
    np.testing.assert_array_equal(r.read_at_all("x"), np.arange(16).reshape(4, 4))
    man = r.manifest()
    assert man["arrays"]["x"]["shape"] == [4, 4]
