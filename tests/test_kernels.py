"""Per-kernel allclose validation against the pure-jnp oracles, swept over
shapes and dtypes (Pallas interpret mode on CPU; TPU is the target)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa
from repro.kernels.quant import ops as qo
from repro.kernels.ssd_scan import ops as so


def _qkv(key, B, S, H, Hk, D, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, D), dtype)
    k = jax.random.normal(k2, (B, S, Hk, D), dtype)
    v = jax.random.normal(k3, (B, S, Hk, D), dtype)
    return q, k, v


@pytest.mark.parametrize("B,S,H,Hk,D", [
    (1, 128, 4, 4, 32),      # MHA
    (2, 256, 4, 2, 32),      # GQA
    (1, 128, 4, 1, 64),      # MQA
    (1, 512, 2, 2, 16),      # long-ish, small heads
])
def test_flash_attention_shapes(B, S, H, Hk, D):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, Hk, D, jnp.float32)
    out = fa.flash_attention(q, k, v, causal=True, impl="pallas")
    ref = fa.flash_attention(q, k, v, causal=True, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 128, 4, 2, 32, dtype)
    out = fa.flash_attention(q, k, v, causal=True, impl="pallas")
    ref = fa.flash_attention(q, k, v, causal=True, impl="ref")
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("feature", ["window", "softcap", "prefix", "noncausal"])
def test_flash_attention_features(feature):
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 256, 4, 2, 32, jnp.float32)
    kw = dict(causal=True)
    if feature == "window":
        kw["sliding_window"] = 64
    elif feature == "softcap":
        kw["logit_softcap"] = 50.0
    elif feature == "prefix":
        kw["prefix_len"] = 32     # paligemma prefix-LM mask
    elif feature == "noncausal":
        kw["causal"] = False
    out = fa.flash_attention(q, k, v, impl="pallas", **kw)
    ref = fa.flash_attention(q, k, v, impl="ref", **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("sq,sk,causal", [
    (100, 100, True),       # below one block: block shrinks, no padding
    (600, 600, True),       # above the default block: padded ragged tail
    (600, 600, False),
    (37, 81, False),        # cross lengths, both ragged
    (130, 50, False),
])
def test_flash_attention_ragged_lengths(sq, sk, causal):
    """Sequence lengths that do not divide the block size: the padded tail
    must be masked out of the online softmax, not averaged in."""

    from repro.kernels.flash_attention import kernel as fk

    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (1, sq, 4, 16))
    k = jax.random.normal(ks[1], (1, sk, 2, 16))
    v = jax.random.normal(ks[2], (1, sk, 2, 16))
    out = fk.flash_attention_fwd(q, k, v, causal=causal, block_q=128, block_k=128)
    ref = fa.flash_attention(q, k, v, causal=causal, impl="ref")
    assert out.shape == ref.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("feature", ["window", "prefix", "softcap"])
def test_flash_attention_ragged_features(feature):
    """Ragged tails compose with the masking features: the kv_len mask is
    applied last, so window/prefix logic cannot re-admit padded columns."""

    from repro.kernels.flash_attention import kernel as fk

    S = 330
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, S, 4, 16))
    k = jax.random.normal(ks[1], (1, S, 2, 16))
    v = jax.random.normal(ks[2], (1, S, 2, 16))
    kw = {"window": dict(sliding_window=100),
          "prefix": dict(prefix_len=40),
          "softcap": dict(logit_softcap=30.0)}[feature]
    out = fk.flash_attention_fwd(q, k, v, causal=True, block_q=128, block_k=128, **kw)
    ref = fa.flash_attention(q, k, v, causal=True, impl="ref", **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5)


def test_flash_attention_grads_match_reference():
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 128, 2, 2, 16, jnp.float32)

    def loss(impl):
        return lambda q, k, v: fa.flash_attention(q, k, v, causal=True, impl=impl).sum()

    g_pal = jax.grad(loss("pallas"), argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(loss("ref"), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_pal, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# SSD (mamba-2) chunked scan
# ---------------------------------------------------------------------------


def _ssd_inputs(key, B, S, H, P, N, groups=1):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, groups, N))
    C = jax.random.normal(ks[4], (B, S, groups, N))
    return x, dt, A, Bm, C


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 256, 4, 32, 16, 128),
    (2, 128, 2, 16, 32, 64),
    (1, 384, 8, 64, 16, 128),   # S not a multiple of 256
])
def test_ssd_scan_shapes(B, S, H, P, N, chunk):
    x, dt, A, Bm, C = _ssd_inputs(jax.random.PRNGKey(0), B, S, H, P, N)
    out = so.ssd_scan(x, dt, A, Bm, C, chunk=chunk, impl="pallas")
    ref = so.ssd_scan(x, dt, A, Bm, C, chunk=chunk, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=5e-5, rtol=5e-5)


def test_ssd_scan_matches_sequential_recurrence():
    """The chunked SSD form must equal the naive per-step SSM recurrence."""

    B, S, H, P, N = 1, 64, 2, 8, 4
    x, dt, A, Bm, C = _ssd_inputs(jax.random.PRNGKey(1), B, S, H, P, N)
    out = so.ssd_scan(x, dt, A, Bm, C, chunk=16, impl="ref")

    state = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(S):
        y, state = so.ssd_decode_step(
            state, x[:, t], dt[:, t], A, Bm[:, t], C[:, t]
        )
        outs.append(y)
    seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(seq), atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# int8 block quantization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [256, 1000, 4096])
@pytest.mark.parametrize("impl", ["ref", "pallas"])
def test_quant_roundtrip(n, impl):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 3.0
    q, scale, pad = qo.quantize_int8(x, impl=impl)
    assert q.dtype == jnp.int8
    y = qo.dequantize_int8(q, scale, pad, (n,), jnp.float32, impl=impl)
    # per-block absmax int8: error bounded by scale/2 per element
    err = np.abs(np.asarray(y) - np.asarray(x))
    bound = np.abs(np.asarray(x)).max() / 127.0
    assert err.max() <= bound + 1e-6


def test_quant_pallas_matches_ref_exactly():
    x = jax.random.normal(jax.random.PRNGKey(1), (2048,))
    q1, s1, p1 = qo.quantize_int8(x, impl="ref")
    q2, s2, p2 = qo.quantize_int8(x, impl="pallas")
    assert p1 == p2
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
