"""Trainer integration: loss decreases on the synthetic Markov stream,
checkpoint/restart resumes, injected worker failures recover, stragglers are
re-dispatched.

Straggler behaviour is asserted through the injectable ``StepGuard.clock``
(a :class:`FakeClock` advanced by the step functions themselves), never
through wall-clock sleeps — tier-1 must pass on a loaded CI machine without
timing margins.  Integration trainers run with a frozen clock, so background
load and checkpoint I/O can never masquerade as worker sickness.
"""

from __future__ import annotations

import jax
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.faults import FaultInjector, StepGuard, StragglerPolicy, WorkerFailure
from repro.runtime.trainer import Trainer, TrainerConfig


class FakeClock:
    """Deterministic time source: step functions advance it explicitly."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    )


def _trainer(tmp_path=None, steps=30, injector=None, straggler=None):
    tcfg = TrainerConfig(
        steps=steps,
        lr=1e-3,
        checkpoint_dir=str(tmp_path) if tmp_path else None,
        checkpoint_every=10,
        log_every=5,
    )
    return Trainer(
        _tiny_cfg(), ParallelConfig(), tcfg, make_host_mesh(),
        seq_len=64, global_batch=4, injector=injector, straggler=straggler,
        # frozen clock: every step measures 0s, so the straggler policy is
        # inert for integration tests that are not about stragglers
        clock=lambda: 0.0,
    )


def test_loss_decreases():
    t = _trainer(steps=40)
    result = t.run()
    losses = [m["loss"] for m in result["metrics"]]
    assert losses[-1] < losses[0] - 0.1, losses


def test_checkpoint_restart_resumes(tmp_path):
    t1 = _trainer(tmp_path, steps=20)
    r1 = t1.run()
    assert r1["final_step"] == 20

    # a fresh trainer resumes from the saved step instead of restarting
    t2 = _trainer(tmp_path, steps=25)
    r2 = t2.run()
    assert r2["final_step"] == 25
    first_logged = r2["metrics"][0]["step"] if r2["metrics"] else 25
    assert first_logged > 20


def test_worker_failure_recovers(tmp_path):
    injector = FaultInjector(fail_at_steps=(7,))
    t = _trainer(tmp_path, steps=15, injector=injector)
    result = t.run()
    assert result["restarts"] == 1
    assert result["final_step"] == 15


def test_unrecoverable_after_max_restarts(tmp_path):
    injector = FaultInjector(fail_at_steps=(3, 4, 5, 6, 7, 8, 9))
    t = _trainer(tmp_path, steps=15, injector=injector)
    t.tcfg.max_restarts = 2
    with pytest.raises(WorkerFailure):
        t.run()


def test_straggler_redispatch():
    """The straggling step re-dispatches once — asserted on a fake clock
    the step function itself advances (no sleeps, no timing margins)."""

    clock = FakeClock()
    calls = {"n": 0}

    def slow_then_fast():
        calls["n"] += 1
        clock.advance(0.25 if calls["n"] == 1 else 0.02)
        return calls["n"]

    guard = StepGuard(
        StragglerPolicy(deadline_factor=5.0, min_samples=3, max_retries=1),
        clock=clock,
    )
    # seed the moving median with exact 20ms steps
    for s in range(5):
        guard.run(s, lambda: clock.advance(0.02))
    out, info = guard.run(10, slow_then_fast)
    assert info["attempts"] == 2      # the straggling step was re-dispatched
    assert out == 2
    assert info["duration_s"] == pytest.approx(0.02)


def test_straggler_exemption_under_checkpoint_io():
    """A step flagged exempt (in-flight checkpoint save) is never marked a
    straggler and its polluted duration stays out of the running median —
    deterministic on the fake clock."""

    clock = FakeClock()
    policy = StragglerPolicy(deadline_factor=2.0, min_samples=3)
    for d in (0.01, 0.01, 0.01, 0.01):
        policy.observe(d)
    guard = StepGuard(policy, clock=clock)
    median_before = policy.median()
    out, info = guard.run(10, lambda: clock.advance(0.1), exempt=True)
    assert info["straggled"] is False and info["attempts"] == 1
    assert info["duration_s"] == pytest.approx(0.1)
    assert policy.median() == median_before
    # the same slow step without the exemption is a straggler
    with pytest.raises(WorkerFailure):
        guard.run(11, lambda: clock.advance(0.1), retry_safe=False)


def test_straggler_window_is_honored():
    """StragglerPolicy.window sizes the history deque (it was dead config:
    the deque hardcoded maxlen=32 regardless of the field)."""

    p = StragglerPolicy(window=4, min_samples=2)
    for i in range(10):
        p.observe(float(i))
    assert p._history.maxlen == 4
    assert list(p._history) == [6.0, 7.0, 8.0, 9.0]
    assert p.median() == 8.0      # median of the WINDOW, not of all history

    # default stays at 32
    assert StragglerPolicy()._history.maxlen == 32


def test_async_checkpoint_overlaps_persistent_steps(tmp_path):
    """Checkpoint writes ride the I/O request engine: the hot loop never
    re-traces (trace:train_step delta stays 1) while saves complete in the
    background, and the run ends with every save durable."""

    from repro.core import tool

    before = tool.pvar_read().get("trace:train_step", 0)
    t = _trainer(tmp_path, steps=12)        # checkpoint_every=10, + final save
    result = t.run()
    assert result["final_step"] == 12
    assert result["ckpt_failures"] == 0
    assert tool.pvar_read().get("trace:train_step", 0) == before + 1
    assert t.ckpt.latest_step() == 12       # final save joined and durable
    assert not t.ckpt.pending()


def test_trainer_tolerates_failed_checkpoint_save(tmp_path):
    """A torn async save surfaces (counted + logged), never as success; the
    run continues from device state and `latest` stays complete."""

    injector = FaultInjector(fail_fragments=("params",))
    t = _trainer(tmp_path, steps=12, injector=injector)
    result = t.run()
    assert result["final_step"] == 12
    assert result["restarts"] == 0
    assert result["ckpt_failures"] == 1     # the step-10 save was torn
    assert t.ckpt.latest_step() == 12       # the final save succeeded


def test_pipeline_trainer_parity_and_single_trace(subproc):
    """Pipeline-parallel mode (ch. 8 fabric): the (data, stage) cart step
    reproduces the GSPMD loss exactly (float32 — bf16 rounds differently
    across partitionings), trains through the persistent engine with ONE
    trace, and its stage boundaries lower to collective-permutes only."""

    code = """
import numpy as np
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import tool
from repro.launch.mesh import make_host_communicator
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, dtype="float32")
pcfg = ParallelConfig()
t = Trainer(cfg, pcfg,
            TrainerConfig(steps=3, pipeline_stages=2, pipeline_microbatches=2,
                          log_every=1),
            make_host_communicator(), seq_len=64, global_batch=8,
            clock=lambda: 0.0)
assert t.comm.dims == (2, 2) and t.comm.axis_names == ("data", "stage")

params, opt_state = t.init_state()
from repro.models import api as model_api
bundle = model_api.build(cfg)
batch = t.pipeline.device_batch(0, t.mesh, pcfg)
ref_loss, _ = jax.jit(lambda p, b: bundle.loss(p, b, pcfg, None))(params, batch)

before = tool.pvar_read().get("trace:train_step", 0)
res = t.run()
assert res["final_step"] == 3
assert tool.pvar_read().get("trace:train_step", 0) - before == 1, "re-traced!"
delta = abs(res["metrics"][0]["loss"] - float(ref_loss))
assert delta < 2e-3, (res["metrics"][0]["loss"], float(ref_loss))

# stage-boundary traffic is permutes; no dense world alltoall appears
from repro.analysis import hlo as hlo_passes
stats = hlo_passes.collective_stats(t._compiled)
assert stats.count.get("collective-permute", 0) > 0, stats.count
assert hlo_passes.no_collective(t._compiled, "all-to-all").ok, stats.count
print("PIPELINE_TRAINER_OK", delta)
"""
    assert "PIPELINE_TRAINER_OK" in subproc(code, n=4)


def test_pipeline_ring_mutual_exclusion_message():
    """Both modes re-form the fabric through ``_reform_topology``; asking
    for both is ``ERR_TOPOLOGY`` with a stable, actionable message."""

    from repro.core import errors

    with pytest.raises(errors.TopologyError) as ei:
        Trainer(
            _tiny_cfg(), ParallelConfig(),
            TrainerConfig(pipeline_stages=2, ring_attention=2),
            make_host_mesh(),
        )
    assert (
        "plan axes stage (pipeline_stages) and ring (ring_attention) both "
        "re-form the communicator; pick one per plan"
    ) in str(ei.value)


def test_trainer_state_derives_from_epoch():
    """The trainer caches no fabric: comm and mesh read through the current
    :class:`~repro.core.epoch.CommEpoch`, and generation 0 adopts the
    incoming communicator (mesh identity preserved)."""

    t = _trainer(steps=1)
    assert t.epoch.generation == 0
    assert t.comm is t.epoch.comm
    assert t.mesh is t.comm.mesh


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint written under one mesh restores under a different
    data-parallel size (elastic rescale)."""

    t1 = _trainer(tmp_path, steps=10)
    t1.run()

    cfg = _tiny_cfg()
    tcfg = TrainerConfig(steps=12, checkpoint_dir=str(tmp_path), checkpoint_every=50)
    # "rescaled" mesh: same devices, different logical split (1 device here,
    # but the restore path re-shards through device_put either way)
    t2 = Trainer(cfg, ParallelConfig(), tcfg, make_host_mesh(model=1),
                 seq_len=64, global_batch=4)
    r2 = t2.run()
    assert r2["final_step"] == 12
