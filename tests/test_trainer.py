"""Trainer integration: loss decreases on the synthetic Markov stream,
checkpoint/restart resumes, injected worker failures recover, stragglers are
re-dispatched."""

from __future__ import annotations

import jax
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.faults import FaultInjector, StepGuard, StragglerPolicy, WorkerFailure
from repro.runtime.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    )


def _trainer(tmp_path=None, steps=30, injector=None, straggler=None):
    tcfg = TrainerConfig(
        steps=steps,
        lr=1e-3,
        checkpoint_dir=str(tmp_path) if tmp_path else None,
        checkpoint_every=10,
        log_every=5,
    )
    return Trainer(
        _tiny_cfg(), ParallelConfig(), tcfg, make_host_mesh(),
        seq_len=64, global_batch=4, injector=injector, straggler=straggler,
    )


def test_loss_decreases():
    t = _trainer(steps=40)
    result = t.run()
    losses = [m["loss"] for m in result["metrics"]]
    assert losses[-1] < losses[0] - 0.1, losses


def test_checkpoint_restart_resumes(tmp_path):
    t1 = _trainer(tmp_path, steps=20)
    r1 = t1.run()
    assert r1["final_step"] == 20

    # a fresh trainer resumes from the saved step instead of restarting
    t2 = _trainer(tmp_path, steps=25)
    r2 = t2.run()
    assert r2["final_step"] == 25
    first_logged = r2["metrics"][0]["step"] if r2["metrics"] else 25
    assert first_logged > 20


def test_worker_failure_recovers(tmp_path):
    injector = FaultInjector(fail_at_steps=(7,))
    t = _trainer(tmp_path, steps=15, injector=injector)
    result = t.run()
    assert result["restarts"] == 1
    assert result["final_step"] == 15


def test_unrecoverable_after_max_restarts(tmp_path):
    injector = FaultInjector(fail_at_steps=(3, 4, 5, 6, 7, 8, 9))
    t = _trainer(tmp_path, steps=15, injector=injector)
    t.tcfg.max_restarts = 2
    with pytest.raises(WorkerFailure):
        t.run()


def test_straggler_redispatch():
    import time

    calls = {"n": 0}

    def slow_then_fast():
        calls["n"] += 1
        time.sleep(0.25 if calls["n"] == 1 else 0.02)
        return calls["n"]

    guard = StepGuard(StragglerPolicy(deadline_factor=5.0, min_samples=3, max_retries=1))
    # seed the moving median with ~20ms steps
    for s in range(5):
        guard.run(s, lambda: time.sleep(0.02))
    out, info = guard.run(10, slow_then_fast)
    assert info["attempts"] == 2      # the straggling step was re-dispatched
    assert out == 2


def test_straggler_exemption_under_checkpoint_io():
    """A step flagged exempt (in-flight checkpoint save) is never marked a
    straggler and its polluted duration stays out of the running median."""

    import time

    # seed the median with fixed durations (no timing-sensitive sleeps: a
    # loaded machine can only make the probe step SLOWER, never faster)
    policy = StragglerPolicy(deadline_factor=2.0, min_samples=3)
    for d in (0.01, 0.01, 0.01, 0.01):
        policy.observe(d)
    guard = StepGuard(policy)
    median_before = policy.median()
    out, info = guard.run(10, lambda: time.sleep(0.1), exempt=True)
    assert info["straggled"] is False and info["attempts"] == 1
    assert policy.median() == median_before
    # the same slow step without the exemption is a straggler
    with pytest.raises(WorkerFailure):
        guard.run(11, lambda: time.sleep(0.1), retry_safe=False)


def test_straggler_window_is_honored():
    """StragglerPolicy.window sizes the history deque (it was dead config:
    the deque hardcoded maxlen=32 regardless of the field)."""

    p = StragglerPolicy(window=4, min_samples=2)
    for i in range(10):
        p.observe(float(i))
    assert p._history.maxlen == 4
    assert list(p._history) == [6.0, 7.0, 8.0, 9.0]
    assert p.median() == 8.0      # median of the WINDOW, not of all history

    # default stays at 32
    assert StragglerPolicy()._history.maxlen == 32


def test_async_checkpoint_overlaps_persistent_steps(tmp_path):
    """Checkpoint writes ride the I/O request engine: the hot loop never
    re-traces (trace:train_step delta stays 1) while saves complete in the
    background, and the run ends with every save durable."""

    from repro.core import tool

    before = tool.pvar_read().get("trace:train_step", 0)
    # lenient straggler deadline: background checkpoint I/O must not trip
    # the wall-clock policy on a loaded test machine
    t = _trainer(tmp_path, steps=12,        # checkpoint_every=10, + final save
                 straggler=StragglerPolicy(deadline_factor=100.0))
    result = t.run()
    assert result["final_step"] == 12
    assert result["ckpt_failures"] == 0
    assert tool.pvar_read().get("trace:train_step", 0) == before + 1
    assert t.ckpt.latest_step() == 12       # final save joined and durable
    assert not t.ckpt.pending()


def test_trainer_tolerates_failed_checkpoint_save(tmp_path):
    """A torn async save surfaces (counted + logged), never as success; the
    run continues from device state and `latest` stays complete."""

    injector = FaultInjector(fail_fragments=("params",))
    t = _trainer(tmp_path, steps=12, injector=injector,
                 straggler=StragglerPolicy(deadline_factor=100.0))
    result = t.run()
    assert result["final_step"] == 12
    assert result["restarts"] == 0
    assert result["ckpt_failures"] == 1     # the step-10 save was torn
    assert t.ckpt.latest_step() == 12       # the final save succeeded


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint written under one mesh restores under a different
    data-parallel size (elastic rescale)."""

    t1 = _trainer(tmp_path, steps=10)
    t1.run()

    cfg = _tiny_cfg()
    tcfg = TrainerConfig(steps=12, checkpoint_dir=str(tmp_path), checkpoint_every=50)
    # "rescaled" mesh: same devices, different logical split (1 device here,
    # but the restore path re-shards through device_put either way)
    t2 = Trainer(cfg, ParallelConfig(), tcfg, make_host_mesh(model=1),
                 seq_len=64, global_batch=4)
    r2 = t2.run()
    assert r2["final_step"] == 12
