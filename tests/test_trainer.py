"""Trainer integration: loss decreases on the synthetic Markov stream,
checkpoint/restart resumes, injected worker failures recover, stragglers are
re-dispatched."""

from __future__ import annotations

import jax
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.launch.mesh import make_host_mesh
from repro.runtime.faults import FaultInjector, StepGuard, StragglerPolicy, WorkerFailure
from repro.runtime.trainer import Trainer, TrainerConfig


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    )


def _trainer(tmp_path=None, steps=30, injector=None, straggler=None):
    tcfg = TrainerConfig(
        steps=steps,
        lr=1e-3,
        checkpoint_dir=str(tmp_path) if tmp_path else None,
        checkpoint_every=10,
        log_every=5,
    )
    return Trainer(
        _tiny_cfg(), ParallelConfig(), tcfg, make_host_mesh(),
        seq_len=64, global_batch=4, injector=injector, straggler=straggler,
    )


def test_loss_decreases():
    t = _trainer(steps=40)
    result = t.run()
    losses = [m["loss"] for m in result["metrics"]]
    assert losses[-1] < losses[0] - 0.1, losses


def test_checkpoint_restart_resumes(tmp_path):
    t1 = _trainer(tmp_path, steps=20)
    r1 = t1.run()
    assert r1["final_step"] == 20

    # a fresh trainer resumes from the saved step instead of restarting
    t2 = _trainer(tmp_path, steps=25)
    r2 = t2.run()
    assert r2["final_step"] == 25
    first_logged = r2["metrics"][0]["step"] if r2["metrics"] else 25
    assert first_logged > 20


def test_worker_failure_recovers(tmp_path):
    injector = FaultInjector(fail_at_steps=(7,))
    t = _trainer(tmp_path, steps=15, injector=injector)
    result = t.run()
    assert result["restarts"] == 1
    assert result["final_step"] == 15


def test_unrecoverable_after_max_restarts(tmp_path):
    injector = FaultInjector(fail_at_steps=(3, 4, 5, 6, 7, 8, 9))
    t = _trainer(tmp_path, steps=15, injector=injector)
    t.tcfg.max_restarts = 2
    with pytest.raises(WorkerFailure):
        t.run()


def test_straggler_redispatch():
    import time

    calls = {"n": 0}

    def slow_then_fast():
        calls["n"] += 1
        time.sleep(0.25 if calls["n"] == 1 else 0.02)
        return calls["n"]

    guard = StepGuard(StragglerPolicy(deadline_factor=5.0, min_samples=3, max_retries=1))
    # seed the moving median with ~20ms steps
    for s in range(5):
        guard.run(s, lambda: time.sleep(0.02))
    out, info = guard.run(10, slow_then_fast)
    assert info["attempts"] == 2      # the straggling step was re-dispatched
    assert out == 2


def test_straggler_window_is_honored():
    """StragglerPolicy.window sizes the history deque (it was dead config:
    the deque hardcoded maxlen=32 regardless of the field)."""

    p = StragglerPolicy(window=4, min_samples=2)
    for i in range(10):
        p.observe(float(i))
    assert p._history.maxlen == 4
    assert list(p._history) == [6.0, 7.0, 8.0, 9.0]
    assert p.median() == 8.0      # median of the WINDOW, not of all history

    # default stays at 32
    assert StragglerPolicy()._history.maxlen == 32


def test_elastic_remesh_restore(tmp_path):
    """Checkpoint written under one mesh restores under a different
    data-parallel size (elastic rescale)."""

    t1 = _trainer(tmp_path, steps=10)
    t1.run()

    cfg = _tiny_cfg()
    tcfg = TrainerConfig(steps=12, checkpoint_dir=str(tmp_path), checkpoint_every=50)
    # "rescaled" mesh: same devices, different logical split (1 device here,
    # but the restore path re-shards through device_put either way)
    t2 = Trainer(cfg, ParallelConfig(), tcfg, make_host_mesh(model=1),
                 seq_len=64, global_batch=4)
    r2 = t2.run()
    assert r2["final_step"] == 12
