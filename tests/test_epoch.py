"""Communicator epochs (ULFM-style shrink/grow): TopologySpec resolution,
epoch generation algebra, revocation, the per-epoch derived-state cache, and
cart re-folding onto arbitrary survivor groups.

Epoch algebra is device-agnostic (Groups over any hashables); only the
``.comm`` fabric needs jax devices, and those tests run on the single
default device."""

from __future__ import annotations

import pytest

from repro.core import errors, tool, topology
from repro.core.communicator import Communicator, world
from repro.core.epoch import ELASTIC, CommEpoch, TopologySpec
from repro.core.session import Group, default_session

# ---------------------------------------------------------------------------
# TopologySpec
# ---------------------------------------------------------------------------


def test_spec_resolves_elastic_dim():
    spec = TopologySpec((ELASTIC, 2), ("data", "stage"), (False, False))
    assert spec.fixed_size == 2
    assert spec.resolve(8) == (4, 2)
    assert spec.resolve(7) == (3, 2)  # floor: one survivor idles
    assert spec.resolve(2) == (1, 2)
    with pytest.raises(errors.DimsError):
        spec.resolve(1)  # not even one fold fits


def test_spec_fixed_shape_passthrough():
    spec = TopologySpec((4, 2), ("data", "model"))
    assert spec.resolve(8) == (4, 2)
    assert spec.resolve(100) == (4, 2)
    assert not spec.is_cart
    assert TopologySpec((ELASTIC,), ("data",), (True,)).is_cart


def test_spec_validation():
    with pytest.raises(errors.DimsError):
        TopologySpec((ELASTIC, ELASTIC), ("a", "b"))  # two elastic dims
    with pytest.raises(errors.DimsError):
        TopologySpec((2, 2), ("only_one",))
    with pytest.raises(errors.DimsError):
        TopologySpec((2,), ("a",), (False, False))  # periods arity
    with pytest.raises(errors.DimsError):
        TopologySpec((0,), ("a",))


def test_spec_from_communicator_marks_data_elastic():
    comm = world(refresh=True)
    spec = TopologySpec.from_communicator(comm)
    assert spec.shape == (ELASTIC,)
    assert spec.axis_names == ("world",)
    assert spec.periods is None


# ---------------------------------------------------------------------------
# epoch generation algebra (device-agnostic)
# ---------------------------------------------------------------------------


def _toy(n=8, shape=(ELASTIC, 2), periods=(False, False)):
    spec = TopologySpec(shape, ("data", "stage"), periods)
    return CommEpoch(Group("abcdefgh"[:n]), spec, name="toy")


def test_epoch_folds_leading_members():
    ep = _toy()
    assert ep.generation == 0
    assert ep.dims == (4, 2)
    assert ep.active.devices == tuple("abcdefgh")
    assert ep.axis_size("stage") == 2


def test_shrink_advances_generation_and_refolds():
    ep = _toy()
    ep1 = ep.shrink([3])  # rank 3 of the active group == device 'd'
    assert ep.revoked and not ep1.revoked
    assert ep1.generation == 1
    assert ep1.pool.devices == tuple("abcefgh")
    assert ep1.dims == (3, 2)  # 7 survivors -> 6 fold, 1 idles
    assert ep1.active.devices == tuple("abcefg")
    # devices and Groups are accepted too
    ep2 = ep1.shrink(Group("a"))
    assert ep2.dims == (3, 2) and ep2.pool.size() == 6


def test_grow_rejoins_and_expands():
    ep = _toy().shrink(["d"])
    ep2 = ep.grow(["d"])
    assert ep2.generation == 2
    assert ep2.dims == (4, 2)
    # survivors keep their ranks; the joiner appends
    assert ep2.pool.devices == tuple("abcefgh") + ("d",)


def test_revoked_epoch_rejects_fabric_access():
    ep = _toy()
    ep.revoke()
    ep.revoke()  # idempotent
    with pytest.raises(errors.RevokedError):
        _ = ep.comm
    with pytest.raises(errors.RevokedError):
        ep.cached("x", lambda e: 1)
    with pytest.raises(errors.RevokedError):
        ep._live()


def test_no_survivors_is_proc_failed():
    spec = TopologySpec((ELASTIC,), ("data",))
    ep = CommEpoch(Group("ab"), spec, name="toy")
    with pytest.raises(errors.ProcFailedError):
        ep.shrink(["a", "b"])


def test_cached_builds_lazily_once_per_epoch():
    ep = _toy()
    builds = []
    build = lambda e: builds.append(e.generation) or len(builds)  # noqa: E731
    assert ep.peek("step") is None
    assert ep.cached("step", build) == 1
    assert ep.cached("step", build) == 1  # cached, no rebuild
    assert builds == [0]
    ep1 = ep.shrink([0])
    assert ep1.peek("step") is None  # successor starts empty
    assert ep1.cached("step", build) == 2
    assert builds == [0, 1]
    ep1.invalidate("step")
    assert ep1.cached("step", build) == 3


# ---------------------------------------------------------------------------
# the fabric (single-device: world-sized epochs)
# ---------------------------------------------------------------------------


def test_epoch_adopts_matching_communicator():
    comm = world(refresh=True)
    ep = CommEpoch.create(comm, name="adopt")
    assert ep.comm is comm  # mesh identity preserved at generation 0
    assert ep.dims == (comm.size(),)


def test_epoch_builds_fabric_and_registers_pset():
    sess = default_session()
    g = sess.group("repro://world")
    spec = TopologySpec((ELASTIC,), ("data",))
    before = tool.pvar_read().get("epoch:rebuild", 0)
    ep = CommEpoch.create(g, spec, name="fabric")
    comm = ep.comm
    assert comm.size() == g.size()
    assert ep.pset_name == "repro://epoch/fabric/0"
    assert sess.group(ep.pset_name).compare(ep.active).name != "UNEQUAL"
    assert tool.pvar_read()["epoch:rebuild"] == before + 1
    assert ep.comm is comm  # built once


def test_epoch_cart_fabric():
    g = default_session().group("repro://world")
    spec = TopologySpec((ELASTIC,), ("ring",), (True,))
    ep = CommEpoch.create(g, spec, name="ring")
    cart = ep.comm
    assert isinstance(cart, topology.CartComm)
    assert cart.periods == (True,)
    assert cart.dims == ep.dims


def test_create_from_group_requires_spec():
    g = default_session().group("repro://world")
    with pytest.raises(errors.ArgError):
        CommEpoch.create(g)


def test_cart_refold_keeps_fixed_dims():
    g = default_session().group("repro://world")
    cart = topology.cart_create(g, (g.size(),), (True,), tag="repro://cart/refold0")
    ref = topology.cart_refold(cart, g, tag="repro://cart/refold1")
    assert ref.dims == cart.dims and ref.periods == cart.periods
    with pytest.raises(errors.DimsError):
        topology.cart_refold(cart, Group())


def test_grad_sync_reinits_per_epoch():
    from repro.optim.grad_sync import PartitionedGradSync

    g = default_session().group("repro://world")
    ep = CommEpoch.create(g, TopologySpec((ELASTIC,), ("data",)), name="gs")
    sync = PartitionedGradSync.for_epoch(ep)
    assert sync.inner is ep.comm
    assert PartitionedGradSync.for_epoch(ep) is sync  # one per epoch
    ep.revoke()
    with pytest.raises(errors.RevokedError):
        PartitionedGradSync.for_epoch(ep)
