"""Data pipeline: deterministic stateless resume (batch = f(seed, step)),
modality stubs, and learnability of the synthetic Markov stream."""

from __future__ import annotations

import numpy as np

from repro.data import TokenPipeline
from repro.configs.base import ParallelConfig
from repro.launch.mesh import make_host_mesh


def _pipe(**kw):
    base = dict(vocab_size=128, seq_len=32, global_batch=4, seed=7)
    base.update(kw)
    return TokenPipeline(**base)


def test_deterministic_resume():
    mesh = make_host_mesh()
    pcfg = ParallelConfig()
    p1 = _pipe()
    p2 = _pipe()
    for step in (0, 5, 1000):
        b1 = p1.device_batch(step, mesh, pcfg)
        b2 = p2.device_batch(step, mesh, pcfg)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))


def test_steps_differ():
    mesh = make_host_mesh()
    pcfg = ParallelConfig()
    p = _pipe()
    a = np.asarray(p.device_batch(1, mesh, pcfg)["tokens"])
    b = np.asarray(p.device_batch(2, mesh, pcfg)["tokens"])
    assert (a != b).any()


def test_tokens_in_vocab():
    mesh = make_host_mesh()
    p = _pipe()
    t = np.asarray(p.device_batch(3, mesh, ParallelConfig())["tokens"])
    assert t.min() >= 0 and t.max() < 128


def test_modality_stubs():
    mesh = make_host_mesh()
    pcfg = ParallelConfig()
    audio = _pipe(modality="audio", frame_dim=16, frame_len=8)
    b = audio.device_batch(0, mesh, pcfg)
    assert b["frames"].shape == (4, 8, 16)
    vlm = _pipe(modality="vlm", image_tokens=4, image_dim=32)
    b = vlm.device_batch(0, mesh, pcfg)
    assert b["image_embeds"].shape == (4, 4, 32)


def test_markov_stream_is_learnable():
    """The synthetic stream must have non-uniform transition structure
    (otherwise training-loss curves are meaningless)."""

    mesh = make_host_mesh()
    p = _pipe(seq_len=256, global_batch=8)
    t = np.asarray(p.device_batch(0, mesh, ParallelConfig())["tokens"])
    # bigram counts concentrated vs uniform: top-1 next-token share >> 1/V
    pairs = {}
    for row in t:
        for a, b in zip(row[:-1], row[1:]):
            pairs.setdefault(int(a), []).append(int(b))
    shares = []
    for a, succ in pairs.items():
        if len(succ) >= 8:
            vals, counts = np.unique(succ, return_counts=True)
            shares.append(counts.max() / counts.sum())
    assert np.mean(shares) > 3.0 / 128
