"""Spec-level coverage of all 40 (arch × shape) cells: input stand-ins and
shardings build for the production mesh shape without device allocation or
compilation (the compile path itself is exercised by launch/dryrun.py and
test_dryrun_integration.py)."""

from __future__ import annotations

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import base
from repro.core._compat import abstract_mesh
from repro.models import api as model_api
from repro.sharding import rules

MESH = abstract_mesh((16, 16), ("data", "model"))
MESH_MP = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _check(specs, tree, mesh):
    for spec, leaf in zip(
        (s for s in _iter_specs(specs)), (l for l in _iter_leaves(tree))
    ):
        shape = np.shape(leaf)
        for dim, axes in zip(shape, tuple(spec)):
            if axes is None:
                continue
            assert dim % _axis_size(mesh, axes) == 0, (shape, spec)


def _iter_specs(specs):
    import jax

    return jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))


def _iter_leaves(tree):
    import jax

    return jax.tree.leaves(tree)


@pytest.mark.parametrize("mesh,multi_pod", [(MESH, False), (MESH_MP, True)])
@pytest.mark.parametrize("arch", base.ARCHITECTURES)
def test_all_cells_spec_level(arch, mesh, multi_pod):
    import jax

    from repro.launch import specs as lspecs

    cfg = base.get_config(arch)
    pcfg = base.get_parallel(arch, multi_pod=multi_pod)
    bundle = model_api.build(cfg)
    params = lspecs.param_structs(bundle)
    pspecs = rules.param_specs(params, mesh, pcfg)
    _check(pspecs, params, mesh)

    for shape_name, shape in base.SHAPES.items():
        ok, why = base.shape_applicable(cfg, shape)
        if not ok:
            assert "full-attention" in why
            continue
        if shape.kind in ("train", "prefill"):
            batch = lspecs.batch_structs(cfg, shape, with_labels=shape.kind == "train")
            bspecs = rules.batch_spec(batch, mesh, pcfg)
            _check(bspecs, batch, mesh)
            # token budget sanity: the cell's global tokens are as assigned
            toks = batch["tokens"].shape
            if cfg.family == "vlm":
                assert toks[1] + cfg.num_image_tokens == shape.seq_len
            else:
                assert toks == (shape.global_batch, shape.seq_len)
        else:
            cache = lspecs.cache_structs(bundle, cfg, pcfg, shape)
            cspecs = rules.cache_specs(cache, mesh, pcfg, cfg)
            _check(cspecs, cache, mesh)
            n_leaves = len(jax.tree.leaves(cache))
            assert n_leaves >= 2, (arch, shape_name)
