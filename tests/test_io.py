"""Parallel file I/O (MPI 4.0 chapter 14): nonblocking collective requests
in the futures engine, split collectives, file views, open-mode semantics,
and the typed failure paths (a background error must never read as
success)."""

from __future__ import annotations

import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as mpx
from repro.core import errors
from repro.core import io as pio
from repro.core import tool
from repro.core.descriptors import Mode
from repro.core.futures import when_all


def _pvar(name):
    return tool.pvar_read().get(name, 0)


# -- nonblocking collective requests (MPI_File_iwrite/iread_at_all) ----------


def test_iwrite_returns_future_and_commits_manifest(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY)
    req = f.iwrite_at_all("x", np.arange(12.0).reshape(3, 4))
    assert isinstance(req, mpx.Future)
    rec = req.get()                      # completion = manifest sync point
    assert rec["shape"] == [3, 4]
    r = pio.open(str(tmp_path / "d"), Mode.RDONLY)
    np.testing.assert_array_equal(r.read_at_all("x"), np.arange(12.0).reshape(3, 4))


def test_iwrite_consumed_semantics(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY)
    req = f.iwrite_at_all("x", np.ones(4))
    req.get()
    with pytest.raises(errors.RequestError):
        req.get()                        # MPI_Wait freed the request


def test_iwrite_then_chains_into_engine(tmp_path):
    """then() on an I/O request is deferred: the continuation runs at the
    chain's completion and can consume the parent (paper Listing 2)."""

    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY)
    chained = f.iwrite_at_all("x", np.ones(8)).then(
        lambda req: req.get()["fragments"][0]["fragment"]
    )
    assert chained.get() == "x.0.npy"


def test_when_all_joins_io_requests(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY)
    reqs = [f.iwrite_at_all(n, np.full(4, i)) for i, n in enumerate("abc")]
    joined = when_all(reqs)
    records = joined.get()
    assert [r["name"] for r in records] == list("abc")
    for r in reqs:                       # MPI_Waitall consumed the inputs
        assert not r.valid()


def test_failed_iwrite_raises_err_io_never_silent(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY)

    def boom(frag):
        raise OSError(f"disk full writing {frag}")

    f.write_hook = boom
    req = f.iwrite_at_all("x", np.ones(4))
    with pytest.raises(errors.IoError):
        req.get()
    # the manifest never committed: the dataset has no record of "x"
    assert pio.open(str(tmp_path / "d"), Mode.RDONLY).names() == []


def test_failed_join_propagates_through_when_all(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY)
    fired = []

    def boom(frag):
        if frag.startswith("bad"):
            fired.append(frag)
            raise OSError("torn write")

    f.write_hook = boom
    good = f.iwrite_at_all("good", np.ones(4))
    bad = f.iwrite_at_all("bad", np.ones(4))
    with pytest.raises(errors.IoError):
        when_all([good, bad]).get()
    assert fired == ["bad.0.npy"]


def test_iread_at_all(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.RDWR)
    f.write_at_all("x", np.arange(6).reshape(2, 3))
    out = f.iread_at_all("x").get()
    np.testing.assert_array_equal(np.asarray(out), np.arange(6).reshape(2, 3))


# -- split collectives (MPI_File_write_at_all_begin / _end) ------------------


def test_split_collective_write(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.RDWR)
    f.write_at_all_begin("x", np.arange(4.0))
    rec = f.write_at_all_end("x")
    assert rec["name"] == "x"
    np.testing.assert_array_equal(np.asarray(f.read_at_all("x")), np.arange(4.0))


def test_one_split_collective_per_handle(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY)
    f.write_at_all_begin("x", np.ones(2))
    with pytest.raises(errors.RequestError):
        f.write_at_all_begin("y", np.ones(2))     # MPI: one active per handle
    with pytest.raises(errors.RequestError):
        f.write_at_all_end("y")                    # mismatched end
    f.write_at_all_end("x")
    with pytest.raises(errors.RequestError):
        f.write_at_all_end("x")                    # end without begin


# -- open-mode semantics (MPI_ERR_FILE_EXISTS) -------------------------------


def test_create_excl_raises_on_existing_dataset(tmp_path):
    """CREATE | EXCL on an existing dataset is ERR_FILE — the old elif made
    the EXCL branch unreachable whenever CREATE was set."""

    path = str(tmp_path / "d")
    pio.open(path, Mode.CREATE | Mode.WRONLY).write_at_all("x", np.ones(2))
    with pytest.raises(errors.FileError):
        pio.open(path, Mode.CREATE | Mode.EXCL | Mode.WRONLY)
    with pytest.raises(errors.FileError):
        pio.open(path, Mode.EXCL | Mode.WRONLY)   # EXCL alone rejects too
    # a fresh path is fine
    pio.open(str(tmp_path / "fresh"), Mode.CREATE | Mode.EXCL | Mode.WRONLY)


def test_write_requires_write_mode(tmp_path):
    f = pio.open(str(tmp_path / "d"), Mode.CREATE)
    with pytest.raises(errors.FileError):
        f.write_at_all("x", np.ones(2))
    with pytest.raises(errors.FileError):
        f.iwrite_at_all("x", np.ones(2))


# -- dtype reinterpretation rules --------------------------------------------


def test_foreign_dtype_fragment_raises_err_io(tmp_path):
    """A float64 fragment against a float32 manifest is a typed ERR_IO, not
    a blind view() that corrupts or crashes with a numpy error."""

    path = str(tmp_path / "d")
    f = pio.open(path, Mode.CREATE | Mode.WRONLY, checksum=False)
    f.write_at_all("x", np.ones(4, np.float32))
    # overwrite the fragment with a float64 payload, manifest unchanged
    frag = os.path.join(path, "x.0.npy")
    np.save(open(frag, "wb"), np.ones(4, np.float64), allow_pickle=False)
    r = pio.open(path, Mode.RDONLY, checksum=False)
    with pytest.raises(errors.IoError, match="refusing to reinterpret"):
        r.read_at_all("x")


def test_integrity_checks_survive_error_checking_off(tmp_path):
    """Data-integrity guards (dtype reinterpret, checksums) are NOT
    interface validation: the error_checking cvar must not disable them."""

    path = str(tmp_path / "d")
    f = pio.open(path, Mode.CREATE | Mode.WRONLY, checksum=False)
    f.write_at_all("x", np.ones(4, np.float32))
    np.save(open(os.path.join(path, "x.0.npy"), "wb"), np.ones(4, np.float64),
            allow_pickle=False)
    prev = mpx.set_error_checking(False)
    try:
        with pytest.raises(errors.IoError, match="refusing to reinterpret"):
            pio.open(path, Mode.RDONLY, checksum=False).read_at_all("x")
    finally:
        mpx.set_error_checking(prev)


def test_bf16_fragment_roundtrip(tmp_path):
    """bf16 fragments store as the uint16 alias and reinterpret back; parity
    asserted in float32 (bf16 equality is mesh-sensitive elsewhere)."""

    path = str(tmp_path / "d")
    x = jnp.arange(16, dtype=jnp.bfloat16) / 7
    f = pio.open(path, Mode.CREATE | Mode.WRONLY)
    rec = f.write_at_all("x", x)
    stored = np.load(os.path.join(path, rec["fragments"][0]["fragment"]))
    assert stored.dtype == np.uint16          # the serialisation alias
    out = pio.open(path, Mode.RDONLY).read_at_all("x")
    assert out.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out, np.float32), np.asarray(x, np.float32)
    )


def test_etype_view_storage(tmp_path):
    """set_view(etype=...) declares the storage representation explicitly;
    a mismatched itemsize is ERR_TYPE."""

    path = str(tmp_path / "d")
    f = pio.open(path, Mode.CREATE | Mode.WRONLY)
    f.set_view(etype=np.uint32)
    rec = f.write_at_all("x", np.arange(4, dtype=np.float32))
    assert rec["etype"] == "uint32"
    stored = np.load(os.path.join(path, "x.0.npy"))
    assert stored.dtype == np.uint32
    r = pio.open(path, Mode.RDONLY)            # record etype is sufficient
    np.testing.assert_array_equal(
        np.asarray(r.read_at_all("x")), np.arange(4, dtype=np.float32)
    )
    with pytest.raises(errors.TypeError_):
        f.set_view(etype=np.uint16)
        f.write_at_all("y", np.arange(4, dtype=np.float32))


# -- file views over C2 datatypes (MPI_File_set_view) ------------------------


@dataclasses.dataclass
class KVState:
    keys: object
    values: object
    step: int


def test_filetype_view_pages_roundtrip(tmp_path):
    """An aggregate round-trips through the packed per-dtype layout
    page-by-page — the same paging an RMA window uses for its transfers."""

    state = KVState(
        keys=jnp.arange(24, dtype=jnp.bfloat16).reshape(4, 6) / 3,
        values=jnp.ones((4, 6), jnp.bfloat16) * 2,
        step=7,
    )
    path = str(tmp_path / "d")
    f = pio.open(path, Mode.CREATE | Mode.WRONLY)
    f.set_view(filetype=state, num_pages=4)
    rec = f.write_at_all("kv", state)
    # one fragment per (dtype group, page): bf16 leaves pack together
    groups = {e["group"] for e in rec["fragments"]}
    assert len(rec["fragments"]) == len(groups) * 4
    before = _pvar("io_bytes_read")

    r = pio.open(path, Mode.RDONLY).set_view(filetype=state, num_pages=4)
    out = r.read_at_all("kv")
    assert isinstance(out, KVState)
    np.testing.assert_array_equal(
        np.asarray(out.keys, np.float32), np.asarray(state.keys, np.float32)
    )
    np.testing.assert_array_equal(
        np.asarray(out.values, np.float32), np.asarray(state.values, np.float32)
    )
    assert int(np.asarray(out.step)) == 7
    assert _pvar("io_bytes_read") > before


def test_view_mismatch_raises(tmp_path):
    state = KVState(keys=jnp.ones((2, 2)), values=jnp.zeros((2, 2)), step=1)
    path = str(tmp_path / "d")
    f = pio.open(path, Mode.CREATE | Mode.WRONLY)
    f.set_view(filetype=state, num_pages=2)
    f.write_at_all("kv", state)

    r = pio.open(path, Mode.RDONLY)
    with pytest.raises(errors.IoError, match="file view"):
        r.read_at_all("kv")                     # no view installed
    other = KVState(keys=jnp.ones((3, 3)), values=jnp.zeros((3, 3)), step=1)
    with pytest.raises(errors.IoError, match="view mismatch"):
        r.set_view(filetype=other).read_at_all("kv")


def test_window_pages_roundtrip_through_file(tmp_path):
    """The C2 packed layout a Window holds round-trips through a file: a
    window's aggregate, written under the window's own datatype view, reads
    back equal to the window buffer."""

    comm = mpx.world()

    @dataclasses.dataclass
    class Pair:
        a: object
        b: object

    local = Pair(a=jnp.arange(8.0), b=jnp.arange(8, dtype=jnp.int32))
    win = mpx.Window(comm, local)
    path = str(tmp_path / "d")
    f = pio.open(path, Mode.CREATE | Mode.WRONLY)
    f.set_view(filetype=win.datatype, num_pages=2)
    f.write_at_all("win", win.buffer)
    out = (
        pio.open(path, Mode.RDONLY)
        .set_view(filetype=win.datatype, num_pages=2)
        .read_at_all("win")
    )
    np.testing.assert_array_equal(np.asarray(out.a), np.asarray(local.a))
    np.testing.assert_array_equal(np.asarray(out.b), np.asarray(local.b))


# -- read-back verify + pvars -------------------------------------------------


def test_readback_verify_and_manifest_commit_pvars(tmp_path):
    before_commits = _pvar("io_manifest_commit")
    before_written = _pvar("io_bytes_written")
    f = pio.open(str(tmp_path / "d"), Mode.CREATE | Mode.WRONLY, verify=True)
    f.write_at_all("x", np.ones((8, 8)))
    assert _pvar("io_manifest_commit") == before_commits + 1
    assert _pvar("io_bytes_written") > before_written
