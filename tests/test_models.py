"""Per-architecture smoke tests (reduced same-family configs, one forward +
train step on CPU, shape and NaN checks) and decode-vs-prefill consistency:
token-by-token decoding must reproduce the full-sequence forward."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.models import api


def _batch_for(cfg, B=2, S=16, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, 1152)), jnp.bfloat16
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, 8, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.mark.parametrize("arch", base.ARCHITECTURES)
def test_smoke_forward_and_train_step(arch):
    cfg = base.get_smoke_config(arch)
    pcfg = base.get_parallel(arch)
    bundle = api.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)

    loss, metrics = bundle.loss(params, batch, pcfg, None)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert float(loss) > 0

    # one full SGD-ish step: grads exist and are finite for every leaf
    grads = jax.grad(lambda p: bundle.loss(p, batch, pcfg, None)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g.astype(jnp.float32)))) for g in flat), arch
    # parameters actually receive gradient signal somewhere
    total = sum(float(jnp.abs(g.astype(jnp.float32)).sum()) for g in flat)
    assert total > 0, f"{arch}: zero gradient"


@pytest.mark.parametrize("arch", base.ARCHITECTURES)
def test_full_config_instantiates(arch):
    cfg = base.get_config(arch)
    assert cfg.param_count() > 1e9 or arch == "seamless_m4t_large_v2"
    assert cfg.padded_vocab % 256 == 0
    shapes = [base.SHAPES[s] for s in base.SHAPES]
    applicable = [s for s in shapes if base.shape_applicable(cfg, s)[0]]
    assert applicable, arch


@pytest.mark.parametrize("arch", [
    "phi4_mini_3_8b",        # dense GQA
    "gemma2_9b",             # local/global + softcaps + post-norms
    "deepseek_v2_236b",      # MLA + MoE
    "grok_1_314b",           # MoE + softcaps
    "mamba2_2_7b",           # SSD
    "zamba2_7b",             # hybrid
    "paligemma_3b",          # VLM prefix-LM
])
def test_decode_matches_prefill(arch):
    """Prefill over S tokens (with one slot of decode headroom), then decode
    token S+1 == prefill of S+1 tokens (the cache is exact, not
    approximate).  Run in float32 so the comparison is tight."""

    import dataclasses

    # float32 + dropless MoE capacity so both paths route identically
    cfg = dataclasses.replace(
        base.get_smoke_config(arch), dtype="float32", capacity_factor=8.0
    )
    pcfg = base.get_parallel(arch)
    bundle = api.build(cfg)
    params = bundle.init(jax.random.PRNGKey(1))
    B, S = 2, 12
    batch = _batch_for(cfg, B=B, S=S + 1, key=7)
    if cfg.family == "vlm":
        batch["image_embeds"] = batch["image_embeds"].astype(jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = batch["frames"].astype(jnp.float32)
    tokens = batch["tokens"]

    pre_batch = {k: (v[:, :S] if k in ("tokens", "labels") else v) for k, v in batch.items()}
    logits_p, cache = bundle.prefill(params, pre_batch, pcfg, None, extra_capacity=1)
    logits_d, _ = bundle.decode(params, cache, tokens[:, S:S + 1], pcfg, None)

    # compare decode at position S against prefill of S+1 tokens
    logits_p2, _ = bundle.prefill(params, batch, pcfg, None)
    np.testing.assert_allclose(
        np.asarray(logits_d, np.float32),
        np.asarray(logits_p2, np.float32),
        atol=2e-3, rtol=2e-3,
    )


def test_gemma2_softcap_and_window_applied():
    cfg = base.get_smoke_config("gemma2_9b")
    pcfg = base.get_parallel("gemma2_9b")
    bundle = api.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, B=1, S=32)
    logits, _ = bundle.prefill(params, {"tokens": batch["tokens"]}, pcfg, None)
    assert float(jnp.abs(logits).max()) <= cfg.final_logit_softcap + 1e-3


def test_moe_router_balance_metrics():
    cfg = base.get_smoke_config("grok_1_314b")
    pcfg = base.get_parallel("grok_1_314b")
    bundle = api.build(cfg)
    params = bundle.init(jax.random.PRNGKey(0))
    _, metrics = bundle.loss(params, _batch_for(cfg), pcfg, None)
    assert "load_balance_loss" in metrics
    assert float(metrics["load_balance_loss"]) >= 0.0
    assert 0.0 <= float(metrics["dropped_fraction"]) <= 1.0


def test_param_count_analytic_close_to_actual():
    for arch in base.ARCHITECTURES:
        cfg = base.get_smoke_config(arch)
        bundle = api.build(cfg)
        params = bundle.init(jax.random.PRNGKey(0))
        actual = sum(int(np.prod(np.shape(p))) for p in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.12, (arch, actual, analytic)
