"""Elastic shrink/grow runtime (ULFM on the Sessions model), end to end.

The acceptance scenario: a deterministic ``FaultInjector.evict_rank``
schedule kills a rank mid-run, the trainer revokes its epoch, shrinks to the
survivor group (``Group.difference``), rebuilds the fabric through
``Communicator.from_group``, restores the last committed manifest, and
continues with **no job restart** — and the post-restore loss trajectory is
bit-identical to a fresh trainer restored from the same manifest on the
survivor set.  The grow path re-admits the rank and the data axis expands.

Everything runs in 8-virtual-device subprocesses with a frozen
``StepGuard.clock``: schedules key on the step counter alone, so the runs
replay deterministically (single-host SPMD simulation — see DESIGN.md's
honesty note: eviction is cooperative, no real process dies)."""

from __future__ import annotations

import textwrap

SHRINK_CODE = textwrap.dedent("""
    import jax
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.core import tool
    from repro.core.communicator import Communicator
    from repro.core.session import Session
    from repro.runtime.faults import FaultInjector
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64)

    def tcfg(ckpt_dir, steps):
        return TrainerConfig(steps=steps, lr=1e-3, checkpoint_dir=ckpt_dir,
                             checkpoint_every=2, log_every=1, seed=7)

    def comm_for(group, data):
        return Communicator.from_group(group, tag="repro://train",
                                       shape=(data, 2),
                                       axis_names=("data", "model"))

    import tempfile
    CKPT_A = tempfile.mkdtemp(prefix="elastic_a_")
    CKPT_B = tempfile.mkdtemp(prefix="elastic_b_")

    sess = Session.init()
    world = sess.group("repro://world")

    # --- elastic run: rank 2 dies at step 5, trainer shrinks and continues
    inj = FaultInjector().evict_rank(5, 2)
    t = Trainer(cfg, ParallelConfig(), tcfg(CKPT_A, 8), comm_for(world, 4),
                seq_len=32, global_batch=12, injector=inj, clock=lambda: 0.0)
    t0 = tool.pvar_read().get("trace:train_step", 0)
    res = t.run()
    traces = tool.pvar_read()["trace:train_step"] - t0
    assert res["final_step"] == 8, res
    assert res["evictions"] == 1 and res["restarts"] == 0, res
    assert res["epoch"] == 1, res
    assert res["world_size"] == 6, res            # 7 survivors, 6 fold (3, 2)
    assert t.comm.group().size() == 6
    assert traces == 2, traces                    # exactly 1 trace per epoch
    assert tool.pvar_read()["elastic:evictions"] == 1
    # eviction at 5, last committed manifest at 4: exactly 1 step replayed
    assert tool.pvar_read()["elastic:recovery_steps"] == 1
    # manifests are tagged with the fabric that wrote them
    assert t.ckpt.manifest_meta(4) == {"epoch": 0, "world_size": 8}
    assert t.ckpt.manifest_meta(8) == {"epoch": 1, "world_size": 6}
    elastic_tail = {m["step"]: m["loss"] for m in res["metrics"] if m["step"] > 4}

    # --- control: a fresh run to the same manifest, then a fresh trainer
    # restored from it on the SAME survivor set -> bit-identical trajectory
    pre = Trainer(cfg, ParallelConfig(), tcfg(CKPT_B, 4), comm_for(world, 4),
                  seq_len=32, global_batch=12, clock=lambda: 0.0)
    pre.run()
    survivors = world.excl([2])                   # rank 2 == device index 2
    assert survivors.compare(t.epoch.pool).name == "IDENT"
    folded = survivors.incl(range(6))             # the epoch's own fold rule
    assert folded.compare(t.comm.group()).name == "IDENT"
    g = Trainer(cfg, ParallelConfig(), tcfg(CKPT_B, 8), comm_for(folded, 3),
                seq_len=32, global_batch=12, clock=lambda: 0.0)
    gres = g.run()
    control_tail = {m["step"]: m["loss"] for m in gres["metrics"] if m["step"] > 4}
    assert set(elastic_tail) == set(control_tail) == {5, 6, 7, 8}
    for s in (5, 6, 7, 8):
        assert elastic_tail[s] == control_tail[s], (s, elastic_tail, control_tail)
    print("SHRINK_OK")
""")


GROW_CODE = textwrap.dedent("""
    import math
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.core import tool
    from repro.core.communicator import Communicator
    from repro.core.session import Session
    from repro.runtime.faults import FaultInjector
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = ModelConfig(name="tiny", family="dense", num_layers=1, d_model=32,
                      num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
                      vocab_size=64)
    import tempfile
    tcfg = TrainerConfig(steps=10, lr=1e-3,
                         checkpoint_dir=tempfile.mkdtemp(prefix="elastic_g_"),
                         checkpoint_every=2, log_every=1, seed=7)

    sess = Session.init()
    world = sess.group("repro://world")
    comm = Communicator.from_group(world, tag="repro://train", shape=(4, 2),
                                   axis_names=("data", "model"))

    # rank 1 dies at step 3; a spare (the evicted device) rejoins at step 6
    inj = FaultInjector().evict_rank(3, 1).admit_rank(6, 1)
    t = Trainer(cfg, ParallelConfig(), tcfg, comm, seq_len=32,
                global_batch=12, injector=inj, clock=lambda: 0.0)
    t0 = tool.pvar_read().get("trace:train_step", 0)
    res = t.run()
    traces = tool.pvar_read()["trace:train_step"] - t0
    assert res["final_step"] == 10, res
    assert res["evictions"] == 1 and res["joins"] == 1, res
    assert res["epoch"] == 2, res
    assert res["world_size"] == 8, res            # the data axis grew back
    assert t.comm.mesh.shape["data"] == 4
    assert traces == 3, traces                    # 1 per epoch, 3 epochs
    assert tool.pvar_read()["elastic:joins"] == 1
    losses = [m["loss"] for m in res["metrics"]]
    assert all(math.isfinite(x) for x in losses), losses
    print("GROW_OK")
""")


RESHARD_CODE = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager
    from repro.core.communicator import Communicator
    from repro.core.session import Session

    import tempfile
    CKPT = tempfile.mkdtemp(prefix="elastic_r_")

    sess = Session.init()
    world = sess.group("repro://world")

    # write under the full (4, 2) fabric...
    big = Communicator.from_group(world, tag="repro://big", shape=(4, 2),
                                  axis_names=("data", "model"))
    w = jax.device_put(
        jnp.arange(96, dtype=jnp.float32).reshape(12, 8),
        NamedSharding(big.mesh, P("data", "model")))
    tree = {"w": w, "b": jnp.float32(3.0)}
    m1 = CheckpointManager(CKPT, async_save=False)
    m1.save(1, tree, meta={"epoch": 0, "world_size": 8})
    m1.wait()
    assert m1.manifest_meta() == {"epoch": 0, "world_size": 8}

    # ...restore onto a 6-device survivor fabric (different world size)
    small = Communicator.from_group(world.excl([5, 7]), tag="repro://small",
                                    shape=(3, 2), axis_names=("data", "model"))
    tmpl = jax.device_put(
        jnp.zeros((12, 8), jnp.float32),
        NamedSharding(small.mesh, P("data", "model")))
    out, step = CheckpointManager(CKPT).restore(
        {"w": tmpl, "b": jnp.float32(0.0)},
        shardings={"w": NamedSharding(small.mesh, P("data", "model")),
                   "b": None})
    assert step == 1
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(w))
    assert float(out["b"]) == 3.0
    assert out["w"].sharding.mesh.shape["data"] == 3
    print("RESHARD_OK")
""")


def test_kill_a_rank_shrinks_bit_identically_8dev(subproc):
    out = subproc(SHRINK_CODE, n=8)
    assert "SHRINK_OK" in out


def test_grow_readmits_rank_8dev(subproc):
    out = subproc(GROW_CODE, n=8)
    assert "GROW_OK" in out


def test_checkpoint_restores_onto_different_world_size_8dev(subproc):
    out = subproc(RESHARD_CODE, n=8)
    assert "RESHARD_OK" in out
