"""The trip-count-aware HLO analyzer (core/hloanalysis.py) against exact
known counts — including the controlled experiment that motivated it:
``cost_analysis()`` counts while bodies once; the analyzer multiplies."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.hloanalysis import analyze_hlo


def _compile(fn, *structs):
    return jax.jit(fn).lower(*structs).compile()


def test_flat_matmul_exact():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    c = _compile(lambda a, b: a @ b, x, w)
    r = analyze_hlo(c.as_text())
    expect = 2 * 256 * 512 * 128
    assert abs(r.flops - expect) / expect < 0.05


def test_scan_multiplies_body():
    def body(x, _):
        return x @ x, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(f, x)
    r = analyze_hlo(c.as_text())
    expect = 10 * 2 * 256 ** 3
    assert abs(r.flops - expect) / expect < 0.05

    # the motivating bug: XLA's own analysis counts the body once
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca.get("flops", 0.0) < r.flops / 5


def test_nested_scan():
    def inner(x, _):
        return x @ x, None

    def outer(x, _):
        y, _ = jax.lax.scan(inner, x, None, length=5)
        return y, None

    def f(x):
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = _compile(f, x)
    r = analyze_hlo(c.as_text())
    expect = 15 * 2 * 128 ** 3
    assert abs(r.flops - expect) / expect < 0.05


def test_collective_inside_scan_counted(subproc):
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core.hloanalysis import analyze_hlo

from repro.core._compat import make_mesh, shard_map
mesh = make_mesh((8,), ("d",))

def body(x, _):
    return jax.lax.psum(x, "d"), None

def f(x):
    y, _ = jax.lax.scan(body, x, None, length=7)
    return y

fs = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P())
xs = jax.ShapeDtypeStruct((262144,), jnp.float32)   # 1 MiB payload
c = jax.jit(fs).lower(xs).compile()
r = analyze_hlo(c.as_text())
expect = 7 * 262144 * 4
assert abs(r.collectives.total_operand_bytes - expect) / expect < 0.05, \\
    r.collectives.total_operand_bytes
assert r.collectives.count["all-reduce"] == 7
# ring wire bytes: 2(n-1)/n per all-reduce
wire_expect = expect * 2 * 7 / 8
assert abs(r.collectives.total_wire_bytes - wire_expect) / wire_expect < 0.05
print("HLOANALYSIS_COLLECTIVE_OK")
"""
    assert "HLOANALYSIS_COLLECTIVE_OK" in subproc(code, n=8)


def test_bytes_reasonable_for_elementwise():
    x = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)  # 4 MiB
    c = _compile(lambda a: a * 2.0 + 1.0, x)
    r = analyze_hlo(c.as_text())
    # read 4 MiB + write 4 MiB, modulo fusion bookkeeping
    assert 4e6 <= r.bytes <= 4e7
