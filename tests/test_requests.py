"""The request layer (MPI 4.0 persistent + partitioned operations):
argument-list binding (ERR_REQUEST on drift), buffer donation, persistent
collectives over datatypes, partitioned order-independence, chunk-fused
continuations, partitioned gradient sync parity, and the trainer/server
zero-retrace guarantee."""

from __future__ import annotations

import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as mpx
from repro.core import errors, tool
from repro.core.futures import PartitionedRequest, PersistentRequest


# ---------------------------------------------------------------------------
# persistent requests: argument binding, donation, continuations
# ---------------------------------------------------------------------------


def test_persistent_start_shape_mismatch_raises():
    req = PersistentRequest(
        jax.jit(lambda x: x * 2.0), (jax.ShapeDtypeStruct((4,), jnp.float32),)
    )
    with pytest.raises(errors.RequestError):
        req.start(jnp.ones((5,), jnp.float32))


def test_persistent_start_dtype_mismatch_raises():
    req = PersistentRequest(
        jax.jit(lambda x: x * 2.0), (jax.ShapeDtypeStruct((4,), jnp.float32),)
    )
    with pytest.raises(errors.RequestError):
        req.start(jnp.ones((4,), jnp.int32))


def test_persistent_start_structure_mismatch_raises():
    req = PersistentRequest(
        jax.jit(lambda t: t["a"] + 1.0),
        ({"a": jax.ShapeDtypeStruct((2,), jnp.float32)},),
    )
    with pytest.raises(errors.RequestError):
        req.start({"a": jnp.ones((2,)), "b": jnp.ones((2,))})


def test_persistent_donation_aliases():
    """Donated inputs are invalidated and (where the backend aliases) the
    output reuses the input buffer."""

    jitted = jax.jit(lambda x: x + 1.0, donate_argnums=(0,))
    req = PersistentRequest(
        jitted, (jax.ShapeDtypeStruct((8,), jnp.float32),), donate_argnums=(0,)
    )
    assert req.donate_argnums == (0,)
    inp = jnp.zeros((8,), jnp.float32)
    try:
        ptr = inp.unsafe_buffer_pointer()
    except Exception:  # pragma: no cover - backend-dependent API
        ptr = None
    out = req.start(inp).get()
    np.testing.assert_array_equal(np.asarray(out), np.ones(8))
    if not inp.is_deleted():
        pytest.skip("backend ignores donation (no aliasing to check)")
    if ptr is not None:
        assert out.unsafe_buffer_pointer() == ptr  # true aliasing


def test_persistent_warm_start_prefetches():
    fired = []

    def fn(x):
        return x + 1.0

    req = PersistentRequest(
        jax.jit(fn), (jnp.full((4,), 7.0),), warm_start=True
    )
    # warm start ran on zeros the request owns; a real start still works and
    # the example argument was not consumed by the prefetch
    out = req.start(jnp.full((4,), 1.0)).get()
    np.testing.assert_array_equal(np.asarray(out), np.full(4, 2.0))
    assert fired == []  # nothing host-visible leaked from the prefetch


def test_persistent_then_continuations_chain_on_every_start():
    req = PersistentRequest(
        jax.jit(lambda x: x + 1.0), (jax.ShapeDtypeStruct((), jnp.float32),)
    )
    req.then(lambda f: f.get() * 10.0).then(lambda f: f.get() + 5.0)
    assert float(req.start(jnp.float32(1.0)).get()) == 25.0
    assert float(req.start(jnp.float32(2.0)).get()) == 35.0
    assert req.starts == 2


def test_persistent_start_counts_pvars():
    tool.pvar_reset()
    req = PersistentRequest(
        jax.jit(lambda x: x), (jax.ShapeDtypeStruct((), jnp.float32),)
    )
    req.start(jnp.float32(0.0)).get()
    req.start(jnp.float32(1.0)).get()
    counts = tool.pvar_read()
    assert counts["persistent_init"] == 1
    assert counts["persistent_start"] == 2
    # a rejected start is not an MPI_Start event
    with pytest.raises(errors.RequestError):
        req.start(jnp.ones((3,), jnp.float32))
    assert tool.pvar_read()["persistent_start"] == 2
    assert req.starts == 2
    # registered request pvars are enumerable before any event
    assert "partition_ready" in tool.pvar_info()


# ---------------------------------------------------------------------------
# persistent collectives (MPI_Allreduce_init & friends)
# ---------------------------------------------------------------------------


def test_persistent_collective_single_array():
    comm = mpx.world()
    req = comm.allreduce_init(jnp.ones((8,), jnp.float32))
    out = req.start(jnp.full((8,), 3.0)).get()
    np.testing.assert_array_equal(np.asarray(out), np.full(8, 3.0))
    with pytest.raises(errors.RequestError):
        req.start(jnp.ones((4,), jnp.float32))
    assert "all-reduce" in req.as_text()


def test_persistent_collective_aggregate_buckets():
    """One AOT executable per dtype bucket; start() packs/unpacks the
    aggregate through the datatype layer."""

    import dataclasses

    @dataclasses.dataclass
    class Grads:
        w: jax.Array
        b: jax.Array
        n: jax.Array

    comm = mpx.world()
    g = Grads(
        w=jnp.ones((4, 2), jnp.float32),
        b=jnp.ones((3,), jnp.float32),
        n=jnp.ones((2,), jnp.int32),
    )
    req = comm.allreduce_init(g)
    assert len(req.requests) == 2      # {f32} and {i32} buckets
    out = req.start(g).get()
    np.testing.assert_array_equal(np.asarray(out.w), np.ones((4, 2)))
    np.testing.assert_array_equal(np.asarray(out.n), np.ones(2, np.int32))
    # aggregate drift binds too: a swapped leaf dtype must not silently cast
    bad = Grads(w=g.w, b=g.b, n=g.n.astype(jnp.float32))
    with pytest.raises(errors.RequestError):
        req.start(bad)


# ---------------------------------------------------------------------------
# partitioned requests
# ---------------------------------------------------------------------------


def test_partitioned_pready_order_independence():
    import itertools

    for order in itertools.permutations(range(3)):
        req = PartitionedRequest(lambda i, x: x * (i + 1.0), 3).start()
        for i in order:
            req.pready(i, jnp.float32(2.0))
        res = [float(r) for r in req.wait()]
        assert res == [2.0, 4.0, 6.0], (order, res)


def test_partitioned_protocol_errors():
    req = PartitionedRequest(lambda i, x: x, 2)
    with pytest.raises(errors.RequestError):
        req.pready(0, 1.0)                # pready before start
    req.start()
    with pytest.raises(errors.RequestError):
        req.start()                       # double activation
    req.pready(0, jnp.float32(1.0))
    with pytest.raises(errors.RequestError):
        req.pready(0, jnp.float32(1.0))   # duplicate pready
    with pytest.raises(errors.RequestError):
        req.pready(5, jnp.float32(1.0))   # out of range
    with pytest.raises(errors.PendingError):
        req.wait()                        # partition 1 never readied
    req.pready(1, jnp.float32(2.0))
    assert [float(r) for r in req.wait()] == [1.0, 2.0]
    req.start()                           # persistent: reusable after wait


def test_partitioned_laziness_and_chunk_fused_continuations():
    """Nothing is traced at pready time; the continuation fuses into each
    partition's future and is traced exactly once per chunk at forcing.
    The python-level assertions run at trace time inside the SPMD body."""

    comm = mpx.world()
    ran: list[int] = []

    def continuation(i, reduced):
        ran.append(i)
        return reduced + i

    @comm.spmd
    def prog():
        req = mpx.partitioned_allreduce(comm, 3, continuation=continuation)
        futs = [req.pready(i, jnp.float32(10.0)) for i in (2, 0, 1)]
        assert ran == []                  # lazy: no partition traced yet
        assert not any(req.parrived(i) for i in range(3))
        chained = futs[1].then(lambda f: f.get() * 2.0)   # chunk-wise then()
        assert ran == []
        doubled = chained.get()           # futs[1] is partition 0: force it
        assert ran == [0]
        res = req.wait()
        assert ran == [0, 1, 2]           # remaining chunks, index order
        return (doubled, *res)

    doubled, *res = prog()
    assert float(doubled) == 20.0
    assert [float(r) for r in res] == [10.0, 11.0, 12.0]


# ---------------------------------------------------------------------------
# multi-device: partitioned collectives inside SPMD + sharding binding
# ---------------------------------------------------------------------------


PARTITIONED_SPMD = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import core as mpx
    from repro.core import errors, overlap

    comm = mpx.world()
    N = comm.size()
    assert N == 8

    @comm.spmd
    def prog():
        r = comm.rank().astype(jnp.float32)
        req = comm.partitioned_allreduce(3)
        for i in (2, 0, 1):                      # any Pready order
            req.pready(i, r + i)
        return tuple(req.wait())

    out = prog()
    base = sum(range(8))
    for i, o in enumerate(out):
        np.testing.assert_allclose(np.asarray(o), base + 8 * i)
    print("PARTITIONED_SPMD_OK")

    # partitioned ring all-gather: chunk continuation fuses into the ring
    @comm.spmd
    def ring():
        r = comm.rank().astype(jnp.float32)
        req = overlap.partitioned_ring_all_gather(
            comm, 2, continuation=lambda i, g: g.sum() + i)
        req.pready(1, jnp.ones((2,)) * r)
        req.pready(0, jnp.ones((2,)) * r)
        return tuple(req.wait())

    s0, s1 = ring()
    np.testing.assert_allclose(np.asarray(s0), 2 * sum(range(8)))
    np.testing.assert_allclose(np.asarray(s1), 2 * sum(range(8)) + 1)
    print("PARTITIONED_RING_OK")

    # persistent request sharding binding: starting with a differently
    # sharded argument raises ERR_REQUEST instead of silently resharding
    from jax.sharding import NamedSharding, PartitionSpec as P
    x_sharded = jax.device_put(
        jnp.arange(16, dtype=jnp.float32),
        NamedSharding(comm.mesh, P(comm.axis_names[0])),
    )
    req = comm.persistent(lambda x: x * 2.0, x_sharded,
                          in_specs=P(comm.axis_names[0]),
                          out_specs=P(comm.axis_names[0]))
    req.start(x_sharded).get()
    x_repl = jax.device_put(
        jnp.arange(16, dtype=jnp.float32), NamedSharding(comm.mesh, P())
    )
    try:
        req.start(x_repl)
        raise SystemExit("sharding mismatch did not raise")
    except errors.RequestError:
        print("SHARDING_BINDING_OK")
""")


def test_partitioned_spmd_multidevice(subproc):
    out = subproc(PARTITIONED_SPMD, n=8)
    assert "PARTITIONED_SPMD_OK" in out
    assert "PARTITIONED_RING_OK" in out
    assert "SHARDING_BINDING_OK" in out


# ---------------------------------------------------------------------------
# partitioned gradient sync: parity with the bucketed reference
# ---------------------------------------------------------------------------


GRAD_SYNC_PARITY = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro import core as mpx
    from repro.core import datatypes
    from repro.core.communicator import Communicator
    from repro.core.descriptors import Compression
    from repro.core.overlap import hierarchical_allreduce
    from repro.optim.grad_sync import (
        ErrorFeedbackState, PartitionedGradSync, _compress_with_feedback,
        sync_gradients,
    )

    comm = Communicator.create((2, 4), ("outer", "inner"))
    inner, outer = comm.split("inner"), comm.split("outer")

    def make_grads(r):
        return {
            "w": jnp.outer(jnp.arange(1, 5.0), jnp.ones(3)) * (r + 1.0),
            "b": jnp.arange(3, dtype=jnp.float32) * (r - 2.0),
        }

    def reference(grads, inner_c, outer_c, compression, ef, mean):
        # the former bucketed sync_gradients, inlined verbatim as the oracle
        n_total = inner_c.size() * (outer_c.size() if outer_c is not None else 1)
        scale = 1.0 / n_total if mean else 1.0
        new_ef = ef
        if compression is Compression.INT8 and ef is not None:
            flat_g, treedef = jax.tree.flatten(grads)
            flat_e = treedef.flatten_up_to(ef.residual)
            pairs = [_compress_with_feedback(g, e) for g, e in zip(flat_g, flat_e)]
            grads = treedef.unflatten([p[0] for p in pairs])
            new_ef = ErrorFeedbackState(
                residual=treedef.unflatten([p[1] for p in pairs]))
        def reduce_leaf(g):
            if outer_c is None:
                return jax.lax.psum(g, inner_c.axis_names)
            return hierarchical_allreduce(g, inner_c, outer_c,
                                          compression=compression)
        bufs, dt = datatypes.pack(grads)
        synced = datatypes.unpack([reduce_leaf(b) for b in bufs], dt)
        out = jax.tree.map(
            lambda s: (s.astype(jnp.float32) * scale).astype(s.dtype), synced)
        return out, new_ef

    MODES = [
        ("single", None, Compression.NONE, False),
        ("hier", outer, Compression.NONE, False),
        ("hier_int8_ef", outer, Compression.INT8, True),
    ]

    for name, outer_c, compression, use_ef in MODES:
        sync = PartitionedGradSync(inner, outer_c, compression=compression)

        @comm.spmd
        def run_pair():
            r = comm.rank().astype(jnp.float32)
            g = make_grads(r)
            ef = ErrorFeedbackState.init(g) if use_ef else None
            got, got_ef = sync(g, ef)
            want, want_ef = reference(g, inner, outer_c, compression, ef, True)
            diffs = [got["w"] - want["w"], got["b"] - want["b"]]
            if use_ef:
                diffs += [got_ef.residual["w"] - want_ef.residual["w"]]
            return [jnp.max(jnp.abs(d)) for d in diffs]

        for d in run_pair():
            assert float(np.max(np.asarray(d))) == 0.0, name
        print(f"PARITY_{name}_OK")

    # functional wrapper and pready-order permutations agree bitwise
    # (two dtype groups -> two buckets -> two partitions to permute)
    @comm.spmd
    def orders():
        r = comm.rank().astype(jnp.float32)
        g = {
            "w": jnp.outer(jnp.arange(1, 5.0), jnp.ones(3)) * (r + 1.0),
            "b": (jnp.arange(3, dtype=jnp.float32) * (r - 2.0)).astype(jnp.bfloat16),
        }
        a, _ = sync_gradients(g, inner, outer, pready_order=(0, 1))
        b, _ = sync_gradients(g, inner, outer, pready_order=(1, 0))
        return (
            jnp.max(jnp.abs(a["w"] - b["w"]))
            + jnp.max(jnp.abs(a["b"].astype(jnp.float32) - b["b"].astype(jnp.float32)))
        )

    assert float(np.max(np.asarray(orders()))) == 0.0
    print("ORDER_INDEPENDENT_OK")
""")


def test_partitioned_grad_sync_parity(subproc):
    out = subproc(GRAD_SYNC_PARITY, n=8)
    assert "PARITY_single_OK" in out
    assert "PARITY_hier_OK" in out
    assert "PARITY_hier_int8_ef_OK" in out
    assert "ORDER_INDEPENDENT_OK" in out


# ---------------------------------------------------------------------------
# the persistent execution engine: zero traces after the first step
# ---------------------------------------------------------------------------


def _tiny_cfg():
    from repro.configs.base import ModelConfig

    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128,
    )


def test_trainer_persistent_zero_retrace():
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.trainer import Trainer, TrainerConfig

    tool.pvar_reset()
    t = Trainer(
        _tiny_cfg(), ParallelConfig(),
        TrainerConfig(steps=5, lr=1e-3, log_every=5),
        make_host_mesh(), seq_len=32, global_batch=4,
    )
    result = t.run()
    assert result["final_step"] == 5
    counts = tool.pvar_read()
    assert counts["trace:train_step"] == 1          # traced exactly once
    assert counts["persistent_start"] == 5          # MPI_Start per step
    assert counts["persistent_init"] == 1
    # the metrics line surfaces the request pvars
    assert result["metrics"][-1]["persistent_start"] == 5
    assert "partition_ready" in result["metrics"][-1]


def test_server_persistent_zero_retrace():
    from repro.configs.base import ParallelConfig
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.server import Request, Server, ServerConfig

    rng = np.random.default_rng(0)
    s = Server(
        _tiny_cfg(), ParallelConfig(),
        ServerConfig(max_batch=2, max_new_tokens=4), make_host_mesh(),
    )
    reqs = [Request(tokens=rng.integers(1, 128, size=(8,), dtype=np.int32))
            for _ in range(2)]
    tool.pvar_reset()
    s.generate(reqs)
    first = tool.pvar_read()
    assert first["trace:decode_step"] == 1
    assert first["trace:prefill_step"] == 1
    s.generate(reqs)                                # same shape bucket
    counts = tool.pvar_read()
    assert counts["trace:decode_step"] == 1         # zero traces after warm
    assert counts["trace:prefill_step"] == 1
    assert counts["persistent_start"] == 2 * (1 + 3)  # prefill + 3 decodes each
