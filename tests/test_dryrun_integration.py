"""Dry-run integration at test scale: the exact specs→steps→rules→lower→
compile path the production dry-run uses, on an 8-virtual-device (2×4) mesh
with smoke configs — plus the roofline extraction on the compiled artifact."""

from __future__ import annotations

import textwrap

import pytest

CODE = textwrap.dedent("""
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import base
    from repro.launch import specs as specs_mod, steps as steps_mod
    from repro.optim import AdamW
    from repro.sharding import rules
    from repro.core import hloanalysis, tool

    from repro.core._compat import make_mesh
    mesh = make_mesh((2, 4), ("data", "model"))

    ARCH = "{arch}"
    cfg = base.get_smoke_config(ARCH)
    pcfg = base.get_parallel(ARCH)
    pcfg.data_axes = ("data",)

    bundle_cfg = cfg
    opt = AdamW(lr=1e-4, moment_dtype=pcfg.moment_dtype)

    # --- train step lower+compile ---
    from repro.models import api as model_api
    bundle = model_api.build(cfg)
    params = specs_mod.param_structs(bundle)
    opt_state = specs_mod.opt_structs(opt, params)
    shape = base.ShapeConfig("t", 64, 4, "train")
    batch = specs_mod.batch_structs(cfg, shape, with_labels=True)
    pshard = rules.shardings(rules.param_specs(params, mesh, pcfg), mesh)
    bshard = rules.shardings(rules.batch_spec(batch, mesh, pcfg), mesh)
    oshard = specs_mod._moment_shardings(params, pshard, opt_state, mesh)
    step = steps_mod.make_train_step(cfg, pcfg, opt)
    with mesh:
        compiled = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
        ).lower(params, opt_state, batch).compile()
    assert compiled.memory_analysis() is not None
    cost = hloanalysis.analyze_hlo(compiled.as_text())
    assert cost.flops > 0
    # FSDP + grad sync must produce collectives on a >1-device mesh
    assert cost.collectives.total_operand_bytes > 0

    # --- decode step (skip encdec: cache built by prefill) ---
    if cfg.family != "encdec":
        dshape = base.ShapeConfig("d", 64, 4, "decode")
        cache = specs_mod.cache_structs(bundle, cfg, pcfg, dshape)
        cshard = rules.shardings(rules.cache_specs(cache, mesh, pcfg, cfg), mesh)
        tok = specs_mod.token_struct(dshape)
        dstep = steps_mod.make_decode_step(cfg, pcfg)
        with mesh:
            dcomp = jax.jit(
                dstep, in_shardings=(pshard, cshard, None),
                out_shardings=(None, cshard),
            ).lower(params, cache, tok).compile()
        assert dcomp.memory_analysis() is not None
    print("DRYRUN_INTEGRATION_OK", ARCH)
""")


@pytest.mark.parametrize("arch", [
    "gemma2_9b",            # local/global + softcap
    "deepseek_v2_236b",     # MLA + MoE + EP
    "mamba2_2_7b",          # SSD
    "zamba2_7b",            # hybrid
    "paligemma_3b",         # VLM
])
def test_dryrun_path_small_mesh(subproc, arch):
    out = subproc(CODE.format(arch=arch), n=8, timeout=1200)
    assert "DRYRUN_INTEGRATION_OK" in out


def test_microbatched_train_step_lowers(subproc):
    code = CODE.format(arch="grok_1_314b").replace(
        'pcfg.data_axes = ("data",)',
        'pcfg.data_axes = ("data",); pcfg.microbatches = 2',
    )
    out = subproc(code, n=8, timeout=1200)
    assert "DRYRUN_INTEGRATION_OK" in out
