"""Elastic rescale across real (virtual) device meshes: a checkpoint written
under a (4 data × 2 model) mesh restores bit-exactly under (2 data × 4 model)
— the restart path a 1000-node deployment takes when a slice is lost."""

from __future__ import annotations

import textwrap

CODE = textwrap.dedent("""
    import tempfile
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import CheckpointManager
    from repro.configs.base import ModelConfig, ParallelConfig
    from repro.models import api
    from repro.sharding import rules

    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab_size=512)
    pcfg = ParallelConfig()
    bundle = api.build(cfg)

    from repro.core._compat import make_mesh
    mesh_a = make_mesh((4, 2), ("data", "model"))
    mesh_b = make_mesh((2, 4), ("data", "model"))

    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2, async_save=False)

        with mesh_a:
            params = jax.jit(bundle.init)(jax.random.PRNGKey(0))
            shard_a = rules.shardings(rules.param_specs(params, mesh_a, pcfg), mesh_a)
            params = jax.device_put(params, shard_a)
        mgr.save(7, {"params": params}, extra={"step": 7})
        mgr.wait()

        # "cluster resize": restore the same logical arrays on mesh B
        with mesh_b:
            template = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
            )
            shard_b = rules.shardings(rules.param_specs(template, mesh_b, pcfg), mesh_b)
            zeros = jax.tree.map(
                lambda s, sh: jax.device_put(jnp.zeros(s.shape, s.dtype), sh),
                template, shard_b,
            )
            restored, step = mgr.restore({"params": zeros},
                                         shardings={"params": shard_b})
        assert step == 7
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            # and the restored copies actually live under mesh B
        leaf = jax.tree.leaves(restored["params"])[0]
        assert leaf.sharding.mesh.shape["data"] == 2
    print("ELASTIC_OK")
""")


def test_elastic_restore_across_meshes(subproc):
    assert "ELASTIC_OK" in subproc(CODE, n=8, timeout=900)
