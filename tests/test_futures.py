"""Futures with continuations (paper C3 / Listing 2): host futures,
trace futures, when_all/when_any joins, persistent requests."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import core as mpx
from repro.core import errors
from repro.core.futures import (
    Future,
    PersistentRequest,
    TraceFuture,
    trace_when_all,
    when_all,
    when_any,
)


def test_host_future_get_consumes():
    f = Future(jnp.ones((2,)))
    np.testing.assert_array_equal(f.get(), np.ones(2))
    assert not f.valid()
    with pytest.raises(errors.RequestError):
        f.get()


def test_double_get_raises():
    f = Future(jnp.asarray(1.0))
    assert f.valid()
    f.get()
    with pytest.raises(errors.RequestError):
        f.get()


def test_host_future_then_chains():
    f = Future(jnp.asarray(1.0))
    g = f.then(lambda fut: fut.get() + 1.0).then(lambda fut: fut.get() * 3.0)
    assert float(g.get()) == 6.0


def test_host_future_then_consumes_parent():
    """Chaining hands the request to the continuation: a chained-then-get
    double read raises ERR_REQUEST, consistent with when_all's behaviour."""

    f = Future(jnp.asarray(1.0))
    g = f.then(lambda fut: fut.get() + 1.0)
    assert not f.valid()
    with pytest.raises(errors.RequestError):
        f.get()
    assert float(g.get()) == 2.0

    # even a continuation that never reads the value consumes the parent
    h = Future(jnp.asarray(2.0))
    h.then(lambda fut: jnp.asarray(0.0))
    assert not h.valid()
    with pytest.raises(errors.RequestError):
        h.then(lambda fut: fut)     # then() on a consumed future is erroneous

    # a pass-through continuation hands the value on in a fresh valid request
    p = Future(jnp.asarray(3.0))
    q = p.then(lambda fut: fut)
    assert not p.valid() and q.valid()
    assert float(q.get()) == 3.0


def test_when_any_timeout_raises_pending():
    class _NeverReady:
        shape, dtype = (), jnp.float32

        def is_ready(self):
            return False

    stuck = Future(_NeverReady())
    with pytest.raises(errors.PendingError):
        when_any([stuck], timeout_s=0.05)
    done = Future(jnp.asarray(1.0))
    f, idx = when_any([stuck, done], timeout_s=1.0)   # a ready peer still wins
    assert idx == 1 and float(f.get()) == 1.0


def test_when_all_joins():
    fs = [Future(jnp.asarray(i)) for i in range(4)]
    joined = when_all(fs)
    assert tuple(int(v) for v in joined.get()) == (0, 1, 2, 3)


def test_when_all_consumes_and_rejects_consumed():
    fs = [Future(jnp.asarray(0)), Future(jnp.asarray(1))]
    when_all(fs)
    for f in fs:                      # MPI_Waitall freed the requests
        assert not f.valid()
        with pytest.raises(errors.RequestError):
            f.get()
    consumed = Future(jnp.asarray(2))
    consumed.get()
    with pytest.raises(errors.RequestError):
        when_all([Future(jnp.asarray(3)), consumed])
    dup = Future(jnp.asarray(4))
    with pytest.raises(errors.RequestError):
        when_all([dup, dup])        # same request twice is erroneous
    stale = Future(jnp.asarray(5))
    stale.get()
    with pytest.raises(errors.RequestError):
        stale.wait()                # wait on a consumed request


def test_when_any_returns_completed():
    fs = [Future(jnp.asarray(7)), Future(jnp.asarray(8))]
    f, idx = when_any(fs)
    assert idx in (0, 1)
    assert int(f.get()) in (7, 8)


def test_when_any_empty_raises():
    with pytest.raises(errors.RequestError):
        when_any([])


def test_when_any_rejects_consumed_input():
    consumed = Future(jnp.asarray(0))
    consumed.get()
    with pytest.raises(errors.RequestError):
        when_any([Future(jnp.asarray(1)), consumed])


def test_trace_when_any_empty_raises():
    from repro.core.futures import trace_when_any

    with pytest.raises(errors.RequestError):
        trace_when_any([])


def test_trace_future_is_lazy():
    forced = []

    def thunk():
        forced.append(1)
        return jnp.asarray(2.0)

    tf = TraceFuture(thunk)
    assert not tf.test()
    assert not forced
    chained = tf.then(lambda f: f.get() + 1.0)
    assert not forced            # still nothing traced
    assert float(chained.get()) == 3.0
    assert forced == [1]


def test_trace_future_continuations_defer_until_forced():
    """A .then() chain builds the task graph without running any stage; only
    forcing the chain end traces it, and exactly once."""

    ran = []

    def record(label, value):
        ran.append(label)
        return value

    tf = TraceFuture(lambda: record("src", jnp.asarray(1.0)))
    chain = tf.then(lambda f: record("c1", f.get() + 1.0)).then(
        lambda f: record("c2", f.get() * 2.0)
    )
    assert ran == []             # continuations must not run before forcing
    assert not chain.test()
    assert float(chain.get()) == 4.0
    assert ran == ["src", "c1", "c2"]
    assert float(chain.get()) == 4.0  # trace futures are re-readable
    assert ran == ["src", "c1", "c2"]  # ...without re-tracing


def test_trace_when_all():
    tfs = [TraceFuture.ready(jnp.asarray(i)) for i in range(3)]
    out = trace_when_all(tfs).get()
    assert tuple(int(v) for v in out) == (0, 1, 2)


def test_when_all_dispatches_trace_futures():
    # an all-TraceFuture join goes to trace_when_all and stays lazy
    hits = []
    futs = [TraceFuture(lambda i=i: hits.append(i) or i) for i in range(3)]
    joined = when_all(futs)
    assert isinstance(joined, TraceFuture)
    assert hits == []                       # nothing forced yet
    assert joined.get() == (0, 1, 2)
    assert hits == [0, 1, 2]                # forced in issue order


def test_when_all_rejects_mixed_levels():
    # a trace-level request cannot be joined outside its SPMD region: the
    # host branch would read its unforced value as None and drop the op
    hits = []
    with pytest.raises(errors.RequestError):
        when_all([Future(7), TraceFuture(lambda: hits.append(1) or 1)])
    assert hits == []


def test_listing2_chain_single_device():
    """The paper's Listing 2 semantics on a 1-device world: the broadcast
    chain increments on designated ranks; with world size 1 every root is
    rank 0, so data increments twice."""

    comm = mpx.world()

    @comm.spmd
    def listing2():
        data = jnp.where(comm.rank() == 0, jnp.int32(1), jnp.int32(0))
        f = mpx.future(comm.immediate_broadcast(data, root=0))
        f = f.then(
            lambda fut: comm.immediate_broadcast(fut.get() + 1, root=0)
        ).then(
            lambda fut: comm.immediate_broadcast(fut.get() + 1, root=0)
        )
        return f.get()

    assert int(listing2()) == 3


def test_persistent_request_reuse():
    jitted = jax.jit(lambda x: x * 2.0)
    req = PersistentRequest(jitted, (jax.ShapeDtypeStruct((4,), jnp.float32),))
    out1 = req.start(jnp.ones((4,), jnp.float32)).get()
    out2 = req.start(jnp.full((4,), 3.0, jnp.float32)).get()
    np.testing.assert_array_equal(out1, np.full(4, 2.0))
    np.testing.assert_array_equal(out2, np.full(4, 6.0))
    assert req.as_text()  # compiled artifact is inspectable (MPI_T-ish)


def test_persistent_request_start_futures_are_independent():
    """Each MPI_Start yields a fresh request: consuming one leaves the others
    valid, and the joined results are per-start."""

    req = PersistentRequest(
        jax.jit(lambda x: x + 1.0), (jax.ShapeDtypeStruct((), jnp.float32),)
    )
    a = req.start(jnp.float32(1.0))
    b = req.start(jnp.float32(2.0))
    assert float(a.get()) == 2.0
    with pytest.raises(errors.RequestError):
        a.get()                     # consumed
    assert b.valid()                # sibling start unaffected
    joined = when_all([b, req.start(jnp.float32(3.0))])
    assert tuple(float(v) for v in joined.get()) == (3.0, 4.0)


def test_task_graph_fork_join():
    """Forks = multiple futures from the current context; join = when_all."""

    comm = mpx.world()

    @comm.spmd
    def graph():
        a = comm.immediate_allreduce(jnp.asarray(1.0))
        b = comm.immediate_allreduce(jnp.asarray(2.0))
        joined = trace_when_all([a, b])
        s = joined.then(lambda f: f.get()[0] + f.get()[1])
        return s.get()

    assert float(graph()) == 3.0
