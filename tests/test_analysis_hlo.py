"""repro.analysis.hlo — the reusable HLO predicate passes (layer 2).

The passes are the single home of the schedule proofs that
``benchmarks/hlo_parity.py`` and the tier-1 tests previously counted
inline; here each predicate is exercised against real compiled modules
(8 virtual devices, in a subprocess per conftest policy) on both its
passing and failing side — a pass that cannot fail proves nothing.
"""

from __future__ import annotations

from repro.analysis.hlo import PassResult, pvar_invariant


def test_pass_result_protocol():
    good = PassResult("p", True, {"x": 1})
    bad = PassResult("p", False, {"x": 2})
    assert good and not bad
    assert "ok" in str(good) and "FAIL" in str(bad)


def test_pvar_invariant():
    counters = {"trace:train_step": 1}
    assert pvar_invariant(counters, "trace:train_step", 1).ok
    r = pvar_invariant(counters, "trace:train_step", 2)
    assert not r.ok and r.detail["got"] == 1
    assert not pvar_invariant({}, "trace:train_step", 1).ok


def test_hlo_passes_on_compiled_modules(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro import core as mpx
from repro.analysis import hlo as hlo_passes

comm = mpx.world()
N, name, lax = comm.size(), comm.axis_names[0], jax.lax
x = jax.ShapeDtypeStruct((8 * N, 16), jnp.float32)

def compile_(fn):
    return jax.jit(comm.spmd(fn, jit=False)).lower(x).compile()

psum = compile_(lambda v: lax.psum(v, name))
ring = compile_(lambda v: lax.ppermute(v, name, [(i, (i + 1) % N) for i in range(N)]))
gather = compile_(lambda v: lax.all_gather(v, name))

# no_collective: both verdicts
assert hlo_passes.no_collective(psum, "all-gather", "all-to-all").ok
bad = hlo_passes.no_collective(gather, "all-gather")
assert not bad.ok and bad.detail["present"] == {"all-gather": 1}

# counts
assert hlo_passes.collective_count(psum, "all-reduce", 1).ok
assert not hlo_passes.collective_count(psum, "all-reduce", 2).ok
assert hlo_passes.permute_count(ring, 1).ok
assert not hlo_passes.permute_count(psum, 1).ok

# identical_lowering: reflexive yes, across different programs no
assert hlo_passes.identical_lowering(psum, psum).ok
assert not hlo_passes.identical_lowering(psum, gather).ok

# parity with the persistent path (accepts PersistentRequest via as_text)
req = comm.allreduce_init(x)
assert hlo_passes.identical_lowering(req, compile_(lambda v: comm.allreduce(v))).ok

# wire fractions: one permute moves 1 shard where the gather moves N-1
wf = hlo_passes.wire_fraction_below(ring, gather, 1.0 / (N - 1) + 1e-9)
assert wf.ok, wf
assert not hlo_passes.wire_fraction_below(gather, ring, 0.5).ok

# stats_dict is the parity row shape
row = hlo_passes.stats_dict(psum)
assert set(row) == {"counts", "operand_bytes", "wire_bytes"}
assert row["counts"] == {"all-reduce": 1}
print("HLO_PASSES_OK")
""")
    assert "HLO_PASSES_OK" in out
