"""Serving-loop batching and PRNG behaviour (single-device; the
disaggregated transport's multi-device path lives in test_onesided.py)."""

from __future__ import annotations

import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import errors
from repro.launch.mesh import make_host_communicator
from repro.runtime.server import Request, Server, ServerConfig


def _tiny_cfg():
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, head_dim=16, d_ff=64, vocab_size=64,
    )


@pytest.fixture(scope="module")
def sampling_server():
    return Server(
        _tiny_cfg(), ParallelConfig(),
        ServerConfig(max_batch=2, max_new_tokens=5, temperature=0.8, seed=7),
        make_host_communicator(),
    )


def _reqs(cfg, n=2, seed=0):
    rng = np.random.default_rng(seed)
    return [
        Request(tokens=rng.integers(1, cfg.vocab_size, size=(8,), dtype=np.int32))
        for _ in range(n)
    ]


# -- _pad_batch: extras are keyed off the batch UNION -------------------------


def test_pad_batch_union_of_extras(sampling_server):
    # request 0 has NO extras; request 1 carries one — the old code keyed
    # off requests[0] and silently dropped it
    reqs = [
        Request(tokens=np.ones((4,), np.int32)),
        Request(tokens=np.ones((6,), np.int32),
                extra={"image_embeds": np.ones((3, 8), np.float32)}),
    ]
    with pytest.raises(errors.ArgError):
        sampling_server._pad_batch(reqs)

    # both requests supply the key: it must appear, stacked, in the batch
    reqs = [
        Request(tokens=np.ones((4,), np.int32),
                extra={"image_embeds": np.zeros((3, 8), np.float32)}),
        Request(tokens=np.ones((6,), np.int32),
                extra={"image_embeds": np.ones((3, 8), np.float32)}),
    ]
    batch, lens = sampling_server._pad_batch(reqs)
    assert batch["image_embeds"].shape == (2, 3, 8)
    assert list(lens) == [4, 6]
    # left-padding keeps the last token aligned
    assert batch["tokens"].shape == (2, 6)


def test_pad_batch_missing_key_is_err_arg(sampling_server):
    reqs = [
        Request(tokens=np.ones((4,), np.int32),
                extra={"image_embeds": np.zeros((3, 8), np.float32)}),
        Request(tokens=np.ones((4,), np.int32)),   # lacks the key
    ]
    with pytest.raises(errors.ArgError) as e:
        sampling_server._pad_batch(reqs)
    assert "image_embeds" in str(e.value)


# -- generate: per-call PRNG keys ---------------------------------------------


def test_sampling_keys_vary_per_call_and_replay(sampling_server):
    """With temperature > 0, successive batches must sample different keys
    (the old code re-seeded PRNGKey(seed) every call), while the sequence of
    calls stays reproducible from the seed."""

    cfg = sampling_server.cfg
    reqs = _reqs(cfg)
    first, _ = sampling_server.generate(reqs)
    second, _ = sampling_server.generate(reqs)
    assert not np.array_equal(first, second), (
        "two generate() calls on identical requests sampled identical keys"
    )

    # a fresh server with the same seed replays the same call sequence
    replay = Server(
        cfg, sampling_server.pcfg,
        ServerConfig(max_batch=2, max_new_tokens=5, temperature=0.8, seed=7),
        make_host_communicator(),
    )
    r_first, _ = replay.generate(reqs)
    r_second, _ = replay.generate(reqs)
    assert np.array_equal(first, r_first)
    assert np.array_equal(second, r_second)
