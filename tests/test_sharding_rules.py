"""Sharding rules: divisibility invariants (a spec never maps a dim onto an
axis group that does not divide it), FSDP/TP/EP placement conventions, and
hypothesis sweeps over mesh shapes."""

from __future__ import annotations

import jax
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.core._compat import abstract_mesh

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import api
from repro.sharding import rules


def _mesh(data=4, model=2, pod=None):
    if pod:
        return abstract_mesh((pod, data, model), ("pod", "data", "model"))
    return abstract_mesh((data, model), ("data", "model"))


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _check_divisible(specs, tree, mesh):
    for spec, leaf in zip(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
                          jax.tree.leaves(tree)):
        shape = np.shape(leaf)
        for dim, axes in zip(shape, tuple(spec)):
            if axes is None:
                continue
            assert dim % _axis_size(mesh, axes) == 0, (shape, spec)


@pytest.mark.parametrize("arch_family", ["dense", "moe", "ssm"])
def test_param_specs_divisible(arch_family):
    from repro.configs import base

    arch = {"dense": "gemma2_9b", "moe": "deepseek_v2_236b", "ssm": "mamba2_2_7b"}[arch_family]
    cfg = base.get_smoke_config(arch)
    pcfg = base.get_parallel(arch)
    bundle = api.build(cfg)
    params = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    mesh = _mesh(2, 2)
    specs = rules.param_specs(params, mesh, pcfg)
    _check_divisible(specs, params, mesh)


@settings(max_examples=20, deadline=None)
@given(
    data=st.sampled_from([1, 2, 4, 16]),
    model=st.sampled_from([1, 2, 4, 16]),
    batch=st.sampled_from([1, 2, 8, 256]),
    seq=st.sampled_from([16, 4096]),
)
def test_batch_spec_divisibility_property(data, model, batch, seq):
    mesh = _mesh(data, model)
    pcfg = ParallelConfig()
    batch_tree = {"tokens": jax.ShapeDtypeStruct((batch, seq), jax.numpy.int32)}
    specs = rules.batch_spec(batch_tree, mesh, pcfg)
    _check_divisible(specs, batch_tree, mesh)


def test_fsdp_toggle_changes_weight_spec():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512)
    bundle = api.build(cfg)
    params = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    mesh = _mesh(2, 2)
    on = rules.param_specs(params, mesh, ParallelConfig(fsdp=True))
    off = rules.param_specs(params, mesh, ParallelConfig(fsdp=False))
    flat_on = jax.tree.leaves(on, is_leaf=lambda x: isinstance(x, P))
    flat_off = jax.tree.leaves(off, is_leaf=lambda x: isinstance(x, P))
    def uses_data(s):
        return any(a and ("data" in (a if isinstance(a, tuple) else (a,))) for a in tuple(s))
    assert any(uses_data(s) for s in flat_on)
    assert not any(uses_data(s) for s in flat_off)


def test_expert_parallel_spec():
    from repro.configs import base

    cfg = base.get_smoke_config("deepseek_v2_236b")
    bundle = api.build(cfg)
    params = jax.eval_shape(lambda: bundle.init(jax.random.PRNGKey(0)))
    mesh = _mesh(2, 4)
    pcfg = ParallelConfig(shard_experts=True)
    specs = rules.param_specs(params, mesh, pcfg)

    found_expert_dim = []

    def visit(path, spec):
        names = [getattr(k, "key", "") for k in path]
        if "w_gate" in names and "layers" in names:
            found_expert_dim.append(tuple(spec))

    jax.tree_util.tree_map_with_path(visit, specs, is_leaf=lambda x: isinstance(x, P))
    assert found_expert_dim
    # stacked MoE weight: (L, E, d, f) → expert dim mapped to 'model' when divisible
    spec = found_expert_dim[0]
    assert "model" in str(spec)


def test_cache_specs_seq_sharding_toggle():
    from repro.configs import base
    from repro.launch import specs as lspecs
    from repro.models import api as mapi

    cfg = base.get_smoke_config("phi4_mini_3_8b")
    bundle = mapi.build(cfg)
    shape = base.ShapeConfig("t", 64, 4, "decode")
    mesh = _mesh(2, 2)
    for toggle in (False, True):
        pcfg = ParallelConfig(seq_shard_cache=toggle)
        cache = lspecs.cache_structs(bundle, cfg, pcfg, shape)
        specs = rules.cache_specs(cache, mesh, pcfg, cfg)
        _check_divisible(specs, cache, mesh)
        text = str(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))[0])
        if toggle:
            assert "model" in text  # sequence dim carries the model axis
