"""Decomposed/overlapped collectives (the TPU-native meaning of the paper's
future-continuation overlap): every decomposed schedule must equal its plain
collective + compute counterpart."""

from __future__ import annotations

import textwrap


CODE = textwrap.dedent("""
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import core as mpx
    from repro.core import overlap

    comm = mpx.world()
    N = comm.size()

    # ring all-gather == lax all-gather
    @comm.spmd
    def ring_vs_plain():
        x = jnp.full((4, 8), comm.rank(), jnp.float32)
        ring = overlap.ring_all_gather(comm, x, axis=0)
        plain = comm.allgather(x)
        return ring, plain
    ring, plain = ring_vs_plain()
    np.testing.assert_allclose(np.asarray(ring), np.asarray(plain).reshape(ring.shape))

    # bidirectional variant
    @comm.spmd
    def bidir():
        x = jnp.full((4, 8), comm.rank() + 1, jnp.float32)
        return overlap.ring_all_gather_bidirectional(comm, x, axis=0), comm.allgather(x)
    r, p = bidir()
    np.testing.assert_allclose(np.asarray(r), np.asarray(p).reshape(r.shape))

    # all_gather_matmul == x @ all_gather(w_shard) (FSDP weight-gather overlap)
    @comm.spmd
    def agmm():
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 16 * N), jnp.float32)
        w_shard = jax.random.normal(jax.random.PRNGKey(1), (16, 8), jnp.float32) \
            * (comm.rank() + 1.0)
        fused = overlap.all_gather_matmul(comm, x, w_shard)
        w_full = comm.allgather(w_shard).reshape(16 * N, 8)
        plain = x @ w_full
        return fused, plain
    f, p = agmm()
    np.testing.assert_allclose(np.asarray(f), np.asarray(p), atol=1e-3, rtol=1e-3)

    # matmul_reduce_scatter: k sharded over ranks; fused == psum(x_r@w_r)
    # sliced to this rank's f/n block (TP output-scatter overlap)
    @comm.spmd
    def mmrs():
        r = comm.rank()
        x_r = jax.random.normal(jax.random.PRNGKey(2), (4, 16), jnp.float32) * (r + 1.0)
        w_r = jax.random.normal(jax.random.PRNGKey(3), (16, 8), jnp.float32) * (r + 1.0)
        fused = overlap.matmul_reduce_scatter(comm, x_r, w_r)
        full = comm.allreduce(jnp.matmul(x_r, w_r))
        blk = full.shape[-1] // N
        plain = jax.lax.dynamic_slice_in_dim(full, r * blk, blk, axis=-1)
        return fused, plain
    f, p = mmrs()
    np.testing.assert_allclose(np.asarray(f), np.asarray(p), atol=1e-3, rtol=1e-3)

    # ring attention == full attention (sequence-parallel schedule)
    @comm.spmd
    def ringattn():
        k = jax.random.PRNGKey(4)
        q = jax.random.normal(k, (1, 8, 2, 16), jnp.float32)
        kk = jax.random.normal(jax.random.PRNGKey(5), (1, 8, 2, 16), jnp.float32)
        v = jax.random.normal(jax.random.PRNGKey(6), (1, 8, 2, 16), jnp.float32)
        out = overlap.ring_attention(comm, q, kk, v, causal=False)
        return out, q, kk, v
    out, q, kk, v = ringattn()

    # oracle: gather the ring shards on host and run full attention
    from repro.kernels.flash_attention import ops as fa
    # each rank held identical q/kk/v here (PRNG same), ring over shards of
    # the same tensor == attention over the concatenation of N copies
    qq = np.asarray(q); kks = np.tile(np.asarray(kk), (1, N, 1, 1)); vvs = np.tile(np.asarray(v), (1, N, 1, 1))
    import jax.numpy as jnp2
    ref = fa.flash_attention(jnp2.asarray(qq), jnp2.asarray(kks), jnp2.asarray(vvs),
                             causal=False, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4, rtol=1e-4)

    # hierarchical allreduce == flat allreduce (multi-pod gradient path)
    grid = mpx.Communicator.create((2, 4), ("pod", "data"))
    pods = grid.split("pod")
    inner = grid.split("data")
    @grid.spmd
    def hier():
        x = jnp.full((8,), grid.rank() + 1, jnp.float32)
        h = overlap.hierarchical_allreduce(x, inner=inner, outer=pods)
        flat = grid.allreduce(x)
        return h, flat
    h, flat = hier()
    np.testing.assert_allclose(np.asarray(h), np.asarray(flat))

    # compressed hierarchical allreduce: int8 cross-pod payload stays close
    from repro.core.descriptors import Compression
    @grid.spmd
    def hier_c():
        x = jax.random.normal(jax.random.PRNGKey(7), (256,), jnp.float32)
        h = overlap.hierarchical_allreduce(x, inner=inner, outer=pods,
                                           compression=Compression.INT8)
        flat = grid.allreduce(x)
        return h, flat
    hc, flatc = hier_c()
    rel = np.abs(np.asarray(hc) - np.asarray(flatc)).max() / np.abs(np.asarray(flatc)).max()
    assert rel < 0.05, rel

    print("OVERLAP_OK")
""")


def test_overlap_equivalences_8dev(subproc):
    out = subproc(CODE, n=8)
    assert "OVERLAP_OK" in out


PIPELINE_CODE = textwrap.dedent("""
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro import core as mpx
    from repro.core import overlap, topology

    comm = mpx.world()
    S = comm.size()
    cart = topology.cart_create(comm, (S,), (False,))

    # halo exchange == the two boundary permutes, nulls read zero
    def halo(x):
        lo, hi = overlap.halo_exchange(cart, x + cart.rank().astype(x.dtype),
                                       dim=0, axis=0, width=2).get()
        return jnp.stack([lo, hi])
    out = np.asarray(cart.spmd(halo, out_specs=P("cart0"))(
        jnp.zeros((4,), jnp.float32))).reshape(S, 2, 2)
    for r in range(S):
        exp_lo = np.full((2,), r - 1.0) if r > 0 else np.zeros(2)
        exp_hi = np.full((2,), r + 1.0) if r < S - 1 else np.zeros(2)
        assert np.allclose(out[r, 0], exp_lo) and np.allclose(out[r, 1], exp_hi), out[r]

    # pipeline schedule: stage s multiplies by (s + 1); M microbatches of a
    # (M, B) input must each come out scaled by (S)! / product of stages,
    # in microbatch order — proves injection, staging and drain alignment
    M = 3
    factor = float(np.prod(np.arange(1, S + 1)))
    def pipe(xs):
        stage = jax.lax.axis_index("cart0").astype(jnp.float32)
        outs = overlap.pipeline_spmd(
            cart, stage_dim=0, num_microbatches=M,
            inject=lambda i: xs[i],
            stage_fn=lambda state, t: state * (stage + 1.0),
            extract=lambda i, state, is_last: jnp.where(is_last, state, 0.0),
        )
        # only the last stage holds the drained value; psum replicates it
        return jnp.stack([jax.lax.psum(o, "cart0") for o in outs])
    xs = jnp.arange(1, M + 1, dtype=jnp.float32)[:, None] * jnp.ones((M, 4))
    got = np.asarray(cart.spmd(pipe)(xs))
    exp = np.asarray(xs) * factor
    assert np.allclose(got, exp), (got, exp)

    print("PIPELINE_OK")
""")


def test_pipeline_schedule_and_halo_4dev(subproc):
    out = subproc(PIPELINE_CODE, n=4)
    assert "PIPELINE_OK" in out
