"""Shared test fixtures.

NOTE: no XLA_FLAGS here — the main pytest process sees ONE CPU device by
design (the dry-run is the only place that forces 512).  Multi-device
behaviour is tested through ``run_with_devices``, which re-execs a code
snippet in a subprocess with a virtual-device count set before jax imports.
"""

from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_with_devices(code: str, n: int = 8, timeout: int = 900) -> str:
    """Run ``code`` in a fresh python with ``n`` virtual CPU devices."""

    env = {
        **os.environ,
        "XLA_FLAGS": f"--xla_force_host_platform_device_count={n}",
        "PYTHONPATH": str(ROOT / "src"),
    }
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=str(ROOT),
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n--- stdout\n{proc.stdout}"
            f"\n--- stderr\n{proc.stderr[-4000:]}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices


@pytest.fixture()
def tiny_dense_cfg():
    from repro.configs.base import ModelConfig

    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
    )


@pytest.fixture()
def pcfg():
    from repro.configs.base import ParallelConfig

    return ParallelConfig()
