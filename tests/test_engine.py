"""Continuous-batching engine: parity against the fixed-batch Server
oracle, paged-pool accounting, preemption/resume, fan-out topologies, and
the serving stats/bench-gate fixes that rode along (single-device)."""

from __future__ import annotations

import json
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core import errors, onesided
from repro.core.descriptors import WindowSpec
from repro.core import topology
from repro.launch.mesh import make_host_communicator
from repro.runtime.engine import Engine, EngineConfig, make_engine
from repro.runtime.kvpool import KVBlockPool
from repro.runtime.server import (
    Request,
    Server,
    ServerConfig,
    generation_lengths,
)

ROOT = Path(__file__).resolve().parents[1]

BUCKET = 8


def _tiny_cfg():
    # float32: the parity tests compare argmax chains token-for-token, and
    # bf16 rounding flips near-tied argmaxes between batch shapes
    return ModelConfig(
        name="tiny", family="dense", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, head_dim=16, d_ff=64, vocab_size=64, dtype="float32",
    )


def _server(max_batch=4, max_new=6, **kw):
    return Server(
        _tiny_cfg(), ParallelConfig(),
        ServerConfig(max_batch=max_batch, max_new_tokens=max_new,
                     temperature=0.0, **kw),
        make_host_communicator(),
    )


def _prompts(n, seed=0, vocab=64):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(1, vocab, size=(int(rng.integers(2, BUCKET + 1)),),
                     dtype=np.int32)
        for _ in range(n)
    ]


def _oracle(server, prompts):
    """The fixed-batch Server on bucket-left-padded prompts is the engine's
    parity oracle: same content at the same cache positions."""

    outs = {}
    mb = server.scfg.max_batch
    for i in range(0, len(prompts), mb):
        group = prompts[i:i + mb]
        reqs = [
            Request(tokens=np.concatenate(
                [np.zeros((BUCKET - len(p),), np.int32), p]))
            for p in group
        ]
        tokens, _ = server.generate(reqs)
        for j, _p in enumerate(group):
            outs[i + j] = np.asarray(tokens[j])
    return outs


# -- token-for-token parity ---------------------------------------------------


def test_ragged_admission_parity_with_fixed_batch_oracle():
    """6 ragged requests over 4 slots: the last two are admitted mid-flight
    into a running decode iteration, each request retires at its own budget
    — and every token matches the fixed-batch oracle."""

    srv = _server(max_batch=4, max_new=6)
    prompts = _prompts(6, seed=3)
    budgets = [6, 3, 5, 2, 4, 6]
    oracle = _oracle(srv, prompts)

    eng = Engine(srv, EngineConfig(prompt_bucket=BUCKET, block_tokens=4))
    handles = [eng.submit(p, max_new=b) for p, b in zip(prompts, budgets)]
    eng.run()

    assert all(h.state == "finished" for h in handles)
    for i, h in enumerate(handles):
        assert len(h.generated) == budgets[i]
        np.testing.assert_array_equal(
            np.asarray(h.generated), oracle[i][: budgets[i]],
            err_msg=f"request {i} diverged from the fixed-batch oracle",
        )
    # the fifth/sixth request could only start after a retirement: admission
    # happened mid-flight, not as one big batch
    assert eng.stats()["steps"] < sum(budgets)


def test_preemption_resume_parity_under_memory_pressure():
    """A pool budget too small for four full-depth rows forces evictions;
    preempted requests resume by re-prefilling prompt + generated prefix and
    still match the oracle token-for-token."""

    srv = _server(max_batch=4, max_new=6)
    prompts = _prompts(6, seed=11)
    oracle = _oracle(srv, prompts)

    ecfg = EngineConfig(prompt_bucket=BUCKET, block_tokens=2, pool_blocks=20)
    eng = Engine(srv, ecfg)
    handles = [eng.submit(p) for p in prompts]
    eng.run()

    assert eng.stats()["preemptions"] > 0, (
        "budget of 20 x 2-token blocks must not fit 4 rows of depth 14"
    )
    assert any(h.preemptions > 0 for h in handles)
    for i, h in enumerate(handles):
        np.testing.assert_array_equal(
            np.asarray(h.generated), oracle[i],
            err_msg=f"request {i} diverged after preemption/resume",
        )
    assert eng.pool.live_blocks == 0


def test_block_tables_reused_after_retirement():
    """Slot block ids are slot-affine, so the next occupant of a retired
    slot reuses the freed ids verbatim."""

    srv = _server(max_batch=2, max_new=3)
    eng = Engine(srv, EngineConfig(prompt_bucket=BUCKET, block_tokens=4))
    first = [eng.submit(p, max_new=2) for p in _prompts(2, seed=1)]
    eng.run()
    tables = {h.slot for h in first}  # slots are cleared on retire
    assert tables == {None}
    first_ids = [sorted(h.block_ids) for h in first]

    second = [eng.submit(p, max_new=2) for p in _prompts(2, seed=2)]
    eng.run()
    second_ids = [sorted(h.block_ids) for h in second]
    assert sorted(map(tuple, first_ids)) == sorted(map(tuple, second_ids))


# -- submission / config validation -------------------------------------------


def test_submit_validation():
    srv = _server(max_batch=2, max_new=4)
    eng = make_engine(srv, EngineConfig(prompt_bucket=4))
    with pytest.raises(errors.TruncateError):
        eng.submit(np.ones((5,), np.int32))          # prompt > bucket
    with pytest.raises(errors.ArgError):
        eng.submit(np.ones((3,), np.int32), max_new=9)   # budget > ceiling
    with pytest.raises(errors.UnsupportedError):
        eng.submit(Request(tokens=np.ones((3,), np.int32),
                           extra={"image_embeds": np.ones((2, 8))}))


def test_engine_rejects_ring_buffer_caches():
    cfg = ModelConfig(
        name="tiny-sw", family="dense", num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, head_dim=16, d_ff=64, vocab_size=64, dtype="float32",
        sliding_window=4,
    )
    srv = Server(cfg, ParallelConfig(),
                 ServerConfig(max_batch=2, max_new_tokens=3, temperature=0.0),
                 make_host_communicator())
    with pytest.raises(errors.UnsupportedError):
        Engine(srv, EngineConfig())


# -- KVBlockPool accounting ---------------------------------------------------


def test_pool_budget_and_range_errors():
    pool = KVBlockPool(num_slots=2, slot_capacity=8, block_tokens=4,
                       budget_blocks=3)
    assert pool.blocks_per_slot == 2 and pool.total_blocks == 4
    assert pool.ensure(0, 8) == [0, 1]
    assert pool.ensure(1, 4) == [2]
    with pytest.raises(errors.NoMemError):
        pool.ensure(1, 8)                    # budget exhausted
    with pytest.raises(errors.RmaRangeError):
        pool.ensure(0, 9)                    # beyond slot capacity
    with pytest.raises(errors.ArgError):
        pool.ensure(2, 4)                    # slot out of range
    assert pool.release(0) == [0, 1]
    assert pool.ensure(1, 8) == [3]          # freed budget absorbed the growth
    assert pool.free_blocks == 1
    with pytest.raises(errors.NoMemError):
        KVBlockPool(num_slots=2, slot_capacity=8, block_tokens=4,
                    budget_blocks=1)         # can't fit even one full slot


def test_pool_mirrors_dynamic_window_attach_state():
    comm = make_host_communicator()
    pool = KVBlockPool(num_slots=2, slot_capacity=8, block_tokens=4)
    win = onesided.Window(
        comm, np.zeros((8, 4), np.float32),
        WindowSpec(dynamic=True, num_pages=pool.total_blocks),
    )
    pool.ensure(0, 8)                        # live before binding
    pool.bind_window(win)
    assert win.attached_pages == {0, 1}
    pool.ensure(1, 5)
    assert win.attached_pages == {0, 1, 2, 3}
    pool.release(0)
    assert win.attached_pages == {2, 3}

    static = onesided.Window(comm, np.zeros((8, 4), np.float32))
    with pytest.raises(errors.WinError):
        pool.bind_window(static)
    mismatched = onesided.Window(
        comm, np.zeros((8, 4), np.float32), WindowSpec(dynamic=True, num_pages=3)
    )
    with pytest.raises(errors.RmaRangeError):
        pool.bind_window(mismatched)


# -- heterogeneous fan-out topology -------------------------------------------


def test_serving_fanout_adjacency_and_routes():
    # 2 prefill : 6 decode — decode rank 2+j pulls from prefill j % 2
    sources, destinations = topology.serving_fanout_adjacency(2, 6)
    assert destinations[:2] == [[2, 4, 6], [3, 5, 7]]   # prefill fan-outs
    assert sources[:2] == [[], []]
    assert sources[2:] == [[0], [1], [0], [1], [0], [1]]
    assert destinations[2:] == [[]] * 6
    perm = topology.fanout_routes(sources, destinations)
    assert perm == [(0, 2), (1, 3), (0, 4), (1, 5), (0, 6), (1, 7)]
    # send_recv carries one target per origin, so the routes split into
    # ceil(D/P) rounds with unique origins (and disjoint targets) each
    rounds = topology.fanout_rounds(perm)
    assert rounds == [[(0, 2), (1, 3)], [(0, 4), (1, 5)], [(0, 6), (1, 7)]]
    srcs35, dsts35 = topology.serving_fanout_adjacency(3, 5)
    rounds35 = topology.fanout_rounds(topology.fanout_routes(srcs35, dsts35))
    assert len(rounds35) == 2
    for rnd in rounds35:
        assert len({s for s, _ in rnd}) == len(rnd)   # unique origins
    assert sorted(d for rnd in rounds35 for _, d in rnd) == [3, 4, 5, 6, 7]
    # a one-to-one bridge permutation is already legal: a single round
    assert topology.fanout_rounds([(0, 2), (1, 3)]) == [[(0, 2), (1, 3)]]
    with pytest.raises(errors.DimsError):
        topology.serving_fanout_adjacency(3, 2)   # more prefill than decode
    with pytest.raises(errors.DimsError):
        topology.serving_fanout_adjacency(0, 4)


# -- Server.generate stats fix ------------------------------------------------


def test_generation_lengths_counts_up_to_stop():
    toks = np.array([
        [5, 9, 2, 7],     # stops at token 2 (index 2) -> length 3
        [5, 9, 4, 7],     # never stops -> full row
        [2, 2, 2, 2],     # stops immediately -> length 1
    ], np.int32)
    assert generation_lengths(toks, 2).tolist() == [3, 4, 1]
    assert generation_lengths(toks, None).tolist() == [4, 4, 4]


def test_generate_stats_report_real_lengths():
    srv = _server(max_batch=2, max_new=4)
    toks, stats = srv.generate([Request(tokens=p) for p in _prompts(2, seed=5)])
    assert stats["gen_lens"] == [4, 4]            # no stop token configured
    assert stats["generated_tokens"] == 8
    assert stats["tokens_per_s"] == pytest.approx(
        8 / stats["decode_s"], rel=1e-6
    )
    # with a stop token, rows must not be billed past their stop
    stop = int(np.asarray(toks)[0, 1])
    srv2 = _server(max_batch=2, max_new=4, stop_token=stop)
    _toks2, stats2 = srv2.generate(
        [Request(tokens=p) for p in _prompts(2, seed=5)]
    )
    lens = stats2["gen_lens"]
    assert stats2["generated_tokens"] == sum(lens)
    assert min(lens) <= 2 and all(1 <= n <= 4 for n in lens)


# -- bench trajectory gate: unguarded warning + reseed ------------------------


@pytest.fixture()
def bench_run():
    sys.path.insert(0, str(ROOT))   # benchmarks/ is a namespace package
    from benchmarks import run as bench_run

    yield bench_run
    sys.path.remove(str(ROOT))


def test_gate_warns_on_unguarded_tracked_series(bench_run, tmp_path, capsys):
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({"overhead_geomean_ratio": 1.0}))
    summary = {
        "overhead_geomean_ratio": 1.0,
        "serving_tokens_ratio": 1.4,     # tracked, but nobody seeded it
        "not_tracked_at_all": 9.9,       # untracked extras stay silent
    }
    rc = bench_run.gate(summary, baseline)
    out = capsys.readouterr().out
    assert rc == 0
    assert "WARNING" in out and "serving_tokens_ratio" in out
    assert "not_tracked_at_all" not in out


def test_gate_fails_on_missing_summary_series(bench_run, tmp_path):
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps(
        {"serving_ttft_p99_ratio": {"value": 0.5, "tolerance": 0.2}}
    ))
    assert bench_run.gate({}, baseline) == 1                   # missing fails
    assert bench_run.gate({"serving_ttft_p99_ratio": 0.55}, baseline) == 0
    assert bench_run.gate({"serving_ttft_p99_ratio": 0.61}, baseline) == 1


def test_reseed_updates_values_keeps_tolerances(bench_run, tmp_path):
    baseline = tmp_path / "baseline.json"
    baseline.write_text(json.dumps({
        "serving_overhead_ratio": {"value": 1.0, "tolerance": 0.1},
        "io_overlap_ratio": 0.97,
    }))
    summary = {
        "serving_overhead_ratio": 1.0444,
        "serving_tokens_ratio": 1.37,
        "untracked_junk": 5.0,
    }
    bench_run.reseed(summary, baseline)
    new = json.loads(baseline.read_text())
    assert new["serving_overhead_ratio"] == {"value": 1.0444, "tolerance": 0.1}
    assert new["serving_tokens_ratio"] == 1.37           # new entry, bare value
    assert new["io_overlap_ratio"] == 0.97               # untouched by this run
    assert "untracked_junk" not in new
