"""The parallelism autotuner and its ``ParallelPlan`` API.

Covers the plan value itself (validation, fold mapping, parsing), the legal
space enumeration, the deterministic roofline search (exhaustive minimum ==
acceptance criterion, coordinate descent never beats it), the multi-slice
device interleave, the ``TrainerConfig`` deprecation shims, and one
end-to-end trainer built from a plan (subprocess, 8 virtual devices).
"""

from __future__ import annotations

import dataclasses
import json
import warnings

import pytest

from repro.configs.base import (
    SHAPES,
    ParallelPlan,
    PlanSpace,
    legal_plans,
    parse_plan,
    plan_space,
)
from repro.core import errors


def _gemma():
    from repro.configs.base import get_config

    return get_config("gemma2_9b")


# -- the plan value -----------------------------------------------------------


def test_plan_defaults_are_pure_data():
    p = ParallelPlan(data=8)
    assert p.fold_dims() == (8,)
    assert p.fold_axes() == ("data",)
    assert p.fold_periods() is None
    assert not p.reforms_fabric
    assert p.fixed_size == 1
    assert p.cart_pset == "repro://cart/8"
    assert p.slug() == "d8"


def test_plan_fold_mapping_per_fabric():
    stage = ParallelPlan(data=2, stage=4, microbatches=2)
    assert stage.fold_dims() == (2, 4)
    assert stage.fold_axes() == ("data", "stage")
    assert stage.fold_periods() == (False, False)

    ring = ParallelPlan(data=2, ring=4)
    assert ring.fold_dims() == (2, 4)
    assert ring.fold_axes() == ("data", "model")
    assert ring.fold_periods() == (False, True)   # KV rotates all the way

    tensor = ParallelPlan(data=2, tensor=4)
    assert tensor.fold_dims() == (2, 4)
    assert tensor.fold_axes() == ("data", "model")
    assert tensor.fold_periods() is None

    for p in (stage, ring, tensor):
        assert p.reforms_fabric and p.total_devices == 8 and p.fixed_size == 4


def test_plan_mutual_exclusions():
    with pytest.raises(errors.TopologyError, match="pick one per plan"):
        ParallelPlan(stage=2, ring=2)
    with pytest.raises(errors.TopologyError, match="model mesh axis"):
        ParallelPlan(ring=2, tensor=2)
    with pytest.raises(errors.TopologyError, match="does not compose"):
        ParallelPlan(stage=2, tensor=2)
    with pytest.raises(errors.TopologyError, match="rides the model axis"):
        ParallelPlan(expert=4, tensor=2)
    with pytest.raises(errors.ArgError, match="remat"):
        ParallelPlan(remat="everything")
    with pytest.raises(errors.ArgError, match="positive int"):
        ParallelPlan(data=0)
    with pytest.raises(errors.TopologyError, match="not a fold axis"):
        ParallelPlan(data=2, tensor=4, dcn_axis="stage")


def test_plan_resolved_fills_data_axis():
    p = ParallelPlan(stage=2, microbatches=2)
    assert p.resolved(8).data == 4
    with pytest.raises(errors.DimsError, match="multiple of 2"):
        p.resolved(7)


def test_plan_from_legacy_matches_old_knobs():
    p = ParallelPlan.from_legacy(pipeline_stages=2, pipeline_microbatches=4)
    assert (p.stage, p.microbatches, p.ring) == (2, 4, 1)
    r = ParallelPlan.from_legacy(ring_attention=4)
    assert (r.ring, r.stage, r.microbatches) == (4, 1, 1)
    assert ParallelPlan.from_legacy() == ParallelPlan()


# -- the --plan grammar -------------------------------------------------------


def test_parse_plan_positional():
    p = parse_plan("2x4")
    assert (p.data, p.stage) == (2, 4)
    assert p.microbatches == 2            # pipeline default rides along
    e = parse_plan("2x1x4")               # DxSxE: expert implies tensor
    assert (e.expert, e.tensor) == (4, 4)


def test_parse_plan_key_value_and_aliases():
    p = parse_plan("data=2,ring=4,micro=2,buckets=4,remat=dots")
    assert (p.data, p.ring, p.microbatches, p.grad_buckets, p.remat) == (
        2, 4, 2, 4, "dots",
    )
    assert parse_plan("tensor=2,dcn=model").dcn_axis == "model"
    assert parse_plan("fanout=2:6").fanout == (2, 6)


def test_parse_plan_derives_data_from_devices():
    p = parse_plan("stage=2", devices=8)
    assert (p.data, p.stage, p.microbatches) == (4, 2, 2)
    with pytest.raises(errors.DimsError):
        parse_plan("stage=3", devices=8)


def test_parse_plan_rejects_bad_specs():
    with pytest.raises(errors.ArgError, match="auto"):
        parse_plan("auto")
    with pytest.raises(errors.ArgError, match="unknown plan key"):
        parse_plan("warp=9")
    with pytest.raises(errors.ArgError, match="P:D"):
        parse_plan("fanout=26")
    with pytest.raises(errors.ArgError, match="1-4 dims"):
        parse_plan("2x2x2x2x2")


# -- legal space enumeration --------------------------------------------------


def test_legal_plans_respect_model_constraints():
    cfg = _gemma()
    shape = SHAPES["train_4k"]
    plans = legal_plans(cfg, shape, 8, plan_space("gemma2_9b"))
    assert plans, "gemma2_9b train_4k must have a legal space at 8 devices"
    for p in plans:
        assert sum(x > 1 for x in (p.stage, p.ring, p.tensor)) <= 1
        assert p.expert in (1, p.tensor)
        assert 8 % p.fixed_size == 0 and p.data == 8 // p.fixed_size
        if p.stage > 1:
            assert cfg.num_layers % p.stage == 0 and p.microbatches >= 2
        if p.ring > 1:
            assert shape.seq_len % p.ring == 0
        if p.tensor > 1:
            assert cfg.num_heads % p.tensor == 0
    # enumeration is deterministic
    assert plans == legal_plans(cfg, shape, 8, plan_space("gemma2_9b"))


def test_legal_plans_multi_slice_emit_dcn_axes():
    plans = legal_plans(
        _gemma(), SHAPES["train_4k"], 16, plan_space("gemma2_9b"), slices=2
    )
    axes = {p.dcn_axis for p in plans}
    assert "data" in axes                 # d16 splits over 2 slices
    for p in plans:
        if p.dcn_axis is not None:
            i = p.fold_axes().index(p.dcn_axis)
            assert p.fold_dims()[i] % 2 == 0


def test_plan_space_family_defaults():
    assert plan_space("mamba2_2_7b").rings == (1,)     # no attention ring
    moe = plan_space("deepseek_v2_236b")
    assert all(e in (1, 2, 4, 8) for e in moe.experts)
    # declared per-arch space wins over the family default
    assert plan_space("gemma2_9b").stages == (1, 2, 6)  # 42 layers


def test_ssm_family_has_no_ring_plans():
    cfg = dataclasses.replace(_gemma(), family="ssm")
    plans = legal_plans(cfg, SHAPES["train_4k"], 8, PlanSpace())
    assert plans and all(p.ring == 1 for p in plans)


# -- plan → topology ----------------------------------------------------------


def test_topology_from_plan_round_trip():
    from repro.core.epoch import ELASTIC, TopologySpec

    plan = ParallelPlan(data=2, ring=4)
    spec = TopologySpec.from_plan(plan)
    assert spec.shape == (ELASTIC, 4)
    assert spec.axis_names == ("data", "model")
    assert spec.periods == (False, True)

    stage = TopologySpec.from_plan(ParallelPlan(data=4, stage=2, microbatches=2))
    assert stage.shape == (ELASTIC, 2)
    assert stage.axis_names == ("data", "stage")


# -- scoring + search ---------------------------------------------------------


def test_score_plan_is_deterministic_and_memory_aware():
    from repro.tune import score_plan

    cfg, shape = _gemma(), SHAPES["train_4k"]
    lean = ParallelPlan(data=8, microbatches=8, grad_buckets=4, remat="full")
    fat = ParallelPlan(data=8, remat="none")
    a, b = score_plan(cfg, shape, lean), score_plan(cfg, shape, lean)
    assert a == b                         # pure arithmetic, no clocks
    assert a.step_s > 0 and a.peak_bytes > 0
    # full remat at 8 microbatches holds less state than rm-none at mb=1
    assert a.peak_bytes < score_plan(cfg, shape, fat).peak_bytes


def test_exhaustive_search_is_the_brute_force_minimum():
    from repro.tune import score_plan, search

    cfg, shape = _gemma(), SHAPES["train_4k"]
    space = plan_space("gemma2_9b")
    result = search(cfg, shape, 8, space=space, mode="exhaustive")
    best = min(
        score_plan(cfg, shape, p).step_s
        for p in legal_plans(cfg, shape, 8, space)
    )
    assert result.score.step_s == best
    # deterministic: same cell, same verdict
    again = search(cfg, shape, 8, space=space, mode="exhaustive")
    assert again.plan == result.plan and again.score == result.score


def test_coordinate_search_never_beats_exhaustive_and_scores_less():
    from repro.tune import search

    cfg, shape = _gemma(), SHAPES["train_4k"]
    space = plan_space("gemma2_9b")
    best = search(cfg, shape, 256, space=space, mode="exhaustive")
    greedy = search(cfg, shape, 256, space=space, mode="coordinate")
    assert greedy.score.step_s >= best.score.step_s    # regret >= 1.0
    assert greedy.n_scored < best.n_scored
    with pytest.raises(errors.ArgError, match="unknown search mode"):
        search(cfg, shape, 8, space=space, mode="simulated-annealing")


def test_search_rejects_empty_cell():
    from repro.tune import search

    with pytest.raises(errors.TopologyError, match="no legal plan"):
        # 7 devices: no gemma2 fold divides them except data=7, but the
        # global batch (SHAPES train_4k) does not split 7 ways
        search(_gemma(), SHAPES["train_4k"], 7, space=plan_space("gemma2_9b"))


# -- multi-slice device interleave -------------------------------------------


class _FakeDev:
    def __init__(self, i, slice_index):
        self.id = i
        self.slice_index = slice_index
        self.process_index = 0
        self.platform = "fake"

    def __repr__(self):
        return f"dev{self.id}@s{self.slice_index}"


def _two_slice_session():
    from repro.core.session import Session

    return Session([_FakeDev(i, i // 4) for i in range(8)])


def test_fold_group_splits_dcn_axis_per_slice():
    from repro import tune

    sess = _two_slice_session()
    assert sorted(p for p in sess.psets() if "slice" in p) == [
        "repro://slice/0", "repro://slice/1",
    ]
    # dcn on the data axis: the fold's leading blocks sit whole in a slice
    g = tune.fold_group(sess, ParallelPlan(data=4, ring=2, dcn_axis="data"))
    assert [d.slice_index for d in g.devices] == [0, 0, 0, 0, 1, 1, 1, 1]
    # dcn on the model axis: the ring itself straddles the slice boundary
    g = tune.fold_group(sess, ParallelPlan(data=4, ring=2, dcn_axis="model"))
    assert [d.slice_index for d in g.devices] == [0, 1, 0, 1, 0, 1, 0, 1]
    # no dcn axis: leading world devices, fold order untouched
    g = tune.fold_group(sess, ParallelPlan(data=8))
    assert [d.id for d in g.devices] == list(range(8))


def test_fold_group_rejects_indivisible_dcn_axis():
    from repro import tune

    with pytest.raises(errors.TopologyError, match="does not split"):
        tune.fold_group(
            _two_slice_session(),
            ParallelPlan(data=2, tensor=3, dcn_axis="model"),
        )
    with pytest.raises(errors.GroupError, match="needs 16 devices"):
        tune.fold_group(_two_slice_session(), ParallelPlan(data=16))


def test_tune_registers_cart_pset():
    from repro import tune
    from repro.core import tool

    sess = _two_slice_session()
    before = tool.pvar_read().get("tune:winner_registered", 0)
    result = tune.tune(
        "gemma2_9b", "train_4k", 8, session=sess, calibrate=False,
        space=plan_space("gemma2_9b"),
    )
    assert result.plan.cart_pset in sess.psets()
    assert tool.pvar_read().get("tune:winner_registered", 0) == before + 1
    assert len(sess.pset(result.plan.cart_pset)) == result.plan.total_devices


# -- TrainerConfig shims ------------------------------------------------------


def test_legacy_knobs_resolve_through_shim_with_warning():
    import repro.runtime.trainer as rt
    from repro.core import tool

    rt._deprecated_knob_warned = False
    tcfg = rt.TrainerConfig(pipeline_stages=2, pipeline_microbatches=4)
    before = tool.pvar_read().get("config:deprecated_knob", 0)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        plan = tcfg.resolved_plan()
    assert plan == ParallelPlan(stage=2, microbatches=4)
    # the warning fires once per process; the pvar counts every resolution
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert tcfg.resolved_plan() == plan
    assert tool.pvar_read().get("config:deprecated_knob", 0) == before + 2


def test_plan_and_legacy_knobs_are_mutually_exclusive():
    from repro.runtime.trainer import TrainerConfig

    tcfg = TrainerConfig(plan=ParallelPlan(stage=2, microbatches=2),
                         pipeline_stages=2)
    with pytest.raises(errors.ArgError, match="deprecated"):
        tcfg.resolved_plan()


def test_default_trainer_config_resolves_to_identity_plan():
    from repro.runtime.trainer import TrainerConfig

    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert TrainerConfig().resolved_plan() == ParallelPlan()


# -- dryrun incremental key ---------------------------------------------------


def test_dryrun_cell_done_keys_on_overrides_and_tag(tmp_path):
    from repro.launch.dryrun import _cell_done

    p = tmp_path / "cell.json"
    assert not _cell_done(p, {}, "")                   # missing: run
    p.write_text(json.dumps({"overrides": {"remat": "dots"}, "tag": "x"}))
    assert _cell_done(p, {"remat": "dots"}, "x")       # same request: skip
    assert not _cell_done(p, {"remat": "full"}, "x")   # other overrides: run
    assert not _cell_done(p, {"remat": "dots"}, "y")   # other tag: run
    p.write_text("{torn")
    assert not _cell_done(p, {}, "")                   # unreadable: run


# -- end to end: a trainer built from the tuned plan --------------------------


def test_trainer_from_plan_subprocess(subproc):
    code = """
from repro.configs.base import ModelConfig, ParallelConfig, ParallelPlan
from repro.core import tool
from repro.launch.mesh import make_host_communicator
from repro.runtime.trainer import Trainer, TrainerConfig

cfg = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                  vocab_size=128, dtype="float32")
t = Trainer(cfg, ParallelConfig(),
            TrainerConfig(steps=2, log_every=1,
                          plan=ParallelPlan(stage=2, microbatches=2)),
            make_host_communicator(), seq_len=64, global_batch=8,
            clock=lambda: 0.0)
assert t.comm.dims == (4, 2), t.comm.dims       # data axis fills 8 devices
assert t.comm.axis_names == ("data", "stage")
res = t.run()
assert res["final_step"] == 2
assert tool.pvar_read().get("trace:train_step", 0) == 1, "re-traced!"
print("PLAN_TRAINER_OK")
"""
    assert "PLAN_TRAINER_OK" in subproc(code, n=8)
