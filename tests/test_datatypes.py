"""Datatype reflection (paper C2): automatic 'MPI datatype' generation from
user aggregates, the ``compliant`` concept, and pack/unpack — including
hypothesis property tests over random nested aggregates."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import datatypes as dt
from repro.core import errors


@dataclasses.dataclass
class Particle:
    pos: jax.Array
    vel: jax.Array
    mass: jax.Array


@dataclasses.dataclass
class Cell:
    particles: Particle
    ids: jax.Array


def _particle():
    return Particle(jnp.ones((3,)), jnp.zeros((3,)), jnp.asarray(2.5))


def test_scalar_and_array_compliance():
    assert dt.is_compliant(jnp.float32(1.0))
    assert dt.is_compliant(jnp.ones((4, 4), jnp.bfloat16))
    assert dt.is_compliant(np.arange(3))
    assert dt.is_compliant(1.5)
    assert dt.is_compliant((jnp.ones(2), jnp.zeros(3)))          # tuple
    assert dt.is_compliant([jnp.ones(2), jnp.zeros(2)])          # list
    assert dt.is_compliant({"a": jnp.ones(1)})                   # dict
    assert not dt.is_compliant("strings are not wire data")
    assert not dt.is_compliant(object())


def test_register_aggregate_enables_compliance():
    dt.register_aggregate(Particle)
    p = _particle()
    assert dt.is_compliant(p)
    d = dt.datatype_of(p)
    assert d is not None


def test_nested_aggregate():
    dt.register_aggregate(Particle)
    dt.register_aggregate(Cell)
    c = Cell(particles=_particle(), ids=jnp.arange(3))
    assert dt.is_compliant(c)
    bufs, d = dt.pack(c)
    out = dt.unpack(bufs, d)
    assert isinstance(out, Cell)
    np.testing.assert_array_equal(out.ids, c.ids)
    np.testing.assert_array_equal(out.particles.pos, c.particles.pos)


def test_pack_unpack_roundtrip_identity():
    dt.register_aggregate(Particle)
    p = _particle()
    bufs, d = dt.pack(p)
    assert all(isinstance(b, jax.Array) for b in bufs)
    q = dt.unpack(bufs, d)
    np.testing.assert_array_equal(q.pos, p.pos)
    np.testing.assert_array_equal(q.vel, p.vel)
    np.testing.assert_array_equal(q.mass, p.mass)


def test_noncompliant_rejected_in_communication():
    from repro import core as mpx

    comm = mpx.world()
    with pytest.raises(errors.TypeError_):
        comm.run(lambda: mpx.broadcast(comm, object()))  # type: ignore[arg-type]


# ---------------------------------------------------------------------------
# hypothesis: random nested aggregates survive pack/unpack, compliance is
# decidable and stable
# ---------------------------------------------------------------------------

_leaf = st.sampled_from([
    lambda: jnp.float32(3.0),
    lambda: jnp.ones((2, 3), jnp.bfloat16),
    lambda: jnp.arange(4, dtype=jnp.int32),
    lambda: np.float64(1.25),
])


@st.composite
def _pytrees(draw, depth=2):
    if depth == 0:
        return draw(_leaf)()
    kind = draw(st.sampled_from(["leaf", "tuple", "dict", "list"]))
    if kind == "leaf":
        return draw(_leaf)()
    n = draw(st.integers(1, 3))
    children = [draw(_pytrees(depth=depth - 1)) for _ in range(n)]
    if kind == "tuple":
        return tuple(children)
    if kind == "list":
        return list(children)
    return {f"k{i}": c for i, c in enumerate(children)}


@settings(max_examples=25, deadline=None)
@given(tree=_pytrees())
def test_property_roundtrip(tree):
    assert dt.is_compliant(tree)
    bufs, d = dt.pack(tree)
    out = dt.unpack(bufs, d)
    flat_in, tdef_in = jax.tree.flatten(tree)
    flat_out, tdef_out = jax.tree.flatten(out)
    assert tdef_in == tdef_out
    for a, b in zip(flat_in, flat_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype


@settings(max_examples=25, deadline=None)
@given(tree=_pytrees())
def test_property_datatype_stable(tree):
    d1 = dt.datatype_of(tree)
    d2 = dt.datatype_of(tree)
    assert d1 == d2
